"""Serve a small LM with batched requests through the KV-cache engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch smollm-360m]

Shows the serving split the decode_* dry-run shapes lower: one prefill pass
that writes every layer's cache, then batched single-token decode steps.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.api import model_init
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = model_init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.tokens)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    print(f"prefill+decode {args.tokens} tokens: {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s on CPU)")
    for i in range(min(2, args.batch)):
        print(f"  seq{i}: ...{out[i, args.prompt_len-4:].tolist()}")


if __name__ == "__main__":
    main()
