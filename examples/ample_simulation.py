"""Reproduce Table 5 / Figure 4 with the AMPLE discrete-event simulator.

    PYTHONPATH=src python examples/ample_simulation.py [--full]

Simulates the accelerator (64 nodeslots, 32 HBM banks, fetch-tag partial
response, mixed-precision pools, 200 MHz) over all six paper datasets, in
both event-driven and double-buffered modes.
"""
import argparse

from repro.core.simulator import SimConfig, simulate_dataset

PAPER = {"cora": 0.246, "citeseer": 0.294, "pubmed": 1.617,
         "flickr": 7.227, "reddit": 24.6, "yelp": 57.5}
PAPER_CPU = {"cora": 244.4, "citeseer": 244.3, "pubmed": 362.4,
             "flickr": 475.4, "reddit": 953.3, "yelp": 760.8}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="no node cap (slow)")
    args = ap.parse_args()
    cap = None if args.full else 120_000
    print(f"{'dataset':10s} {'sim ms':>9s} {'paper ms':>9s} {'vs CPU':>8s} "
          f"{'db ms':>9s} {'ev gain':>8s} {'slot busy':>9s}")
    for name in PAPER:
        ev = simulate_dataset(name, max_nodes=cap)
        db = simulate_dataset(name, max_nodes=cap, cfg=SimConfig(event_driven=False))
        print(f"{name:10s} {ev['latency_ms']:9.3f} {PAPER[name]:9.3f} "
              f"{PAPER_CPU[name]/ev['latency_ms']:7.0f}x {db['latency_ms']:9.3f} "
              f"{db['latency_ms']/ev['latency_ms']:7.2f}x {ev['slot_busy_frac']:9.2f}")


if __name__ == "__main__":
    main()
