"""Quickstart: event-driven mixed-precision GCN inference with AMPLE-on-TPU.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic Cora-statistics graph, runs GCN through the AmpleEngine
(event-driven tiles + Degree-Quant int8/float split), and compares against
the dense float oracle — the 60-second tour of the paper's three ideas.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AmpleEngine, EngineConfig
from repro.graphs import add_self_loops, make_dataset
from repro.models.gnn import gcn


def main():
    # 1. A graph with Cora's published statistics (Table 4).
    g = add_self_loops(make_dataset("cora", seed=0))
    g = g.with_features(make_dataset("cora", seed=0).features)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"mean degree {g.mean_degree:.1f}, features {g.feature_dim}")

    # 2. The engine compiles the event-driven ExecutionPlan (the nodeslot
    #    schedule) and the Degree-Quant precision tags.
    eng = AmpleEngine(g, EngineConfig(mixed_precision=True, edges_per_tile=256))
    rep = eng.occupancy_report()
    print(f"event-driven lane occupancy:  {rep['event_driven_lane_occupancy']:.3f}")
    print(f"double-buffer pipeline gaps:  {rep['double_buffer_pipeline_gap_ratio']:.3f}")
    print(f"float-protected nodes:        {rep['float_node_ratio']:.1%} (Table 4: 2.1%)")

    # 3. Two-layer GCN, mixed precision vs dense float oracle.
    params = gcn.init(jax.random.PRNGKey(0), [g.feature_dim, 64, 7])
    x = jnp.asarray(g.features)
    t0 = time.time()
    y = gcn.apply(params, eng, x)
    y.block_until_ready()
    print(f"mixed-precision inference: {(time.time() - t0) * 1e3:.1f} ms "
          f"(CPU; the Pallas kernels target TPU)")

    yref = gcn.apply_reference(params, g, x)
    rel = float(jnp.abs(y - yref).max() / (jnp.abs(yref).max() + 1e-9))
    agree = float((jnp.argmax(y, -1) == jnp.argmax(yref, -1)).mean())
    print(f"vs float oracle: max rel err {rel:.4f}, argmax agreement {agree:.1%}")


if __name__ == "__main__":
    main()
