"""Quickstart: config-driven, event-driven mixed-precision GNN inference.

    PYTHONPATH=src python examples/quickstart.py

The 60-second tour of the unified API: resolve a ``family="gnn"``
ModelConfig from the registry (``get_config("ample-gcn")``), initialise and
run it through the same ``model_init`` / ``model_forward`` surface the LM
families use (the batch carries ``graph`` + ``features``), compare against
the dense float oracle, then serve repeat traffic through the plan-cached
``GNNServeEngine`` to see cold-plan vs cache-hit latency.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import AmpleEngine, compile_plans
from repro.graphs import make_dataset
from repro.models.api import model_forward, model_init
from repro.models.gnn import api as gnn_api
from repro.serve.gnn_engine import GNNServeEngine


def main():
    # 1. A graph with Cora's published statistics (Table 4) and the paper's
    #    GCN as a registry config (arch, dims, precision policy).
    cfg = dataclasses.replace(get_config("ample-gcn", reduced=True), d_model=24)
    g = make_dataset("cora", max_feature_dim=cfg.d_model, seed=0)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"mean degree {g.mean_degree:.1f}, features {g.feature_dim}")
    print(f"config: {cfg.name} arch={cfg.gnn_arch} dims={cfg.gnn_layer_dims} "
          f"precision={cfg.gnn_precision}")

    # 2. compile_plans is the host-side planner (NID programming): the
    #    event-driven nodeslot schedule + Degree-Quant precision tags, as a
    #    reusable, cacheable ExecutionPlan.
    prepared = gnn_api.prepare_graph(cfg, g)  # GCN: explicit self-loops
    plan = compile_plans(prepared, gnn_api.engine_config(cfg),
                         modes=(gnn_api.agg_mode(cfg),))
    eng = AmpleEngine(prepared, plan=plan)
    rep = eng.occupancy_report()
    print(f"event-driven lane occupancy:  {rep['event_driven_lane_occupancy']:.3f}")
    print(f"double-buffer pipeline gaps:  {rep['double_buffer_pipeline_gap_ratio']:.3f}")
    print(f"float-protected nodes:        {rep['float_node_ratio']:.1%} (Table 4: 2.1%)")

    # 3. The family-agnostic model API: same five entry points as the LMs.
    params = model_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(g.features)
    y, _ = model_forward(params, cfg, {"graph": g, "features": x, "engine": eng})

    yref = gnn_api.gnn_reference(cfg, params, g, x)
    rel = float(jnp.abs(y - yref).max() / (jnp.abs(yref).max() + 1e-9))
    agree = float((jnp.argmax(y, -1) == jnp.argmax(yref, -1)).mean())
    print(f"vs float oracle: max rel err {rel:.4f}, argmax agreement {agree:.1%}")

    # 4. Serving: the plan is the cacheable artifact — repeat traffic on the
    #    same graph structure skips the planner (nodeslot recycling).
    serve = GNNServeEngine(cfg, params)
    cold = serve.infer(g, g.features)
    warm = serve.infer(g, g.features)
    print(f"serve cold: plan {cold.plan_ms:.1f} ms + run {cold.run_ms:.1f} ms "
          f"(cache_hit={cold.cache_hit})")
    print(f"serve warm: plan {warm.plan_ms:.1f} ms + run {warm.run_ms:.1f} ms "
          f"(cache_hit={warm.cache_hit}, planner_calls="
          f"{serve.stats['planner_calls']})")

    # 5. Partition-aware serving: the same graph sharded edge-balanced, one
    #    plan per shard with halo exchange (the cluster-level Feature Bank).
    #    Repeat traffic hits the per-shard plan cache; outputs match the
    #    single-plan path to float tolerance.
    sharded = GNNServeEngine(cfg, params, num_shards=4)
    s_cold = sharded.infer(g, g.features)
    s_warm = sharded.infer(g, g.features)
    rep = sharded.shard_report()
    drift = float(jnp.abs(jnp.asarray(s_warm.outputs) - jnp.asarray(warm.outputs)).max())
    print(f"sharded x{s_cold.num_shards}: plan {s_cold.plan_ms:.1f} ms cold, "
          f"cache_hit={s_warm.cache_hit} warm; edge_balance="
          f"{rep['edge_balance']:.3f}, halo {rep['halo_total']} rows/layer, "
          f"max |sharded - unsharded| = {drift:.2e}")

    # 6. Continuous batching: the paper's event-driven nodeslots at the
    #    serving layer. Requests are admitted into micro-batch unions as they
    #    arrive; unions are padded to size classes so ever-changing mixes
    #    reuse cached per-member plan pieces instead of re-running the
    #    planner per composition.
    from repro.serve.async_gnn import AsyncGNNEngine

    async_eng = AsyncGNNEngine(
        GNNServeEngine(cfg, params, union_node_bucket=512, union_edge_bucket=4096),
        window=3,
    )
    pool = [make_dataset("cora", max_nodes=n, max_feature_dim=cfg.d_model, seed=s)
            for n, s in [(150, 1), (120, 2), (180, 3), (90, 4)]]
    for wave in range(3):  # three waves of a varying mix
        for s in pool[wave % 2 :: 2] + [pool[wave]]:
            async_eng.submit(s, s.features)
        async_eng.step()  # completed members return; slots recycle
    async_eng.drain()
    info = async_eng.cache_info()
    lookups = info["member_hits"] + info["member_misses"]
    print(f"continuous batching: {info['completed']} requests in "
          f"{info['steps']} micro-batches; member-plan hit rate "
          f"{info['member_hits'] / max(lookups, 1):.2f} "
          f"(planner ran {info['planner_calls']}x for {lookups} member slots)")

    # 7. Out-of-core serving: cap the device bytes granted to node features.
    #    A request whose feature matrix exceeds the budget keeps features
    #    host-resident in a chunked FeatureStore (f32 + 1-byte int8 streams
    #    per the Degree-Quant tags) and the plan-driven prefetcher streams
    #    chunks through a budget-bound device cache with reuse-distance
    #    eviction. Outputs are bitwise-identical to the in-memory path —
    #    the budget only moves bytes, never numerics.
    #    An async staging worker builds upcoming chunk/row copies ahead of
    #    the consuming tile step; stall_ms / copy_ms are fenced wall-clock
    #    measurements, so prefetch_overlap reports how much copy time the
    #    lookahead actually hid (not an inferred number).
    budget = g.features.nbytes // 4
    ooc = GNNServeEngine(cfg, params, feature_budget_bytes=budget)
    r = ooc.infer(g, g.features)
    exact = bool((r.outputs == warm.outputs).all())
    print(f"out-of-core (budget {budget >> 10}KB of "
          f"{g.features.nbytes >> 10}KB): streamed={r.streamed}, "
          f"{r.bytes_streamed >> 10}KB moved, chunk hit rate "
          f"{r.chunk_hit_rate:.2f}, bitwise == in-memory: {exact}")
    print(f"  async staging: prefetch_overlap={r.prefetch_overlap:.2f} "
          f"(stall {r.stall_ms:.1f}ms of {r.copy_ms:.1f}ms copies)")

    # 8. Runtime edge coefficients: GAT through the same serving stack. The
    #    attention coefficients are computed from node features per layer per
    #    request and scattered through the plan's edge_ids indirection — the
    #    plan cache stays structure-keyed, so warm GAT traffic has exactly
    #    GCN's hit economics (plan_ms == 0, no planner after the cold call).
    gat_cfg = dataclasses.replace(get_config("ample-gat", reduced=True),
                                  d_model=cfg.d_model)
    gat = GNNServeEngine(gat_cfg, key=jax.random.PRNGKey(0))
    g_cold = gat.infer(g, g.features)
    g_warm = gat.infer(g, g.features)
    print(f"gat ({gat_cfg.gnn_heads} heads, runtime coeffs): cold plan "
          f"{g_cold.plan_ms:.1f} ms, warm plan {g_warm.plan_ms:.1f} ms "
          f"(cache_hit={g_warm.cache_hit}, planner_calls="
          f"{gat.stats['planner_calls']}, bitwise warm repeat: "
          f"{bool((g_cold.outputs == g_warm.outputs).all())})")

    #    Heads are vectorized to [E, H] — one tile scan per layer carries all
    #    heads. Set gnn_use_kernel=True to fuse LeakyReLU → segment softmax →
    #    aggregate into a single Pallas launch per layer (int8 FTE weights
    #    are also repacked at load time for the matmul tiling). The fused
    #    path matches the jnp oracle to ~1e-6 (not bitwise — different
    #    association) and is incompatible with feature_budget_bytes.
    fused_cfg = dataclasses.replace(gat_cfg, gnn_use_kernel=True)
    fused = GNNServeEngine(fused_cfg, gat.params)
    g_fused = fused.infer(g, g.features)
    drift = float(abs(g_fused.outputs - g_warm.outputs).max())
    print(f"gat fused kernel: one launch/layer, |fused - jnp| max {drift:.2e}")

    # 9. Multi-tenant serving: the TenantRouter fronts the async engine with
    #    per-tenant queues, token-bucket rate limits and deficit-weighted
    #    round-robin admission — a high-priority "gold" tenant rides ahead
    #    of a best-effort backlog (and may preempt held windows) while DWRR
    #    weights keep best effort at its fair share of node volume. Every
    #    completion streams into per-tenant telemetry (p50/p99 latency,
    #    queue wait, SLO hit rate) with O(1) memory histograms.
    from repro.serve.tenancy import TenantRouter

    router = TenantRouter(async_eng)  # wrap the async engine from section 6
    router.add_tenant("gold", weight=4.0, priority=1, slo_ms=2_000.0)
    router.add_tenant("batch", weight=1.0)
    small = [make_dataset("cora", max_nodes=n, max_feature_dim=cfg.d_model,
                          seed=n) for n in (40, 60, 80)]
    for s in small * 2:                       # saturating best-effort load
        router.submit("batch", s, s.features)
    vip = router.submit("gold", small[0], small[0].features)
    vip.result()                              # drives the DWRR loop
    router.drain()
    snap = router.snapshot()["tenants"]
    for name in ("gold", "batch"):
        t = snap[name]
        print(f"tenant {name}: done={t['completed']} "
              f"p99={t['latency_ms']['p99']:.1f} ms "
              f"queue_p99={t['queue_wait_ms']['p99']:.1f} ms "
              f"slo_hit_rate={t['slo_hit_rate']:.2f}")

    # 10. Observability: request tracing + the unified metrics registry
    #     (src/repro/observe). Tracing is off by default and free when off;
    #     enable() installs a recorder and every serving layer starts
    #     recording lifecycle spans — queue/plan/execute on the consumer
    #     lane, per-chunk copies on the staging lanes, stalls where the
    #     consumer actually blocked — all on one perf_counter timeline, so
    #     trace-derived totals reconcile with the reported *_ms fields.
    #     The written trace.json loads in https://ui.perfetto.dev (or
    #     chrome://tracing): look for copy spans overlapping execute.
    from repro.observe import metrics as ometrics, trace as otrace

    rec = otrace.enable()
    traced = ooc.infer(g, g.features)  # a streamed request, now traced
    path = rec.export("trace.json")
    mine = [s for s in rec.spans() if s.trace_id == traced.trace_id]
    copy_ms = sum(s.dur_ms for s in mine if s.name.startswith("copy:"))
    print(f"trace: {len(rec.spans())} spans -> {path} "
          f"(request {traced.trace_id}: {len(mine)} spans, "
          f"copy spans {copy_ms:.1f}ms vs reported {traced.copy_ms:.1f}ms)")
    otrace.disable()
    #     Metrics need no enabling — the engines' stats dicts ARE registry
    #     cells (StatsView), so the Prometheus dump always agrees with
    #     engine.stats / cache_info(). One line per labeled counter:
    text = ometrics.get_registry().prometheus_text()
    line = next(l for l in text.splitlines()
                if l.startswith("gnn_serve_requests") and ooc.instance in l)
    print(f"metrics: {len(text.splitlines())} exposition lines, e.g. {line}")


if __name__ == "__main__":
    main()
