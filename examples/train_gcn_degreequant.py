"""End-to-end driver: train a GCN with Degree-Quant QAT on synthetic Cora.

    PYTHONPATH=src python examples/train_gcn_degreequant.py [--steps 300]

Reproduces the paper's quantization workflow (§2.3.1): train with stochastic
degree-based protection masks (float nodes protected, the rest fake-quantized
with STE), then deploy int8 through the mixed-precision engine, and report
the accuracy cost of quantization — the quantity Degree-Quant minimizes.
Node-classification labels come from a planted feature/community model so
accuracy is meaningful.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import AmpleEngine, EngineConfig
from repro.core.degree_quant import DegreeQuantConfig, sample_protection_mask
from repro.core.quantization import compute_scale_zp, fake_quant
from repro.graphs import add_self_loops, make_dataset
from repro.models.gnn import gcn
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def planted_labels(g, num_classes, seed):
    """Labels = argmax over class prototypes of (features + neighbor mean)."""
    rng = np.random.default_rng(seed)
    proto = rng.standard_normal((g.feature_dim, num_classes)).astype(np.float32)
    x = g.features
    deg = np.maximum(g.degrees, 1)
    rows = np.repeat(np.arange(g.num_nodes), g.degrees)
    agg = np.zeros_like(x)
    np.add.at(agg, rows, x[g.indices])
    smooth = x + agg / deg[:, None]
    return np.argmax(smooth @ proto, axis=1).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=800)
    ap.add_argument("--lr", type=float, default=5e-3)
    args = ap.parse_args()

    base = make_dataset("cora", max_nodes=args.nodes, max_feature_dim=128, seed=0)
    g = add_self_loops(base).with_features(base.features)
    num_classes = 7
    labels = jnp.asarray(planted_labels(g, num_classes, seed=1))
    train_mask = np.zeros(g.num_nodes, bool)
    train_mask[np.random.default_rng(2).permutation(g.num_nodes)[: g.num_nodes // 2]] = True
    test_mask = ~train_mask
    train_m = jnp.asarray(train_mask)
    x = jnp.asarray(g.features)

    dq = DegreeQuantConfig(p_min=0.0, p_max=0.2)
    eng_float = AmpleEngine(g, EngineConfig(mixed_precision=False))
    cfg = dataclasses.replace(
        get_config("ample-gcn", reduced=True),
        d_model=g.feature_dim, d_ff=32, vocab_size=num_classes,
    )
    params = gcn.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, weight_decay=5e-3)
    opt = adamw_init(params)
    rng = np.random.default_rng(3)

    def loss_fn(p, protect_mask):
        """QAT forward: unprotected node activations are fake-quantized."""
        def fq(h):
            qp = compute_scale_zp(h, symmetric=True)
            hq = fake_quant(h, qp)
            return jnp.where(protect_mask[:, None], h, hq)

        h = fq(x)
        m = eng_float.aggregate(h, mode="gcn")
        h = jax.nn.relu(m @ p["layers"][0]["w"])
        h = fq(h)
        m = eng_float.aggregate(h, mode="gcn")
        logits = m @ p["layers"][1]["w"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
        return jnp.where(train_m, nll, 0.0).sum() / train_m.sum()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    t0 = time.time()
    for step in range(args.steps):
        mask = jnp.asarray(sample_protection_mask(g, dq, rng))
        loss, grads = grad_fn(params, mask)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        if (step + 1) % 50 == 0:
            print(f"step {step+1:4d}  qat_loss {float(loss):.4f}  "
                  f"({time.time()-t0:.1f}s)")

    def accuracy(apply_fn):
        logits = apply_fn()
        pred = jnp.argmax(logits, -1)
        return float((pred == labels)[jnp.asarray(test_mask)].mean())

    acc_float = accuracy(lambda: gcn.apply(cfg, params, eng_float, x))
    eng_int8 = AmpleEngine(g, EngineConfig(mixed_precision=True))
    acc_mixed = accuracy(lambda: gcn.apply(cfg, params, eng_int8, x))
    print(f"\ntest accuracy  float32: {acc_float:.3f}   "
          f"mixed int8/float (deployed): {acc_mixed:.3f}   "
          f"quantization cost: {acc_float - acc_mixed:+.3f}")


if __name__ == "__main__":
    main()
