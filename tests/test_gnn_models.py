"""GCN / GIN / GraphSAGE on the AMPLE engine vs dense references."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AmpleEngine, EngineConfig
from repro.graphs import add_self_loops, make_dataset
from repro.models.gnn import MODELS, gcn, gin, sage

DIMS = [24, 16, 8]


def _graph_for(name, base):
    g = add_self_loops(base) if name == "gcn" else base
    return g.with_features(base.features)


@pytest.fixture(scope="module")
def base_graph():
    return make_dataset("citeseer", max_nodes=150, max_feature_dim=DIMS[0], seed=3)


@pytest.mark.parametrize("name", ["gcn", "gin", "sage"])
def test_model_matches_reference_float(name, base_graph):
    mod = MODELS[name]
    g = _graph_for(name, base_graph)
    x = jnp.asarray(g.features)
    params = mod.init(jax.random.PRNGKey(0), DIMS)
    eng = AmpleEngine(g, EngineConfig(mixed_precision=False, edges_per_tile=64))
    y = mod.apply(params, eng, x)
    yref = mod.apply_reference(params, g, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("name", ["gcn", "gin", "sage"])
def test_model_mixed_precision_bounded_error(name, base_graph):
    mod = MODELS[name]
    g = _graph_for(name, base_graph)
    x = jnp.asarray(g.features)
    params = mod.init(jax.random.PRNGKey(1), DIMS)
    eng = AmpleEngine(g, EngineConfig(mixed_precision=True, edges_per_tile=64))
    y = np.asarray(mod.apply(params, eng, x))
    yref = np.asarray(mod.apply_reference(params, g, x))
    rel = np.abs(y - yref).max() / (np.abs(yref).max() + 1e-9)
    assert rel < 0.08, f"{name}: int8 mixed-precision rel err {rel}"
    assert np.isfinite(y).all()


@pytest.mark.parametrize("name", ["gcn", "gin", "sage"])
def test_model_through_pallas_kernels(name, base_graph):
    """Engine with use_kernel=True routes AGE+FTE through Pallas (interpret)."""
    mod = MODELS[name]
    g = _graph_for(name, base_graph)
    x = jnp.asarray(g.features)
    params = mod.init(jax.random.PRNGKey(2), DIMS)
    eng_k = AmpleEngine(
        g, EngineConfig(mixed_precision=True, edges_per_tile=64, use_kernel=True)
    )
    eng_j = AmpleEngine(
        g, EngineConfig(mixed_precision=True, edges_per_tile=64, use_kernel=False)
    )
    yk = np.asarray(mod.apply(params, eng_k, x))
    yj = np.asarray(mod.apply(params, eng_j, x))
    np.testing.assert_allclose(yk, yj, atol=2e-3, rtol=2e-3)


def test_gcn_permutation_equivariance(base_graph):
    """Relabeling nodes permutes GCN outputs identically (sanity of plans)."""
    from repro.graphs.csr import from_edge_list

    g = add_self_loops(base_graph)
    n = g.num_nodes
    params = gcn.init(jax.random.PRNGKey(3), DIMS)
    x = jnp.asarray(base_graph.features)
    perm = np.random.default_rng(0).permutation(n)
    inv = np.argsort(perm)
    # permuted graph: edge (j -> i) becomes (perm[j] -> perm[i])
    rows = np.repeat(np.arange(n), g.degrees)
    g2 = from_edge_list(perm[g.indices], perm[rows], n)
    x2 = x[jnp.asarray(inv)]

    y1 = gcn.apply(params, AmpleEngine(g, EngineConfig(mixed_precision=False)), x)
    y2 = gcn.apply(params, AmpleEngine(g2, EngineConfig(mixed_precision=False)), x2)
    np.testing.assert_allclose(
        np.asarray(y1), np.asarray(y2)[jnp.asarray(perm)], atol=5e-4, rtol=1e-3
    )
