"""GCN / GIN / GraphSAGE / GAT through the arch registry vs dense references."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import AmpleEngine, EngineConfig
from repro.graphs import make_dataset
from repro.models.gnn import api as gnn_api

ARCHS = ["gcn", "gin", "sage", "gat"]


def _cfg(arch, *, precision="mixed"):
    return dataclasses.replace(
        get_config(f"ample-{arch}", reduced=True),
        d_model=24, d_ff=16, vocab_size=8, gnn_precision=precision,
        gnn_edges_per_tile=64,
    )


@pytest.fixture(scope="module")
def base_graph():
    return make_dataset("citeseer", max_nodes=150, max_feature_dim=24, seed=3)


def _engine(cfg, base, **overrides):
    g = gnn_api.prepare_graph(cfg, base)
    eng_cfg = dataclasses.replace(gnn_api.engine_config(cfg), **overrides)
    return g, AmpleEngine(g, eng_cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_model_matches_reference_float(arch, base_graph):
    cfg = _cfg(arch, precision="float")
    x = jnp.asarray(base_graph.features)
    params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(0))
    _, eng = _engine(cfg, base_graph)
    y = gnn_api.gnn_apply(cfg, params, eng, x)
    yref = gnn_api.gnn_reference(cfg, params, base_graph, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_model_mixed_precision_bounded_error(arch, base_graph):
    cfg = _cfg(arch)
    x = jnp.asarray(base_graph.features)
    params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(1))
    _, eng = _engine(cfg, base_graph)
    y = np.asarray(gnn_api.gnn_apply(cfg, params, eng, x))
    yref = np.asarray(gnn_api.gnn_reference(cfg, params, base_graph, x))
    rel = np.abs(y - yref).max() / (np.abs(yref).max() + 1e-9)
    assert rel < 0.08, f"{arch}: int8 mixed-precision rel err {rel}"
    assert np.isfinite(y).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_model_through_pallas_kernels(arch, base_graph):
    """Engine with use_kernel=True routes AGE+FTE through Pallas (interpret).

    Tolerance note: the two paths differ by float accumulation order in the
    scatter-add; a ~1e-7 difference that lands exactly on an int8 rounding
    boundary flips one quantization code (one step ≈ 1e-2 here), which the
    next layer amplifies. The bound therefore allows a few one-step flips
    rather than float-level agreement (seed's 2e-3 was flaky on sage).
    """
    cfg = _cfg(arch)
    x = jnp.asarray(base_graph.features)
    params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(2))
    _, eng_k = _engine(cfg, base_graph, use_kernel=True)
    _, eng_j = _engine(cfg, base_graph, use_kernel=False)
    yk = np.asarray(gnn_api.gnn_apply(cfg, params, eng_k, x))
    yj = np.asarray(gnn_api.gnn_apply(cfg, params, eng_j, x))
    np.testing.assert_allclose(yk, yj, atol=6e-2, rtol=2e-3)
    assert (np.abs(yk - yj) > 2e-3).mean() < 0.05  # only isolated code flips


def test_gcn_permutation_equivariance(base_graph):
    """Relabeling nodes permutes GCN outputs identically (sanity of plans)."""
    from repro.graphs.csr import add_self_loops, from_edge_list

    cfg = _cfg("gcn", precision="float")
    g = add_self_loops(base_graph)
    n = g.num_nodes
    params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(3))
    x = jnp.asarray(base_graph.features)
    perm = np.random.default_rng(0).permutation(n)
    inv = np.argsort(perm)
    # permuted graph: edge (j -> i) becomes (perm[j] -> perm[i])
    rows = np.repeat(np.arange(n), g.degrees)
    g2 = from_edge_list(perm[g.indices], perm[rows], n)
    x2 = x[jnp.asarray(inv)]

    y1 = gnn_api.gnn_apply(cfg, params, AmpleEngine(g, EngineConfig(mixed_precision=False)), x)
    y2 = gnn_api.gnn_apply(cfg, params, AmpleEngine(g2, EngineConfig(mixed_precision=False)), x2)
    np.testing.assert_allclose(
        np.asarray(y1), np.asarray(y2)[jnp.asarray(perm)], atol=5e-4, rtol=1e-3
    )


def test_registry_lists_paper_archs():
    assert set(gnn_api.list_archs()) >= {"gcn", "gin", "sage", "gat"}
    with pytest.raises(KeyError, match="unknown GNN arch"):
        gnn_api.get_arch("transformer")


def test_agg_mode_defaults_and_override():
    assert gnn_api.agg_mode(_cfg("gcn")) == "gcn"
    assert gnn_api.agg_mode(_cfg("gin")) == "sum"
    assert gnn_api.agg_mode(_cfg("sage")) == "mean"
    assert gnn_api.agg_mode(_cfg("gat")) == "runtime"
    cfg = dataclasses.replace(_cfg("gin"), gnn_agg="mean")
    assert gnn_api.agg_mode(cfg) == "mean"
