"""Event-driven continuous batching: AsyncGNNEngine + padded union size classes.

The contract under test: a micro-batch admitted asynchronously is served
through the very same plan-assembly + execution steps as the synchronous
``infer_batch``, so identical admitted compositions are **bitwise** identical;
admission is FIFO (no starvation, completion order == submission order); and
padded size classes keep the member-plan cache hot across varying mixes.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.graphs import make_dataset
from repro.graphs.csr import Graph
from repro.serve.async_gnn import AsyncGNNEngine, GNNTicket
from repro.serve.gnn_engine import GNNRequest, GNNServeEngine

ARCHS = ["gcn", "gin", "sage", "gat"]


def _cfg(arch, *, precision="mixed"):
    return dataclasses.replace(
        get_config(f"ample-{arch}", reduced=True),
        d_model=20, d_ff=12, vocab_size=6, gnn_precision=precision,
        gnn_edges_per_tile=64,
    )


@pytest.fixture(scope="module")
def pool():
    return [
        make_dataset("cora", max_nodes=n, max_feature_dim=20, seed=s)
        for n, s in [(60, 1), (45, 2), (75, 3), (30, 4)]
    ]


# ------------------------------------------------- async == sync, bitwise
@pytest.mark.parametrize("arch", ARCHS)
def test_async_matches_sync_bitwise(arch, pool):
    """One admitted window == one synchronous infer_batch, bit for bit
    (mixed precision on, so plan caching and quant state are exercised)."""
    eng = GNNServeEngine(_cfg(arch), key=jax.random.PRNGKey(7))
    async_eng = AsyncGNNEngine(eng, window=len(pool))
    for g in pool:
        async_eng.submit(g, g.features)
    got = async_eng.drain()
    want = eng.infer_batch([GNNRequest(graph=g, features=g.features) for g in pool])
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.outputs, b.outputs)
        assert a.fingerprint == b.fingerprint
    assert async_eng.stats["steps"] == 1


def test_async_matches_sync_windowed(pool):
    """window=2 splits the stream into pair compositions; each pair is
    bitwise the synchronous infer_batch of that pair."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(3))
    async_eng = AsyncGNNEngine(eng, window=2)
    for g in pool:
        async_eng.submit(g, g.features)
    got = async_eng.drain()
    assert async_eng.stats["steps"] == 2
    for off, pair in ((0, pool[:2]), (2, pool[2:])):
        want = eng.infer_batch(
            [GNNRequest(graph=g, features=g.features) for g in pair]
        )
        for i, b in enumerate(want):
            np.testing.assert_array_equal(got[off + i].outputs, b.outputs)


@pytest.mark.parametrize("num_shards", [1, 2])
def test_async_sharded_matches_sync(num_shards, pool):
    """The admission loop drives the sharded plan path identically."""
    eng = GNNServeEngine(
        _cfg("gcn"), key=jax.random.PRNGKey(5), num_shards=num_shards
    )
    async_eng = AsyncGNNEngine(eng, window=3)
    members = pool[:3]
    for g in members:
        async_eng.submit(g, g.features)
    got = async_eng.drain()
    want = eng.infer_batch(
        [GNNRequest(graph=g, features=g.features) for g in members]
    )
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.outputs, b.outputs)
        assert a.num_shards == num_shards


def test_ticket_result_drives_loop(pool):
    """Reading a pending ticket's result ticks the event loop to completion."""
    async_eng = AsyncGNNEngine(_cfg("gin"), window=2, key=jax.random.PRNGKey(1))
    t1 = async_eng.submit(pool[0], pool[0].features)
    t2 = async_eng.submit(pool[1], pool[1].features)
    assert not t1.done and not t2.done and async_eng.pending == 2
    r2 = t2.result()  # drives step(); both ride the same micro-batch
    assert t1.done and t2.done and async_eng.pending == 0
    assert r2.outputs.shape == (pool[1].num_nodes, 6)
    assert r2.batch_size == 2


# ----------------------------------------------- fairness / slot recycling
def test_fifo_order_and_straggler_isolation(pool):
    """A node-budget-busting straggler closes its window but is neither
    skipped nor overtaken: completion order equals submission order."""
    big = make_dataset("cora", max_nodes=150, max_feature_dim=20, seed=9)
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(2))
    async_eng = AsyncGNNEngine(eng, window=4, max_batch_nodes=160)
    order = [pool[0], big, pool[1], pool[2]]  # 60, 150, 45, 75 nodes
    tickets = [async_eng.submit(g, g.features) for g in order]

    first = async_eng.step()
    assert [t.seq for t in first] == [0]  # big (150) won't fit next to 60
    second = async_eng.step()
    assert [t.seq for t in second] == [1]  # the straggler rides alone
    third = async_eng.step()
    assert [t.seq for t in third] == [2, 3]  # freed slots recycle together
    assert async_eng.step() == []  # idle tick is a no-op
    assert [t.response.batch_size for t in tickets] == [1, 1, 2, 2]


def test_window_slot_recycling_refills_from_queue(pool):
    """Every tick admits up to `window` requests — slots freed by a completed
    batch are immediately refilled from the queue head."""
    async_eng = AsyncGNNEngine(_cfg("sage"), window=2, key=jax.random.PRNGKey(4))
    for g in pool:
        async_eng.submit(g, g.features)
    sizes = []
    while async_eng.pending:
        sizes.append(len(async_eng.step()))
    assert sizes == [2, 2]
    assert async_eng.stats["completed"] == 4


# ------------------------------------------------- padded size-class cache
def test_padded_size_class_cache_hits_across_mixes(pool):
    """Varying member mixes in one size class: the planner runs once per
    distinct member, never per composition."""
    eng = GNNServeEngine(
        _cfg("gcn"), key=jax.random.PRNGKey(0),
        union_node_bucket=256, union_edge_bucket=4096,
    )
    async_eng = AsyncGNNEngine(eng, window=3)
    mixes = [pool[:2], [pool[0], pool[2]], [pool[1], pool[2]], [pool[2], pool[0]]]
    for mix in mixes:
        for g in mix:
            async_eng.submit(g, g.features)
        async_eng.step()
    async_eng.drain()
    info = async_eng.cache_info()
    lookups = info["member_hits"] + info["member_misses"]
    assert info["member_misses"] == 3  # one planner visit per distinct member
    assert info["member_hits"] == lookups - 3
    assert info["member_hits"] / lookups > 0.5
    assert info["class_hits"] >= 3  # all mixes land in one size class
    assert info["planner_calls"] == 3
    # exact composition repeat is a full assembled-plan hit
    again = eng.infer_batch(
        [GNNRequest(graph=g, features=g.features) for g in mixes[0]]
    )
    assert all(r.cache_hit for r in again)


@pytest.mark.parametrize("arch", ARCHS)
def test_padded_matches_exact_shapes(arch, pool):
    """Padded size-class serving returns the same answers as exact-shape
    union serving (float tolerance: tile packing order differs)."""
    cfg = _cfg(arch)
    exact = GNNServeEngine(cfg, key=jax.random.PRNGKey(11))
    padded = GNNServeEngine(
        cfg, exact.params, union_node_bucket=128, union_edge_bucket=512
    )
    reqs = [GNNRequest(graph=g, features=g.features) for g in pool[:3]]
    a = exact.infer_batch(reqs)
    b = padded.infer_batch(reqs)
    for x, y in zip(a, b):
        assert x.outputs.shape == y.outputs.shape  # padding rows sliced off
        np.testing.assert_allclose(x.outputs, y.outputs, atol=1e-5, rtol=1e-5)
    # repeat composition on the padded engine is warm and bitwise-stable
    c = padded.infer_batch(reqs)
    for y, z in zip(b, c):
        assert z.cache_hit
        np.testing.assert_array_equal(y.outputs, z.outputs)


def test_padded_single_infer_prewarms_batches(pool):
    """Solo requests and batch members share one member-plan cache."""
    eng = GNNServeEngine(
        _cfg("gin"), key=jax.random.PRNGKey(6), union_node_bucket=128
    )
    eng.infer(pool[0], pool[0].features)
    eng.infer(pool[1], pool[1].features)
    assert eng.stats["member_misses"] == 2
    eng.infer_batch(
        [GNNRequest(graph=g, features=g.features) for g in pool[:2]]
    )
    assert eng.stats["member_misses"] == 2  # batch reused both solo pieces
    assert eng.stats["member_hits"] == 2


# ------------------------------------------------------- input validation
def test_submit_rejects_bad_feature_rows(pool):
    async_eng = AsyncGNNEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    bad = np.zeros((pool[0].num_nodes - 5, 20), np.float32)
    with pytest.raises(ValueError, match="rows but graph"):
        async_eng.submit(pool[0], bad)
    with pytest.raises(ValueError, match="must be 2-D"):
        async_eng.submit(pool[0], np.zeros(pool[0].num_nodes, np.float32))
    assert async_eng.pending == 0  # nothing half-admitted


def test_engine_rejects_zero_node_graph(pool):
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    empty = Graph(
        indptr=np.zeros(1, np.int64), indices=np.zeros(0, np.int32), num_nodes=0
    )
    with pytest.raises(ValueError, match="zero-node graph"):
        eng.infer(empty, np.zeros((0, 20), np.float32))
    reqs = [
        GNNRequest(graph=pool[0], features=pool[0].features),
        GNNRequest(graph=empty, features=np.zeros((0, 20), np.float32)),
    ]
    with pytest.raises(ValueError, match="zero-node graph"):
        eng.infer_batch(reqs)


def test_infer_batch_rejects_mismatched_features(pool):
    eng = GNNServeEngine(_cfg("sage"), key=jax.random.PRNGKey(0))
    reqs = [
        GNNRequest(graph=pool[0], features=pool[0].features),
        GNNRequest(graph=pool[1], features=pool[0].features),  # wrong rows
    ]
    with pytest.raises(ValueError, match="rows but graph"):
        eng.infer_batch(reqs)


# --------------------------------------------------- response accounting
def test_response_batch_size_and_amortized_run_ms(pool):
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(8))
    solo = eng.infer(pool[0], pool[0].features)
    assert solo.batch_size == 1
    assert solo.run_ms_per_member == solo.run_ms
    batch = eng.infer_batch(
        [GNNRequest(graph=g, features=g.features) for g in pool[:3]]
    )
    for r in batch:
        assert r.batch_size == 3
        assert r.run_ms_per_member == pytest.approx(r.run_ms / 3)
    # every member of one union call reports the same whole-batch wall time
    assert len({r.run_ms for r in batch}) == 1


# ---------------------------------------------------------- persistence
def test_padded_plan_cache_roundtrip(tmp_path, pool):
    """Assembled (padded) union plans persist and warm-start a new engine;
    the 'pad' tag must not resurrect as a transform node group on load."""
    eng = GNNServeEngine(
        _cfg("gcn"), key=jax.random.PRNGKey(12),
        union_node_bucket=128, union_edge_bucket=512,
    )
    reqs = [GNNRequest(graph=g, features=g.features) for g in pool[:2]]
    want = eng.infer_batch(reqs)
    eng.save_plan_cache(str(tmp_path))

    warm = GNNServeEngine(
        eng.cfg, eng.params,
        union_node_bucket=128, union_edge_bucket=512,
    )
    assert warm.load_plan_cache(str(tmp_path)) >= 1
    got = warm.infer_batch(reqs)
    # The assembled plan was resident so no assembly ran, but the member
    # pieces were cold and honestly count as planning paid by this request.
    assert all(not r.cache_hit and r.plan_ms > 0.0 for r in got)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a.outputs, b.outputs)
    hot = warm.infer_batch(reqs)  # pieces + assembly now warm: full hit
    assert all(r.cache_hit and r.plan_ms == 0.0 for r in hot)
    for a, b in zip(want, hot):
        np.testing.assert_array_equal(a.outputs, b.outputs)


def test_submit_rejects_bad_feature_columns(pool):
    """Wrong feature width is rejected at the admission door, not as a
    cryptic concatenate failure after co-admitted members were planned."""
    async_eng = AsyncGNNEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    bad = np.zeros((pool[0].num_nodes, 13), np.float32)
    with pytest.raises(ValueError, match="13 columns"):
        async_eng.submit(pool[0], bad)
    assert async_eng.pending == 0


def test_step_failure_requeues_tickets(pool, monkeypatch):
    """A batch-execution failure must not strand admitted tickets: the
    window goes back to the queue head in order and the error propagates."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=2)
    t1 = async_eng.submit(pool[0], pool[0].features)
    t2 = async_eng.submit(pool[1], pool[1].features)

    real = eng.infer_batch
    calls = {"n": 0}

    def flaky(requests):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient device failure")
        return real(requests)

    monkeypatch.setattr(eng, "infer_batch", flaky)
    with pytest.raises(RuntimeError, match="transient"):
        async_eng.step()
    assert async_eng.pending == 2  # both tickets back in the queue, in order
    assert not t1.done and not t2.done
    done = async_eng.drain()  # retry succeeds
    assert [t.done for t in (t1, t2)] == [True, True]
    assert [r.outputs.shape[0] for r in done] == [g.num_nodes for g in pool[:2]]
    assert async_eng.stats["steps"] == 1  # the failed tick never counted


# --------------------------------------------- latency-aware window close
def test_timeout_holds_partial_window_until_deadline(pool):
    """A partial window is held open (step admits nothing) until the oldest
    request has waited out window_timeout_ms, then admits at the deadline."""
    import time

    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=4, window_timeout_ms=60.0)
    t = async_eng.submit(pool[0], pool[0].features)
    assert async_eng.step() == []  # held: partial window, deadline not reached
    assert async_eng.pending == 1 and not t.done
    assert async_eng.stats["held_windows"] >= 1
    time.sleep(0.08)
    done = async_eng.step()  # deadline passed: the partial window admits
    assert [x.seq for x in done] == [t.seq]
    assert async_eng.stats["deadline_closes"] == 1


def test_timeout_full_window_admits_immediately(pool):
    """Count-closed windows never wait: a full window admits on the next
    tick regardless of the timeout."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=2, window_timeout_ms=10_000.0)
    async_eng.submit(pool[0], pool[0].features)
    async_eng.submit(pool[1], pool[1].features)
    done = async_eng.step()
    assert len(done) == 2
    assert async_eng.stats["deadline_closes"] == 0


def test_timeout_budget_closed_window_admits_immediately(pool):
    """A node-budget-closed window is full by definition: no deadline wait."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(
        eng, window=4, max_batch_nodes=pool[0].num_nodes + 1,
        window_timeout_ms=10_000.0,
    )
    async_eng.submit(pool[0], pool[0].features)
    async_eng.submit(pool[1], pool[1].features)  # overflows the budget
    done = async_eng.step()  # closes at the budget: only the head admits
    assert len(done) == 1
    assert async_eng.pending == 1


def test_timeout_drain_flushes_held_window(pool):
    """drain() is the shutdown path: held partial windows flush at once."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=4, window_timeout_ms=60_000.0)
    async_eng.submit(pool[0], pool[0].features)
    assert async_eng.step() == []
    resps = async_eng.drain()  # no minute-long wait
    assert len(resps) == 1 and resps[0] is not None


def test_timeout_result_sleeps_out_deadline(pool):
    """GNNTicket.result() drives a held window to completion by sleeping the
    remaining deadline rather than spinning or raising."""
    import time

    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=4, window_timeout_ms=50.0)
    t = async_eng.submit(pool[0], pool[0].features)
    t0 = time.monotonic()
    r = t.result()
    waited_ms = (time.monotonic() - t0) * 1e3
    assert r is not None and t.done
    assert async_eng.stats["deadline_closes"] == 1
    # it actually waited for the window deadline (generous lower bound:
    # the first step happens immediately, the sleep covers the rest)
    assert waited_ms >= 20.0


def test_timeout_defaults_from_config(pool):
    cfg = dataclasses.replace(_cfg("gcn"), gnn_window_timeout_ms=75.0)
    async_eng = AsyncGNNEngine(cfg, key=jax.random.PRNGKey(0))
    assert async_eng.window_timeout_ms == 75.0
    async_eng2 = AsyncGNNEngine(cfg, window_timeout_ms=0.0, key=jax.random.PRNGKey(0))
    assert async_eng2.window_timeout_ms == 0.0  # explicit override wins


def test_timeout_budget_saturated_window_admits_immediately(pool):
    """A window whose node budget is already saturated can never admit
    another member — it must not be held for the deadline."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(
        eng, window=4, max_batch_nodes=pool[0].num_nodes,
        window_timeout_ms=60_000.0,
    )
    async_eng.submit(pool[0], pool[0].features)  # alone, saturates the budget
    done = async_eng.step()  # no minute-long hold
    assert len(done) == 1
    assert async_eng.stats["held_windows"] == 0


# ------------------------------------- event-based completion + timeouts
def test_result_timeout_raises_on_held_window(pool):
    """result(timeout=...) bounds the total wait instead of sleeping out a
    long window deadline, and the ticket stays pending (not lost)."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=4, window_timeout_ms=60_000.0)
    t = async_eng.submit(pool[0], pool[0].features)
    with pytest.raises(TimeoutError, match="still pending"):
        t.result(timeout=0.05)
    assert not t.done and async_eng.pending == 1
    assert async_eng.drain()[0] is not None  # shutdown path still completes it


def test_result_wakes_on_event_from_concurrent_driver(pool):
    """A waiter blocked in result() on a held window wakes the moment some
    OTHER thread executes the window — via the completion event, not by
    sleeping out the full deadline remainder."""
    import threading
    import time

    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=4, window_timeout_ms=30_000.0)
    async_eng.step()  # warm nothing; just ensure engine constructed
    t = async_eng.submit(pool[0], pool[0].features)
    got = {}

    def waiter():
        t0 = time.monotonic()
        got["resp"] = t.result(timeout=20.0)
        got["waited_s"] = time.monotonic() - t0

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.15)  # the waiter is now event-waiting on the held window
    async_eng.step(flush=True)  # a concurrent driver executes the window
    th.join(timeout=10.0)
    assert not th.is_alive()
    assert got["resp"] is not None and t.done
    # woke promptly on the event: nowhere near the 30s window deadline
    assert got["waited_s"] < 5.0


def test_window_retries_exhaust_into_failed_tickets(pool, monkeypatch):
    """A window that keeps failing is failed LOUDLY after window_retries
    executions: tickets complete with the error attached (result re-raises)
    instead of wedging the queue forever."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=2, window_retries=3)
    t1 = async_eng.submit(pool[0], pool[0].features)
    t2 = async_eng.submit(pool[1], pool[1].features)

    def always_fails(requests):
        raise RuntimeError("poisoned window")

    monkeypatch.setattr(eng, "infer_batch", always_fails)
    for _ in range(2):  # failures 1..N-1: transient, requeued + raised
        with pytest.raises(RuntimeError, match="poisoned"):
            async_eng.step()
    assert async_eng.pending == 2 and not t1.done
    done = async_eng.step()  # failure N: tickets failed, no raise
    assert [x.seq for x in done] == [t1.seq, t2.seq]
    assert t1.done and t2.done and t1.response is None
    assert isinstance(t1.error, RuntimeError)
    with pytest.raises(RuntimeError, match="poisoned"):
        t1.result()
    assert async_eng.stats["window_failures"] == 3
    assert async_eng.stats["failed_tickets"] == 2
    assert async_eng.stats["completed"] == 0  # failures never count as served
    assert async_eng.pending == 0  # nothing wedged in the queue


def test_window_retries_default_from_config(pool):
    cfg = dataclasses.replace(_cfg("gcn"), gnn_window_retries=5)
    async_eng = AsyncGNNEngine(cfg, key=jax.random.PRNGKey(0))
    assert async_eng.window_retries == 5
    with pytest.raises(ValueError, match="window_retries"):
        AsyncGNNEngine(cfg, window_retries=0, key=jax.random.PRNGKey(0))


def test_failed_tickets_contribute_none_to_drain(pool, monkeypatch):
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=1, window_retries=1)
    t = async_eng.submit(pool[0], pool[0].features)
    monkeypatch.setattr(
        eng, "infer_batch",
        lambda reqs: (_ for _ in ()).throw(RuntimeError("dead")),
    )
    resps = async_eng.drain()  # retries=1: fails immediately, no raise
    assert resps == [None] and t.error is not None


# ----------------------------------------------------- queue_ms accounting
def test_queue_ms_reported_on_async_path(pool):
    """GNNResponse.queue_ms covers admission -> execution start: a request
    that waited in the queue reports a wait of at least that long."""
    import time

    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    async_eng = AsyncGNNEngine(eng, window=2)
    t1 = async_eng.submit(pool[0], pool[0].features)
    time.sleep(0.03)
    t2 = async_eng.submit(pool[1], pool[1].features)
    r1, r2 = (t.result() for t in (t1, t2))
    assert r1.queue_ms >= 25.0  # t1 sat in the queue while t2 arrived
    assert r1.queue_ms > r2.queue_ms >= 0.0
    # queue wait is wait, not compute: execution time is reported separately
    assert r1.run_ms > 0.0


def test_queue_ms_zero_on_direct_sync_calls(pool):
    """Direct infer/infer_batch calls never queued: queue_ms is 0 unless the
    caller stamps an admission time explicitly."""
    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    assert eng.infer(pool[0], pool[0].features).queue_ms == 0.0
    rs = eng.infer_batch(
        [GNNRequest(graph=g, features=g.features) for g in pool[:2]]
    )
    assert all(r.queue_ms == 0.0 for r in rs)


def test_queue_ms_honors_explicit_admission_stamp(pool):
    """A queueing front (the tenancy router) can carry its own admission
    timestamp through the sync path and get an honest end-to-end wait."""
    from repro.serve.gnn_engine import request_stamp

    eng = GNNServeEngine(_cfg("gcn"), key=jax.random.PRNGKey(0))
    admitted_at = request_stamp() - 0.2  # admitted 200ms ago upstream
    r = eng.infer(pool[0], pool[0].features, admitted_at=admitted_at)
    assert r.queue_ms >= 190.0
    rs = eng.infer_batch([
        GNNRequest(graph=pool[0], features=pool[0].features,
                   admitted_at=admitted_at),
        GNNRequest(graph=pool[1], features=pool[1].features),
    ])
    assert rs[0].queue_ms >= 190.0
    assert rs[1].queue_ms == 0.0  # unstamped member stays at zero
