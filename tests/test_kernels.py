"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import build_edge_tile_plan
from repro.graphs.datasets import make_lognormal_graph


# ---------------------------------------------------------------- segment_agg
class TestSegmentAgg:
    @pytest.mark.parametrize("d", [4, 20, 130, 260])
    @pytest.mark.parametrize("ept", [16, 64])
    def test_shape_sweep(self, d, ept):
        from repro.kernels.segment_agg import ops
        from repro.kernels.segment_agg.ref import aggregate_tiles_ref

        g = make_lognormal_graph(80, 4.0, seed=d * 7 + ept)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((80, d)).astype(np.float32))
        plan = build_edge_tile_plan(g, edges_per_tile=ept)
        args = (
            jnp.asarray(plan.gather_idx),
            jnp.asarray(plan.coeff),
            jnp.asarray(plan.seg_ids),
            jnp.asarray(plan.out_node),
        )
        kw = dict(num_nodes=80, segments_per_tile=plan.segments_per_tile)
        out = ops.aggregate_tiles(x, *args, block_d=128, **kw)
        ref = aggregate_tiles_ref(x, *args, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)

    @given(
        n=st.integers(4, 60),
        md=st.floats(1.0, 6.0),
        seed=st.integers(0, 200),
    )
    @settings(max_examples=10)
    def test_property_random_graphs(self, n, md, seed):
        from repro.kernels.segment_agg import ops
        from repro.kernels.segment_agg.ref import aggregate_tiles_ref

        g = make_lognormal_graph(n, md, seed=seed)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((n, 16)).astype(np.float32))
        coeff = rng.uniform(0.5, 1.5, g.num_edges).astype(np.float32)
        plan = build_edge_tile_plan(g, edges_per_tile=32, coeff=coeff)
        args = (
            jnp.asarray(plan.gather_idx),
            jnp.asarray(plan.coeff),
            jnp.asarray(plan.seg_ids),
            jnp.asarray(plan.out_node),
        )
        kw = dict(num_nodes=n, segments_per_tile=plan.segments_per_tile)
        out = ops.aggregate_tiles(x, *args, block_d=128, **kw)
        ref = aggregate_tiles_ref(x, *args, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# --------------------------------------------------------------- quant_matmul
class TestQuantMatmul:
    @pytest.mark.parametrize(
        "m,k,n",
        [(8, 8, 8), (100, 64, 48), (256, 512, 256), (33, 130, 7), (1, 300, 5)],
    )
    def test_shape_sweep_exact(self, m, k, n):
        from repro.kernels.quant_matmul import ops
        from repro.kernels.quant_matmul.ref import quant_matmul_ref

        rng = np.random.default_rng(m * 31 + k * 7 + n)
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        out = ops.quant_matmul(a, b)
        ref = quant_matmul_ref(a, b)
        assert out.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_extreme_values_no_overflow(self):
        """Worst case |a|,|b| = 128: K*128*128 must fit int32 for K ≤ 131072."""
        from repro.kernels.quant_matmul import ops
        from repro.kernels.quant_matmul.ref import quant_matmul_ref

        k = 1024
        a = jnp.full((4, k), -128, jnp.int8)
        b = jnp.full((k, 4), -128, jnp.int8)
        out = ops.quant_matmul(a, b)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(quant_matmul_ref(a, b)))
        assert int(np.asarray(out)[0, 0]) == k * 128 * 128
