"""Multi-tenant serving front: DWRR fairness, priority, preemption, parity.

The contracts under test:

* scheduling — DWRR grants each backlogged tenant its weight share of
  admitted node-volume; priority classes fill first and may preempt strictly
  lower classes out of a *staged* window; no tenant starves under
  adversarial offered load (property test);
* admission control — token-bucket rate limits reject at the door (counted,
  never queued); unknown tenants raise;
* parity — routing changes window composition only: routed outputs are
  **bitwise** identical to driving ``AsyncGNNEngine`` directly (single
  tenant) and to replaying the logged window compositions through a fresh
  synchronous ``infer_batch`` (multi tenant).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.base import get_config
from repro.graphs import make_dataset
from repro.serve.async_gnn import AsyncGNNEngine
from repro.serve.gnn_engine import GNNRequest, GNNServeEngine
from repro.serve.tenancy import (
    RateLimitExceeded,
    TenantRegistry,
    TenantRouter,
    TenantSpec,
    TokenBucket,
    UnknownTenant,
)


def _cfg():
    return dataclasses.replace(
        get_config("ample-gcn", reduced=True),
        d_model=20, d_ff=12, vocab_size=6, gnn_edges_per_tile=64,
    )


@pytest.fixture(scope="module")
def serve_engine():
    return GNNServeEngine(_cfg(), key=jax.random.PRNGKey(7))


@pytest.fixture(scope="module")
def pool():
    return {
        n: make_dataset("cora", max_nodes=n, max_feature_dim=20, seed=n)
        for n in (20, 30, 45, 60, 75)
    }


def _router(serve_engine, *, window=4, max_batch_nodes=None, hold_ms=0.0,
            **router_kwargs):
    return TenantRouter(
        AsyncGNNEngine(serve_engine, window=window,
                       max_batch_nodes=max_batch_nodes),
        hold_ms=hold_ms, **router_kwargs,
    )


def _schedule_only(router):
    """Drive the DWRR fill without executing: pop staged windows until the
    queues drain. Pure scheduling — no engine work, so property tests and
    fairness counts run in microseconds."""
    windows = []
    guard = 0
    while any(router._queues.values()) or router._staged:
        router._fill_staged()
        staged, router._staged, router._staged_nodes = router._staged, [], 0
        assert staged, "fill made no progress with backlog present"
        windows.append(staged)
        guard += 1
        assert guard <= router.stats["submitted"] + 1, "scheduler looping"
    return windows


# ----------------------------------------------------------- registry/bucket
def test_registry_validation():
    reg = TenantRegistry(TenantSpec("a"))
    with pytest.raises(ValueError):
        reg.add("a")  # duplicate
    with pytest.raises(UnknownTenant):
        reg.get("ghost")
    with pytest.raises(ValueError):
        TenantSpec("bad", weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec("")
    reg.add("b", weight=2.0, priority=1, rate_rps=5.0, slo_ms=50.0)
    assert set(reg.names) == {"a", "b"} and len(reg) == 2 and "b" in reg


def test_token_bucket_is_deterministic():
    b = TokenBucket(rate=2.0, burst=2.0)
    assert b.try_acquire(now=1000.0)
    assert b.try_acquire(now=1000.0)
    assert not b.try_acquire(now=1000.0)  # burst exhausted
    assert not b.try_acquire(now=1000.4)  # 0.8 tokens: still short
    assert b.try_acquire(now=1000.6)  # 1.2 tokens accrued
    unlimited = TokenBucket(rate=0.0, burst=0.0)
    assert all(unlimited.try_acquire(now=0.0) for _ in range(100))


def test_rate_limit_rejects_at_the_door(serve_engine, pool):
    router = _router(serve_engine)
    router.add_tenant("limited", rate_rps=0.001, burst=2.0)
    g = pool[20]
    admitted, rejected = 0, 0
    for _ in range(5):
        try:
            router.submit("limited", g, g.features)
            admitted += 1
        except RateLimitExceeded:
            rejected += 1
    assert (admitted, rejected) == (2, 3)
    assert router.stats["rejected"] == 3
    assert router.pending == 2  # rejected requests consume no queue space
    snap = router.snapshot()
    assert snap["tenants"]["limited"]["rejected"] == 3
    router.drain()


def test_unknown_tenant_raises(serve_engine, pool):
    router = _router(serve_engine)
    with pytest.raises(UnknownTenant):
        router.submit("ghost", pool[20], pool[20].features)


# ------------------------------------------------------------------ DWRR
def test_dwrr_weight_share(serve_engine, pool):
    """Two equally-sized backlogged tenants at weight 3:1 split each full
    window 3:1 — the textbook DWRR allocation."""
    router = _router(serve_engine, window=4)
    router.add_tenant("heavy", weight=3.0)
    router.add_tenant("light", weight=1.0)
    g = pool[30]
    for _ in range(12):
        router.submit("heavy", g, g.features)
    for _ in range(12):
        router.submit("light", g, g.features)
    windows = _schedule_only(router)
    # While both are backlogged every window is heavy x3 + light x1.
    for w in windows[:4]:
        counts = {t: sum(1 for rt in w if rt.tenant == t)
                  for t in ("heavy", "light")}
        assert counts == {"heavy": 3, "light": 1}
    # Work conservation: once heavy drains, light gets whole windows.
    assert sum(1 for rt in windows[-2][0:] if rt.tenant == "light") == 4


def test_dwrr_fairness_is_node_volume_not_request_count(serve_engine, pool):
    """A tenant of big graphs and a tenant of small ones at equal weight get
    equal *node* volume — the small-graph tenant admits more requests."""
    router = _router(serve_engine, window=8)
    router.add_tenant("big")
    router.add_tenant("small")
    for _ in range(8):
        router.submit("big", pool[60], pool[60].features)
    for _ in range(24):
        router.submit("small", pool[20], pool[20].features)
    windows = _schedule_only(router)
    both_backlogged = windows[0]
    nodes = {t: sum(rt.graph.num_nodes for rt in both_backlogged
                    if rt.tenant == t) for t in ("big", "small")}
    assert nodes["big"] > 0 and nodes["small"] > 0
    ratio = nodes["big"] / nodes["small"]
    assert 0.5 <= ratio <= 2.0  # equal share within one-request granularity


def test_priority_class_fills_first(serve_engine, pool):
    """While a higher class is backlogged, it leads every window; the lower
    class still rides (same weight => same volume: no starvation)."""
    router = _router(serve_engine, window=4)
    router.add_tenant("gold", priority=1)
    router.add_tenant("be", priority=0)
    g = pool[30]
    for _ in range(8):
        router.submit("be", g, g.features)
    for _ in range(8):
        router.submit("gold", g, g.features)
    windows = _schedule_only(router)
    while_both = [w for w in windows
                  if {rt.tenant for rt in w} == {"gold", "be"}]
    assert while_both, "classes never shared a window"
    for w in while_both:
        # Each DWRR round serves gold before best effort, so gold leads the
        # window and leads every round's slot pair; equal weights still give
        # both classes equal volume (priority is ordering, not capacity).
        assert w[0].tenant == "gold"
        gold_slots = [i for i, rt in enumerate(w) if rt.tenant == "gold"]
        be_slots = [i for i, rt in enumerate(w) if rt.tenant == "be"]
        assert min(gold_slots) < min(be_slots)
        assert len(gold_slots) == len(be_slots)
    # equal weights: best effort completed everything, in its own FIFO order
    be_seqs = [rt.seq for w in windows for rt in w if rt.tenant == "be"]
    assert be_seqs == sorted(be_seqs) and len(be_seqs) == 8


# ------------------------------------------------------------- preemption
def test_preemption_evicts_lower_class_from_held_window(serve_engine, pool):
    """A gold arrival that cannot fit a held staged window bumps the
    largest best-effort member back to its queue head; the victim is not
    lost, not reordered within its tenant, and counted as preempted."""
    router = _router(serve_engine, window=4, max_batch_nodes=120,
                     hold_ms=60_000.0)
    router.add_tenant("gold", priority=1)
    router.add_tenant("be", priority=0)
    t60 = router.submit("be", pool[60], pool[60].features)
    t45 = router.submit("be", pool[45], pool[45].features)
    assert router.step() == []  # partial window held for late arrivals
    assert [rt.tenant for rt in router._staged] == ["be", "be"]
    tg = router.submit("gold", pool[75], pool[75].features)  # 105+75 > 120
    assert [(rt.tenant, rt.graph.num_nodes) for rt in router._staged] == [
        ("be", 45), ("gold", 75)
    ]
    assert t60.preemptions == 1 and t45.preemptions == 0
    assert router.stats["preempted"] == 1
    done = router.drain()
    assert [rt.seq for rt in done] == [t60.seq, t45.seq, tg.seq]
    assert all(rt.response is not None for rt in done)
    assert list(router.window_log) == [
        (("be", t45.seq), ("gold", tg.seq)), (("be", t60.seq),)
    ]
    assert router.snapshot()["tenants"]["be"]["preempted"] == 1


def test_no_preemption_within_a_class(serve_engine, pool):
    """Equal-priority tenants never evict each other: fairness between them
    is DWRR's job, not preemption's."""
    router = _router(serve_engine, window=4, max_batch_nodes=120,
                     hold_ms=60_000.0)
    router.add_tenant("a", priority=1)
    router.add_tenant("b", priority=1)
    router.submit("a", pool[60], pool[60].features)
    router.submit("a", pool[45], pool[45].features)
    assert router.step() == []
    router.submit("b", pool[75], pool[75].features)
    assert [rt.tenant for rt in router._staged] == ["a", "a"]
    assert router.stats["preempted"] == 0
    router.drain()


# ------------------------------------------------------------------ parity
def test_single_tenant_routing_is_bitwise_direct_serving(pool):
    """One tenant reduces DWRR to FIFO: the router composes exactly the
    windows the bare engine would, and outputs are bitwise identical."""
    graphs = [pool[60], pool[45], pool[75], pool[30]]
    routed_eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(7))
    router = TenantRouter(AsyncGNNEngine(routed_eng, window=2))
    router.add_tenant("solo")
    for g in graphs:
        router.submit("solo", g, g.features)
    routed = router.drain()

    direct_eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(7))
    direct = AsyncGNNEngine(direct_eng, window=2)
    for g in graphs:
        direct.submit(g, g.features)
    want = direct.drain()

    assert len(routed) == len(want) == len(graphs)
    for rt, w in zip(routed, want):
        np.testing.assert_array_equal(rt.response.outputs, w.outputs)
        assert rt.response.fingerprint == w.fingerprint
    assert [len(w) for w in router.window_log] == [2, 2]


def test_multi_tenant_windows_replay_bitwise(pool):
    """Every routed window is bitwise the synchronous ``infer_batch`` of
    its logged composition — routing moved requests between windows but
    never changed a number."""
    routed_eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(7))
    router = TenantRouter(AsyncGNNEngine(routed_eng, window=3))
    router.add_tenant("gold", weight=2.0, priority=1)
    router.add_tenant("be")
    tickets = {}
    for g in (pool[60], pool[45], pool[30], pool[20]):
        rt = router.submit("be", g, g.features)
        tickets[rt.seq] = rt
    for g in (pool[75], pool[30]):
        rt = router.submit("gold", g, g.features)
        tickets[rt.seq] = rt
    router.drain()

    replay_eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(7))
    assert router.window_log
    for window in router.window_log:
        members = [tickets[seq] for _, seq in window]
        want = replay_eng.infer_batch([
            GNNRequest(graph=rt.graph, features=rt.features, arch=rt.arch)
            for rt in members
        ])
        for rt, w in zip(members, want):
            np.testing.assert_array_equal(rt.response.outputs, w.outputs)
            assert rt.response.fingerprint == w.fingerprint


# ------------------------------------------------------- failure + timeout
def test_routed_result_timeout_on_held_window(serve_engine, pool):
    router = _router(serve_engine, hold_ms=60_000.0)
    router.add_tenant("t")
    rt = router.submit("t", pool[20], pool[20].features)
    with pytest.raises(TimeoutError):
        rt.result(timeout=0.05)
    assert not rt.done  # timed out, not lost: still staged in the held window
    router.drain()  # shutdown path flushes the hold
    assert rt.done and rt.response is not None


def test_failed_window_completes_routed_tickets_exceptionally(pool):
    eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(7))
    boom = RuntimeError("device on fire")

    def _explode(requests):
        raise boom

    eng.infer_batch = _explode
    router = TenantRouter(AsyncGNNEngine(eng, window=2, window_retries=2))
    router.add_tenant("t", slo_ms=10.0)
    rt = router.submit("t", pool[20], pool[20].features)
    with pytest.raises(RuntimeError):
        router.step(flush=True)  # failure 1: transient, requeued + raised
    assert not rt.done and router.pending == 1
    done = router.step(flush=True)  # failure 2: retries out, ticket failed
    assert done == [rt] and rt.done and rt.error is boom
    with pytest.raises(RuntimeError, match="device on fire"):
        rt.result()
    assert router.stats["failed"] == 1
    assert router.snapshot()["tenants"]["t"]["failed"] == 1
    assert router.pending == 0


# --------------------------------------------------- no-starvation property
@settings(max_examples=40, deadline=None)
@given(
    stream=st.lists(st.integers(min_value=0, max_value=2),
                    min_size=1, max_size=60),
    weights=st.tuples(*[st.sampled_from([0.5, 1.0, 2.0, 4.0])] * 3),
    priorities=st.tuples(*[st.integers(0, 2)] * 3),
    sizes=st.tuples(*[st.sampled_from([20, 30, 45, 60, 75])] * 3),
    window=st.integers(1, 6),
    budget=st.sampled_from([None, 64, 128, 256]),
)
def test_no_tenant_starves_under_adversarial_load(
    serve_engine, pool, stream, weights, priorities, sizes, window, budget
):
    """Property: for ANY tenant mix (weights, priorities, graph sizes), ANY
    submission stream and ANY window/budget, the scheduler (1) terminates,
    (2) admits every request exactly once, (3) preserves FIFO order within
    each tenant, and (4) respects the window's slot and node budgets (an
    oversized request may ride alone). Starvation would fail (1) or (2)."""
    router = _router(serve_engine, window=window, max_batch_nodes=budget)
    for i in range(3):
        router.add_tenant(f"t{i}", weight=weights[i], priority=priorities[i])
    submitted = []
    for tenant_idx in stream:
        g = pool[sizes[tenant_idx]]
        submitted.append(router.submit(f"t{tenant_idx}", g, g.features))
    windows = _schedule_only(router)

    admitted = [rt.seq for w in windows for rt in w]
    assert sorted(admitted) == [rt.seq for rt in submitted]  # (1) + (2)
    for i in range(3):
        seqs = [rt.seq for w in windows for rt in w if rt.tenant == f"t{i}"]
        assert seqs == sorted(seqs)  # (3)
    for w in windows:
        assert 1 <= len(w) <= window  # (4a)
        if budget is not None and len(w) > 1:
            assert sum(rt.graph.num_nodes for rt in w) <= budget  # (4b)
