"""int8 KV cache: decode quality vs full-precision cache (the decode lever)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.api import model_init, model_init_cache, model_decode_step, model_prefill


@pytest.mark.parametrize("arch", ["qwen3-8b", "jamba-v0.1-52b"])
def test_int8_kv_close_to_bf16(arch):
    cfg = get_config(arch, reduced=True)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8",
                               capacity_factor=64.0 if cfg.is_moe else cfg.capacity_factor)
    cfg = dataclasses.replace(cfg, capacity_factor=cfg8.capacity_factor)
    params = model_init(cfg, jax.random.PRNGKey(0))
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)

    def run(c):
        logits, cache, n = model_prefill(params, c, {"tokens": toks}, 24)
        out = [logits[:, -1]]
        for t in range(4):
            lg, cache = model_decode_step(
                params, c, {"tokens": jnp.ones((B, 1), jnp.int32)}, cache, n + t
            )
            out.append(lg)
        return jnp.stack(out)

    full = run(cfg)
    q8 = run(cfg8)
    rel = float(jnp.abs(full - q8).max() / (jnp.abs(full).max() + 1e-9))
    assert rel < 0.05, f"{arch}: int8 KV rel err {rel}"
    # top-1 agreement on every step
    agree = float((jnp.argmax(full, -1) == jnp.argmax(q8, -1)).mean())
    assert agree >= 0.9, agree
