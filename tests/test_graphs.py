"""Graph substrate: CSR invariants, dataset calibration, partitioning."""
from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.graphs import (
    PAPER_DATASETS,
    add_self_loops,
    from_edge_list,
    halo_nodes,
    make_dataset,
    make_lognormal_graph,
    partition_by_edges,
    validate,
)
from repro.graphs.csr import gcn_norm_coeffs


@given(
    n=st.integers(2, 60),
    num_edges=st.integers(0, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_from_edge_list_roundtrip(n, num_edges, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, num_edges)
    dst = rng.integers(0, n, num_edges)
    g = from_edge_list(src, dst, n)
    validate(g)
    # every (src, dst) pair present exactly once
    want = {(int(s), int(d)) for s, d in zip(src, dst)}
    got = {
        (int(j), i) for i in range(n) for j in g.neighbors(i)
    }
    assert got == want


@given(n=st.integers(2, 50), md=st.floats(1.0, 8.0), seed=st.integers(0, 1000))
def test_lognormal_graph_valid(n, md, seed):
    g = make_lognormal_graph(n, md, seed=seed)
    validate(g)
    assert (g.degrees >= 1).all()
    # no self loops, no duplicate edges per row
    for i in range(n):
        nb = g.neighbors(i)
        assert i not in nb
        assert len(set(nb.tolist())) == len(nb)


def test_self_loops_idempotent():
    g = make_lognormal_graph(40, 3.0, seed=1)
    g1 = add_self_loops(g)
    g2 = add_self_loops(g1)
    validate(g1)
    assert g1.num_edges == g.num_edges + 40
    assert g2.num_edges == g1.num_edges
    for i in range(40):
        assert i in g1.neighbors(i)


@pytest.mark.parametrize("name", list(PAPER_DATASETS))
def test_dataset_calibration(name):
    spec = PAPER_DATASETS[name]
    # scaled-down instantiation keeps the mean degree; full sizes used by the
    # simulator are checked against Table 4 in test_simulator.
    n = min(spec.num_nodes, 2000)
    g = make_dataset(name, max_nodes=n, max_feature_dim=64, seed=0)
    validate(g)
    assert g.num_nodes == n
    assert abs(g.mean_degree - spec.mean_degree) / spec.mean_degree < 0.15
    assert g.features.shape[1] == min(spec.feature_dim, 64)


def test_degree_skew_present():
    """Social-graph generators must produce hubs (the paper's premise)."""
    g = make_lognormal_graph(5000, 10.0, seed=0)
    deg = g.degrees
    assert deg.max() > 8 * deg.mean()


def test_gcn_norm_coeffs_match_formula():
    g = add_self_loops(make_lognormal_graph(30, 3.0, seed=3))
    coeff = gcn_norm_coeffs(g)
    deg = g.degrees
    for i in range(g.num_nodes):
        for e, j in enumerate(g.neighbors(i)):
            c = coeff[g.indptr[i] + e]
            assert np.isclose(c, 1.0 / np.sqrt(deg[i] * deg[j]), atol=1e-6)


@given(n=st.integers(4, 200), shards=st.integers(1, 8), seed=st.integers(0, 100))
def test_partition_by_edges_balanced(n, shards, seed):
    g = make_lognormal_graph(n, 4.0, seed=seed)
    part = partition_by_edges(g, shards)
    assert part.num_shards == shards
    assert part.starts[0] == 0 and part.starts[-1] == n
    # every node in exactly one shard; edge counts within 2x of ideal + slack
    covered = 0
    for k in range(shards):
        lo, hi = part.nodes(k)
        covered += hi - lo
        edges = int(g.indptr[hi] - g.indptr[lo])
        ideal = g.num_edges / shards
        assert edges <= 2 * ideal + g.degrees.max() + 1
    assert covered == n


def test_halo_nodes_are_remote_neighbors():
    g = make_lognormal_graph(100, 5.0, seed=7)
    part = partition_by_edges(g, 4)
    for k in range(4):
        lo, hi = part.nodes(k)
        halo = halo_nodes(g, part, k)
        assert all((h < lo) or (h >= hi) for h in halo)
        # union of local + halo covers all neighbours of the shard
        nbrs = set()
        for i in range(lo, hi):
            nbrs.update(g.neighbors(i).tolist())
        remote = {x for x in nbrs if x < lo or x >= hi}
        assert remote == set(halo.tolist())


# ------------------------------------------------- padded disjoint unions
def test_disjoint_union_node_padding():
    from repro.graphs import disjoint_union, validate

    a = make_lognormal_graph(40, 4.0, seed=1)
    b = make_lognormal_graph(25, 3.0, seed=2)
    u = disjoint_union([a, b], pad_num_nodes=128)
    validate(u)
    assert u.num_nodes == 128
    assert u.num_edges == a.num_edges + b.num_edges
    # padding nodes are isolated: no edges in, and never a gather source
    assert np.all(np.diff(u.indptr[65:]) == 0)
    assert u.num_edges == 0 or u.indices.max() < 65


def test_disjoint_union_edge_padding_self_edges_only():
    from repro.graphs import disjoint_union, validate

    a = make_lognormal_graph(40, 4.0, seed=3)
    target_e = a.num_edges + 37
    u = disjoint_union([a], pad_num_nodes=64, pad_num_edges=target_e)
    validate(u)
    assert u.num_nodes == 64 and u.num_edges == target_e
    # every padding edge is a self-edge on a padding node
    rows = np.repeat(np.arange(64), np.diff(u.indptr))
    pad_lanes = rows >= 40
    assert pad_lanes.sum() == 37
    np.testing.assert_array_equal(u.indices[pad_lanes], rows[pad_lanes])


def test_disjoint_union_padded_features_zero_rows():
    from repro.graphs import disjoint_union

    a = make_dataset("cora", max_nodes=30, max_feature_dim=8, seed=1)
    b = make_dataset("cora", max_nodes=20, max_feature_dim=8, seed=2)
    u = disjoint_union([a, b], pad_num_nodes=64)
    assert u.features.shape == (64, 8)
    np.testing.assert_array_equal(u.features[:50], np.concatenate([a.features, b.features]))
    assert not u.features[50:].any()


def test_disjoint_union_padding_validation():
    from repro.graphs import disjoint_union

    a = make_lognormal_graph(40, 4.0, seed=4)
    with pytest.raises(ValueError, match="pad_num_nodes"):
        disjoint_union([a], pad_num_nodes=10)
    with pytest.raises(ValueError, match="pad_num_edges"):
        disjoint_union([a], pad_num_nodes=40, pad_num_edges=a.num_edges - 1)
    with pytest.raises(ValueError, match="padding node"):
        disjoint_union([a], pad_num_nodes=40, pad_num_edges=a.num_edges + 5)
