"""ExecutionPlan invariants — the event-driven scheduler is correct iff every
edge is dispatched exactly once with its coefficient, across all plan kinds."""
from __future__ import annotations

import numpy as np
from _hypothesis_compat import given, st

from repro.core import (
    build_bucket_plan,
    build_edge_tile_plan,
    build_mixed_precision_plans,
    build_padded_plan,
    pack_segments,
)
from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags
from repro.graphs.datasets import make_lognormal_graph


def _edge_multiset_from_tiles(plan):
    """{(dst, src): coeff_sum} reconstructed from the tiles."""
    out = {}
    t, e = plan.gather_idx.shape
    for ti in range(t):
        for lane in range(e):
            c = plan.coeff[ti, lane]
            if c == 0:
                continue
            seg = plan.seg_ids[ti, lane]
            dst = plan.out_node[ti, seg]
            src = plan.gather_idx[ti, lane]
            out[(int(dst), int(src))] = out.get((int(dst), int(src)), 0.0) + float(c)
    return out


def _edge_multiset_from_graph(g, coeff=None):
    out = {}
    for i in range(g.num_nodes):
        lo, hi = g.indptr[i], g.indptr[i + 1]
        for k in range(lo, hi):
            c = 1.0 if coeff is None else float(coeff[k])
            out[(i, int(g.indices[k]))] = out.get((i, int(g.indices[k])), 0.0) + c
    return out


@given(
    n=st.integers(2, 80),
    md=st.floats(1.0, 10.0),
    ept=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 1000),
)
def test_edge_tile_plan_covers_every_edge_once(n, md, ept, seed):
    g = make_lognormal_graph(n, md, seed=seed)
    coeff = np.random.default_rng(seed).uniform(0.5, 2.0, g.num_edges).astype(
        np.float32
    )
    plan = build_edge_tile_plan(g, edges_per_tile=ept, coeff=coeff)
    got = _edge_multiset_from_tiles(plan)
    want = _edge_multiset_from_graph(g, coeff)
    assert set(got) == set(want)
    for k in want:
        assert np.isclose(got[k], want[k], atol=1e-5)
    assert plan.total_edges == g.num_edges


@given(n=st.integers(2, 60), md=st.floats(1.0, 8.0), seed=st.integers(0, 500))
def test_event_driven_beats_double_buffer_occupancy(n, md, seed):
    g = make_lognormal_graph(n, md, seed=seed)
    plan = build_edge_tile_plan(g, edges_per_tile=64)
    padded = build_padded_plan(g, batch_size=16)
    # the paper's claim, structurally: total lane-cycles dispatched by the
    # event-driven schedule never exceed the double-buffered schedule's, up to
    # one partially-filled tail tile.
    event_lanes = plan.num_tiles * plan.edges_per_tile
    padded_lanes = sum(b.gather_idx.size for b in padded.batches)
    assert event_lanes <= padded_lanes + plan.edges_per_tile


def test_bucket_plan_waste_bounded():
    g = make_lognormal_graph(500, 6.0, seed=3)
    plan = build_bucket_plan(g)
    # power-of-two buckets waste < 2x lanes
    assert plan.lane_occupancy > 0.5
    # every node with degree>0 appears; capacity covers its chunk rows
    seen = {}
    for b in plan.buckets:
        for row, v in enumerate(b.node_ids):
            seen[int(v)] = seen.get(int(v), 0) + int((b.coeff[row] != 0).sum())
    deg = g.degrees
    for v, cnt in seen.items():
        assert cnt == deg[v]
    assert set(seen) == {int(v) for v in range(g.num_nodes) if deg[v] > 0}


def test_split_node_partial_response():
    """A hub with degree >> tile capacity must be split across tiles and
    scatter-combine to the exact total (the partial-response mechanism)."""
    from repro.graphs.csr import from_edge_list

    n = 300
    src = np.arange(1, n)
    dst = np.zeros(n - 1, np.int64)  # node 0 has degree n-1 = 299
    g = from_edge_list(src, dst, n)
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    got = _edge_multiset_from_tiles(plan)
    assert len(got) == n - 1
    # node 0's edges span multiple tiles
    tiles_of_0 = {
        ti
        for ti in range(plan.num_tiles)
        for s in range(plan.segments_per_tile)
        if plan.out_node[ti, s] == 0
    }
    assert len(tiles_of_0) >= (n - 1) // 32


def test_mixed_precision_plans_partition_nodes():
    g = make_lognormal_graph(400, 5.0, seed=11)
    tags = inference_precision_tags(g, DegreeQuantConfig(float_ratio=0.05))
    plans = build_mixed_precision_plans(g, tags)
    assert set(plans) == {"float", "int8"}
    fl = set(plans["float"].node_ids.tolist())
    i8 = set(plans["int8"].node_ids.tolist())
    assert fl.isdisjoint(i8)
    assert len(fl) + len(i8) == g.num_nodes
    # protected = highest degree nodes
    deg = g.degrees
    assert min(deg[list(fl)]) >= np.percentile(deg, 90) - 1


@given(
    lengths=st.lists(st.integers(1, 40), min_size=1, max_size=60),
    cap=st.sampled_from([16, 32, 64]),
)
def test_pack_segments_feasible(lengths, cap):
    tile_of, offset_of, num_tiles = pack_segments(lengths, cap)
    total = sum(lengths)
    assert num_tiles >= -(-total // cap)
    # first-fit-decreasing should stay within 2x of optimal lane count
    assert num_tiles * cap <= 2 * total + 2 * cap
    for i, ln in enumerate(lengths):
        assert 0 <= offset_of[i] < cap


# --------------------------------------- padded union size-class planning
def test_size_class_rounds_up():
    from repro.core.scheduler import size_class

    assert size_class(100, 900, 256, 1024) == (256, 1024)
    assert size_class(256, 1024, 256, 1024) == (256, 1024)
    assert size_class(257, 1025, 256, 1024) == (512, 2048)
    assert size_class(100, 900, 0, 0) == (100, 900)  # buckets off = exact
    assert size_class(0, 0, 256, 1024) == (256, 1024)  # never below one bucket


def test_union_bucket_fingerprint_is_class_keyed():
    from repro.core.scheduler import union_bucket_fingerprint

    # different member mixes, same size class -> same key
    a = union_bucket_fingerprint(100, 900, 256, 1024, "cfg", "gcn")
    b = union_bucket_fingerprint(130, 1000, 256, 1024, "cfg", "gcn")
    assert a == b
    # crossing a bucket boundary, changing buckets, or changing config parts
    # all change the key
    assert union_bucket_fingerprint(300, 900, 256, 1024, "cfg", "gcn") != a
    assert union_bucket_fingerprint(100, 900, 128, 1024, "cfg", "gcn") != a
    assert union_bucket_fingerprint(100, 900, 256, 1024, "cfg", "gin") != a


def test_concat_tile_plans_matches_union_aggregation():
    """Assembled member tiles == dense union aggregation (exact edge cover)."""
    from repro.core.scheduler import concat_tile_plans
    from repro.graphs import disjoint_union

    a = make_lognormal_graph(30, 4.0, seed=1)
    b = make_lognormal_graph(20, 3.0, seed=2)
    u = disjoint_union([a, b], pad_num_nodes=64)
    pa = build_edge_tile_plan(a, edges_per_tile=32)
    pb = build_edge_tile_plan(b, edges_per_tile=32)
    cat = concat_tile_plans([pa, pb], [0, 30], num_nodes=64, min_tiles=12)
    assert cat.num_tiles == 12  # padded up to the tile bucket
    assert cat.total_edges == a.num_edges + b.num_edges
    got = _edge_multiset_from_tiles(cat)
    want = _edge_multiset_from_graph(u)
    assert got == want


def test_concat_tile_plans_rejects_geometry_mismatch():
    import pytest

    from repro.core.scheduler import concat_tile_plans

    a = build_edge_tile_plan(make_lognormal_graph(20, 3.0, seed=1), edges_per_tile=32)
    b = build_edge_tile_plan(make_lognormal_graph(20, 3.0, seed=2), edges_per_tile=64)
    with pytest.raises(ValueError, match="tile geometry"):
        concat_tile_plans([a, b], [0, 20], num_nodes=40)
    with pytest.raises(ValueError, match="beyond"):
        concat_tile_plans([a], [30], num_nodes=40)


# ----------------------------------------------- interior/boundary halo split
def test_split_plan_by_halo_partitions_tiles_and_edges():
    """Every tile lands in exactly one half, real-edge counts are conserved,
    interior tiles never gather a halo row, and each run (partial-response
    chain) stays whole inside one half."""
    from repro.core import split_plan_by_halo, tile_runs

    g = make_lognormal_graph(220, 3.0, seed=13)
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    num_owned = 140  # rows >= 140 play the halo role
    interior, boundary = split_plan_by_halo(plan, num_owned)
    assert interior.gather_idx.shape[0] + boundary.gather_idx.shape[0] == \
        plan.gather_idx.shape[0]
    assert interior.total_edges + boundary.total_edges == plan.total_edges
    real_int = interior.coeff != 0
    assert not np.any(real_int & (interior.gather_idx >= num_owned))
    # every boundary run really touches the halo
    bounds = tile_runs(boundary)
    for r in range(bounds.shape[0] - 1):
        t0, t1 = int(bounds[r]), int(bounds[r + 1])
        real = boundary.coeff[t0:t1] != 0
        assert np.any(real & (boundary.gather_idx[t0:t1] >= num_owned))
    # edge multiset is preserved across the split
    whole = _edge_multiset_from_tiles(plan)
    merged = _edge_multiset_from_tiles(interior)
    for k, v in _edge_multiset_from_tiles(boundary).items():
        merged[k] = merged.get(k, 0.0) + v
    assert set(merged) == set(whole)
    for k in whole:
        np.testing.assert_allclose(merged[k], whole[k], rtol=1e-6)


def test_split_plan_by_halo_degenerate_halves():
    from repro.core import split_plan_by_halo

    g = make_lognormal_graph(120, 3.0, seed=14)
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    interior, boundary = split_plan_by_halo(plan, g.num_nodes)
    assert boundary.gather_idx.shape[0] == 0 and boundary.total_edges == 0
    assert interior.total_edges == plan.total_edges
    interior2, boundary2 = split_plan_by_halo(plan, 0)
    assert interior2.total_edges == 0
    assert boundary2.total_edges == plan.total_edges
