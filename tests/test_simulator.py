"""Discrete-event simulator: paper-structure reproduction properties."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.simulator import SimConfig, simulate, simulate_dataset
from repro.graphs.datasets import make_lognormal_graph

PAPER_AMPLE_MS = {"cora": 0.246, "citeseer": 0.294, "pubmed": 1.617}


@pytest.mark.parametrize("name", list(PAPER_AMPLE_MS))
def test_latency_within_calibration_band(name):
    """Simulated Table-5 latency lands within 3x of the published number
    (microarch constants are estimates; the paper publishes none)."""
    rec = simulate_dataset(name)
    ratio = rec["latency_ms"] / PAPER_AMPLE_MS[name]
    assert 1 / 3 < ratio < 3, (name, rec["latency_ms"], PAPER_AMPLE_MS[name])


def test_event_driven_beats_double_buffer_on_skewed_graph():
    # small out_dim as in the paper's classifiers — otherwise the shared FTE
    # serializes both modes and masks the scheduling difference
    g = make_lognormal_graph(5_000, 8.0, sigma=1.6, seed=0)
    ev = simulate(g, feature_dim=256, out_dim=16, cfg=SimConfig(event_driven=True))
    db = simulate(g, feature_dim=256, out_dim=16, cfg=SimConfig(event_driven=False))
    assert db.cycles > 2.0 * ev.cycles  # the paper's core claim


def test_gap_widens_with_degree_skew():
    """More skew (higher sigma) => larger event-driven advantage."""
    gains = []
    for sigma in [0.3, 1.0, 1.8]:
        g = make_lognormal_graph(3_000, 6.0, sigma=sigma, seed=1)
        ev = simulate(g, feature_dim=128, cfg=SimConfig(event_driven=True))
        db = simulate(g, feature_dim=128, cfg=SimConfig(event_driven=False))
        gains.append(db.cycles / ev.cycles)
    assert gains[2] > gains[0], gains


def test_mixed_precision_faster_than_float():
    g = make_lognormal_graph(2_000, 6.0, seed=2)
    all_float = simulate(g, feature_dim=256, float_mask=np.ones(2_000, bool))
    mostly_int8 = simulate(
        g, feature_dim=256, float_mask=np.zeros(2_000, bool)
    )
    assert mostly_int8.cycles < 0.5 * all_float.cycles  # 4x bytes, 2x lanes


def test_partial_response_hides_fetch_latency():
    """Larger fetch-tag capacity (later agg start) must not be faster."""
    g = make_lognormal_graph(1_000, 30.0, sigma=1.2, seed=3)
    early = simulate(g, feature_dim=512, cfg=SimConfig(fetch_tag_capacity=8))
    late = simulate(g, feature_dim=512, cfg=SimConfig(fetch_tag_capacity=10_000))
    assert early.cycles <= late.cycles * 1.01


def test_more_nodeslots_helps_until_bandwidth_bound():
    g = make_lognormal_graph(4_000, 10.0, seed=4)
    c8 = simulate(g, feature_dim=256, cfg=SimConfig(num_nodeslots=8))
    c64 = simulate(g, feature_dim=256, cfg=SimConfig(num_nodeslots=64))
    assert c64.cycles < c8.cycles
