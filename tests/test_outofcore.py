"""Out-of-core serving: feature store, chunk schedule, prefetcher, parity.

The load-bearing guarantee is **bitwise identity**: a request served under a
feature budget (chunk-streamed aggregation + FTE) must produce exactly the
bytes the in-memory path produces, across budgets small enough to force
chunk-cache eviction and multi-wave tiles.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, st

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.message_passing import AmpleEngine, EngineConfig
from repro.core.quantization import compute_scale_zp
from repro.core.scheduler import (
    build_chunk_schedule,
    build_edge_tile_plan,
    pack_tiles_by_chunk,
    tile_runs,
)
from repro.graphs.csr import Graph, from_edge_list
from repro.graphs.datasets import make_dataset, make_lognormal_graph
from repro.memory.feature_store import FeatureStore, default_chunk_rows
from repro.memory.prefetcher import ChunkPrefetcher, StreamStats, StreamedFeatures
from repro.serve.gnn_engine import GNNServeEngine


def _graph(n=600, deg=5.0, seed=0, dim=32):
    g = make_lognormal_graph(n, deg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    return g.with_features(rng.standard_normal((n, dim)).astype(np.float32))


def _banded_graph(n=512, k=3, dim=16):
    """Neighbours within ±k — real source locality for cache/reorder tests."""
    src, dst = [], []
    for i in range(n):
        for o in range(1, k + 1):
            src.append((i + o) % n)
            dst.append(i)
    g = from_edge_list(np.asarray(src), np.asarray(dst), n)
    rng = np.random.default_rng(0)
    return g.with_features(rng.standard_normal((n, dim)).astype(np.float32))


# ------------------------------------------------------------ feature store
def test_store_agg_scale_matches_dense_calibration():
    g = _graph()
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    qp = compute_scale_zp(jnp.asarray(g.features), symmetric=True)
    assert float(qp.scale) == float(store.agg_scale)  # bitwise, not approx


def test_store_int8_chunks_match_device_quantize():
    from repro.core.quantization import quantize

    g = _graph(n=300)
    store = FeatureStore.from_array(g.features, chunk_rows=128)
    qp = compute_scale_zp(jnp.asarray(g.features), symmetric=True)
    xq = np.asarray(quantize(jnp.asarray(g.features), qp))
    for c in range(store.num_chunks):
        lo, hi = store.chunk_range(c)
        np.testing.assert_array_equal(store.chunk_i8(c)[: hi - lo], xq[lo:hi])


def test_store_roundtrip_and_gather():
    g = _graph(n=200, dim=8)
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    np.testing.assert_array_equal(store.dense(), g.features)
    ids = np.asarray([0, 63, 64, 150, 199])
    np.testing.assert_array_equal(store.gather_rows_f32(ids), g.features[ids])
    assert float(store.amax_rows(ids)) == float(np.max(np.abs(g.features[ids])))


def test_store_memmap_backed(tmp_path):
    g = _graph(n=200, dim=8)
    mem = FeatureStore.from_array(
        g.features, chunk_rows=64, memmap_dir=str(tmp_path)
    )
    ram = FeatureStore.from_array(g.features, chunk_rows=64)
    assert (tmp_path / "features.f32.bin").exists()
    assert (tmp_path / "features.i8.bin").exists()
    for c in range(ram.num_chunks):
        np.testing.assert_array_equal(np.asarray(mem.chunk_f32(c)), ram.chunk_f32(c))
        np.testing.assert_array_equal(np.asarray(mem.chunk_i8(c)), ram.chunk_i8(c))


def test_default_chunk_rows_scales_with_budget():
    small = default_chunk_rows(100_000, 256, 1 << 20)
    big = default_chunk_rows(100_000, 256, 1 << 28)
    assert 256 <= small <= big <= 65536


# ----------------------------------------------------------- chunk schedule
def test_chunk_schedule_covers_all_lanes():
    g = _graph(n=800, deg=8.0)
    plan = build_edge_tile_plan(g, edges_per_tile=64)
    sched = build_chunk_schedule(plan, 128)
    for t in range(plan.num_tiles):
        touched = np.unique(plan.gather_idx[t].astype(np.int64) // 128)
        assert set(touched) <= set(sched.tile_chunks[t].tolist())
    # order is a permutation of all tiles
    assert sorted(sched.order.tolist()) == list(range(plan.num_tiles))


def test_reorder_permutes_whole_runs_only():
    """Split nodes must keep their tiles consecutive and in order — the
    bitwise-identity precondition for the streamed scatter-add."""
    g = _graph(n=400, deg=20.0, seed=3)  # hubs overflow tiles -> splits
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    runs = tile_runs(plan)
    assert runs[0] == 0 and runs[-1] == plan.num_tiles
    sched = build_chunk_schedule(plan, 64, reorder=True)
    pos = np.empty(plan.num_tiles, np.int64)
    pos[sched.order] = np.arange(plan.num_tiles)
    for r in range(runs.size - 1):
        span = pos[runs[r] : runs[r + 1]]
        # contiguous and increasing: the run moved as one block
        assert np.array_equal(span, np.arange(span[0], span[0] + span.size))


def test_reorder_raises_chunk_reuse_on_structured_graph():
    """Interleaved-degree banded graph: plan order hops between far-apart
    node ranges, the locality reorder clusters them back together."""
    n, dim = 1024, 8
    src, dst = [], []
    for i in range(n):
        k = 2 if i % 2 == 0 else 3  # alternate degrees -> degree sort shuffles
        for o in range(1, k + 1):
            src.append((i + o) % n)
            dst.append(i)
    g = from_edge_list(np.asarray(src), np.asarray(dst), n)
    rng = np.random.default_rng(0)
    g = g.with_features(rng.standard_normal((n, dim)).astype(np.float32))
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=32)

    def uploads(reorder):
        schedule = build_chunk_schedule(plan, 64, reorder=reorder)
        stats = StreamStats()
        pf = ChunkPrefetcher(
            store, schedule, stream="f32",
            budget_bytes=3 * store.chunk_bytes_f32, prefetch_depth=0,
            stats=stats,
        )
        pf.aggregate(plan)
        return stats.uploads

    assert uploads(True) < uploads(False)


# -------------------------------------------------------- prefetcher cache
def test_belady_cache_all_resident_is_cold_misses_only():
    g = _banded_graph()
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=64)
    schedule = build_chunk_schedule(plan, 64)
    stats = StreamStats()
    pf = ChunkPrefetcher(
        store, schedule, stream="f32", budget_bytes=store.nbytes * 2,
        prefetch_depth=0, stats=stats,
    )
    out = pf.aggregate(plan)
    assert stats.uploads == store.num_chunks  # each chunk moved exactly once
    assert stats.evictions == 0
    assert out.shape == (g.num_nodes, g.feature_dim)


def test_prefetch_overlap_on_local_graph():
    g = _banded_graph(n=1024, k=2)
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    schedule = build_chunk_schedule(plan, 64)
    stats = StreamStats()
    pf = ChunkPrefetcher(
        store, schedule, stream="f32",
        budget_bytes=4 * store.chunk_bytes_f32, prefetch_depth=2, stats=stats,
    )
    pf.aggregate(plan)
    assert stats.prefetched > 0
    assert 0.0 < stats.prefetch_overlap <= 1.0


def test_streamed_aggregate_matches_inmemory_single_stream():
    g = _banded_graph(n=300, k=4)
    from repro.core.aggregation import aggregate_edge_tiles, to_device_plan

    plan = build_edge_tile_plan(g, edges_per_tile=32)
    ref = aggregate_edge_tiles(
        jnp.asarray(g.features), to_device_plan(plan),
        num_nodes=g.num_nodes, segments_per_tile=plan.segments_per_tile,
    )
    store = FeatureStore.from_array(g.features, chunk_rows=32)
    schedule = build_chunk_schedule(plan, 32)
    for budget in (store.chunk_bytes_f32, 3 * store.chunk_bytes_f32):
        pf = ChunkPrefetcher(
            store, schedule, stream="f32", budget_bytes=budget,
            stats=StreamStats(),
        )
        out = pf.aggregate(plan)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------------------------------- engine-level aggregate parity
@pytest.mark.parametrize("mode", ["gcn", "sum", "mean"])  # gcn / gin / sage
def test_engine_aggregate_streamed_bitwise(mode):
    g = _graph(n=500, deg=6.0, seed=2)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=64, mixed_precision=True))
    x = jnp.asarray(g.features)
    ref = np.asarray(eng.aggregate(x, mode=mode))
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    for frac in (10, 3):  # both force eviction (cache < working set)
        sf = StreamedFeatures(store, store.nbytes // frac)
        out = np.asarray(eng.aggregate(sf, mode=mode))
        np.testing.assert_array_equal(out, ref)
        assert sf.stats.bytes_streamed > 0
        assert sf.stats.evictions > 0


def test_engine_aggregate_streamed_float_policy_bitwise():
    g = _graph(n=400, deg=5.0, seed=4)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=64, mixed_precision=False))
    ref = np.asarray(eng.aggregate(jnp.asarray(g.features), mode="sum"))
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    sf = StreamedFeatures(store, store.nbytes // 4)
    np.testing.assert_array_equal(np.asarray(eng.aggregate(sf, mode="sum")), ref)


def test_engine_transform_streamed_bitwise():
    g = _graph(n=400, deg=5.0, seed=5, dim=24)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=64, mixed_precision=True))
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((24, 16)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((16,)).astype(np.float32))
    ref = np.asarray(eng.transform(jnp.asarray(g.features), w, b, jax.nn.relu))
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    sf = StreamedFeatures(store, store.nbytes // 4)
    out = np.asarray(eng.transform(sf, w, b, jax.nn.relu))
    np.testing.assert_array_equal(out, ref)
    assert sf.stats.bytes_streamed > 0


# -------------------------------------------------- serve-level end-to-end
@pytest.mark.parametrize("arch", ["gcn", "gin", "sage", "gat"])
def test_served_outofcore_bitwise_identical(arch):
    """The acceptance guarantee: streamed serving == in-memory serving, bit
    for bit, for every arch with mixed precision on, across two budgets
    small enough to force chunk-cache eviction."""
    cfg = get_config(f"ample-{arch}", reduced=True)
    g = make_dataset("cora", max_nodes=700, max_feature_dim=cfg.d_model, seed=0)
    ref_eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref = ref_eng.infer(g, g.features)
    assert not ref.streamed
    for frac in (10, 3):
        eng = GNNServeEngine(
            cfg, ref_eng.params,
            feature_budget_bytes=g.features.nbytes // frac,
            feature_chunk_rows=64,
        )
        r = eng.infer(g, g.features)
        assert r.streamed
        np.testing.assert_array_equal(r.outputs, ref.outputs)
        assert r.bytes_streamed > 0
        info = eng.cache_info()
        assert info["streamed_requests"] == 1
        if arch not in ("sage", "gat"):
            # gcn/gin aggregate the store through the chunk cache; the tiny
            # budget must have forced eviction (misses beyond one cold pass).
            # sage's φ (and gat's attention projection) stream chunk-blocked
            # through the FTE instead — no cache, so only bytes_streamed is
            # meaningful there.
            assert info["chunk_misses"] > (700 // 64 + 1)
        # warm repeat stays bitwise too (static per-plan calibration)
        r2 = eng.infer(g, g.features)
        np.testing.assert_array_equal(r2.outputs, ref.outputs)
        assert r2.cache_hit


def test_warm_engine_different_features_bitwise():
    """Static per-plan calibration: a warm engine serves NEW features with
    the FIRST request's activation scale (existing in-memory semantics). The
    streamed int8 stream must quantize under that cached slot scale — not
    the new store's own — or warm different-feature requests silently skew
    by scale_old/scale_new."""
    cfg = get_config("ample-gcn", reduced=True)
    g = make_dataset("cora", max_nodes=500, max_feature_dim=cfg.d_model, seed=0)
    rng = np.random.default_rng(9)
    x2 = (3.0 * rng.standard_normal(g.features.shape)).astype(np.float32)
    assert np.max(np.abs(x2)) != np.max(np.abs(g.features))  # distinct scales
    ref_eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref_eng.infer(g, g.features)  # calibrates the slots on request 1
    ref2 = ref_eng.infer(g, x2)
    eng = GNNServeEngine(
        cfg, ref_eng.params,
        feature_budget_bytes=g.features.nbytes // 4, feature_chunk_rows=64,
    )
    eng.infer(g, g.features)
    r2 = eng.infer(g, x2)
    assert r2.streamed
    np.testing.assert_array_equal(r2.outputs, ref2.outputs)


def test_padded_union_path_reuses_store_across_warm_requests():
    """Size-class padding copies the matrix per request; the store cache
    must key on the caller's original array so warm padded requests skip
    the (chunking + int8 quantization) store build."""
    from repro.memory import feature_store as fs

    cfg = get_config("ample-gcn", reduced=True)
    g = make_dataset("cora", max_nodes=500, max_feature_dim=cfg.d_model, seed=0)
    eng = GNNServeEngine(
        cfg,
        union_node_bucket=512,
        union_edge_bucket=2048,
        feature_budget_bytes=g.features.nbytes // 4,
        feature_chunk_rows=64,
        key=jax.random.PRNGKey(0),
    )
    assert eng.padded_unions
    builds = 0
    orig = fs.FeatureStore.from_array.__func__

    def counting(cls, x, **kw):
        nonlocal builds
        builds += 1
        return orig(cls, x, **kw)

    try:
        fs.FeatureStore.from_array = classmethod(counting)
        ref = eng.infer(g, g.features)
        warm = eng.infer(g, g.features)
    finally:
        fs.FeatureStore.from_array = classmethod(orig)
    assert ref.streamed and warm.streamed
    np.testing.assert_array_equal(warm.outputs, ref.outputs)
    assert builds == 1  # one build, warm request hit the store LRU
    assert len(eng._stores) == 1


def test_served_within_budget_takes_inmemory_path():
    cfg = get_config("ample-gcn", reduced=True)
    g = make_dataset("cora", max_nodes=300, max_feature_dim=cfg.d_model, seed=0)
    eng = GNNServeEngine(
        cfg, feature_budget_bytes=g.features.nbytes * 10, key=jax.random.PRNGKey(0)
    )
    r = eng.infer(g, g.features)
    assert not r.streamed
    assert eng.cache_info()["streamed_requests"] == 0


def test_streaming_telemetry_in_stats():
    cfg = get_config("ample-gcn", reduced=True)
    g = make_dataset("cora", max_nodes=600, max_feature_dim=cfg.d_model, seed=0)
    eng = GNNServeEngine(
        cfg, feature_budget_bytes=g.features.nbytes // 4,
        feature_chunk_rows=64, key=jax.random.PRNGKey(0),
    )
    r = eng.infer(g, g.features)
    info = eng.cache_info()
    assert info["bytes_streamed"] == r.bytes_streamed > 0
    assert 0.0 <= info["chunk_hit_rate"] <= 1.0
    assert 0.0 <= info["prefetch_overlap"] <= 1.0
    assert info["chunk_hits"] + info["chunk_misses"] > 0


def test_streamed_batch_responses_carry_telemetry():
    from repro.serve.gnn_engine import GNNRequest

    cfg = get_config("ample-gcn", reduced=True)
    members = [
        make_dataset("cora", max_nodes=250, max_feature_dim=cfg.d_model, seed=s)
        for s in (0, 1)
    ]
    ref_eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    reqs = [GNNRequest(graph=m, features=m.features) for m in members]
    ref = ref_eng.infer_batch(reqs)
    total = sum(m.features.nbytes for m in members)
    eng = GNNServeEngine(
        cfg, ref_eng.params, feature_budget_bytes=total // 4,
        feature_chunk_rows=64,
    )
    out = eng.infer_batch(reqs)
    for a, b in zip(out, ref):
        assert a.streamed
        np.testing.assert_array_equal(a.outputs, b.outputs)
        # whole-batch telemetry on every member, amortized via the property
        assert a.bytes_streamed_per_member == a.bytes_streamed / len(reqs)
    # per-call union matrices never repeat: the store LRU must stay empty
    # (an id-keyed entry would only pin the dead concatenated matrix)
    assert len(eng._stores) == 0


# --------------------------------------- simulator/measured trend matching
def test_sim_prefetch_trend_matches_measured_hit_rate_trend():
    """Deeper simulated prefetch must not add stall cycles; a bigger
    measured chunk cache must not lower the hit rate — the two monotone
    trends the calibration sweep (bench_prefetch_calibration) reports."""
    from repro.core.simulator import SimConfig, simulate

    g = make_lognormal_graph(2_000, 10.0, seed=1)
    stalls = [
        simulate(g, feature_dim=128, cfg=SimConfig(prefetch_depth=d)).fetch_stall_frac
        for d in (0, 1, 2, 4)
    ]
    assert all(a >= b - 1e-12 for a, b in zip(stalls, stalls[1:]))
    assert stalls[-1] < stalls[0]  # lookahead hides some latency

    feats = np.random.default_rng(0).standard_normal((2_000, 32)).astype(np.float32)
    store = FeatureStore.from_array(feats, chunk_rows=128)
    plan = build_edge_tile_plan(g, edges_per_tile=128)
    schedule = build_chunk_schedule(plan, 128)
    rates = []
    for frac in (8, 4, 2, 1):
        stats = StreamStats()
        ChunkPrefetcher(
            store, schedule, stream="f32",
            budget_bytes=store.nbytes // frac, stats=stats,
        ).aggregate(plan)
        rates.append(stats.hit_rate)
    assert all(a <= b + 1e-12 for a, b in zip(rates, rates[1:]))
    assert rates[-1] > rates[0]


def test_sim_prefetch_depth_zero_is_historical_timing():
    from repro.core.simulator import SimConfig, simulate

    g = make_lognormal_graph(1_500, 8.0, seed=2)
    a = simulate(g, feature_dim=128, cfg=SimConfig())
    b = simulate(g, feature_dim=128, cfg=SimConfig(prefetch_depth=0))
    assert a.cycles == b.cycles


# ------------------------------- warm streamed requests: plan bytes stay home
def test_warm_streamed_aggregate_reuploads_zero_plan_bytes():
    """The instruction stream (per-tile coeff/seg/scatter arrays + lane
    offsets) is plan-static: the cold streamed call uploads it once into the
    engine's device cache; warm calls move feature chunks only."""
    g = _graph(n=500, deg=5.0, seed=2, dim=16)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=64, mixed_precision=True))
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    cold = StreamedFeatures(store, store.nbytes // 4)
    y1 = np.asarray(eng.aggregate(cold, mode="sum"))
    assert cold.stats.instr_bytes > 0
    warm = StreamedFeatures(store, store.nbytes // 4)
    y2 = np.asarray(eng.aggregate(warm, mode="sum"))
    assert warm.stats.instr_bytes == 0  # zero plan bytes re-uploaded
    assert warm.stats.bytes_streamed > 0  # features still stream
    np.testing.assert_array_equal(y1, y2)


def test_warm_streamed_serve_reuploads_zero_plan_bytes():
    """Serve-level regression: a warm streamed request's telemetry shows
    zero instruction-stream bytes (ROADMAP PR-4 follow-on)."""
    cfg = get_config("ample-gcn", reduced=True)
    g = make_dataset("cora", max_nodes=600, max_feature_dim=cfg.d_model, seed=0)
    eng = GNNServeEngine(
        cfg, feature_budget_bytes=g.features.nbytes // 4,
        feature_chunk_rows=64, key=jax.random.PRNGKey(0),
    )
    r1 = eng.infer(g, g.features)
    assert r1.streamed
    assert eng._last_stream.instr_bytes > 0
    r2 = eng.infer(g, g.features)
    assert r2.streamed and r2.cache_hit
    assert eng._last_stream.instr_bytes == 0
    np.testing.assert_array_equal(r1.outputs, r2.outputs)


def test_direct_prefetcher_still_accounts_instr_bytes():
    """Without an engine-owned device tile cache (direct ChunkPrefetcher
    use), per-call plan uploads keep being charged — the accounting only
    moves when the cache actually exists."""
    g = _graph(n=300, deg=4.0, seed=1, dim=8)
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=64)
    schedule = build_chunk_schedule(plan, store.chunk_rows)
    stats = StreamStats()
    pf = ChunkPrefetcher(
        store, schedule, stream="f32",
        budget_bytes=store.chunk_bytes_f32 * 2, stats=stats,
    )
    pf.aggregate(plan).block_until_ready()
    assert stats.instr_bytes > 0


# -------------------------------------- locality packing: pack_tiles_by_chunk
def _row_edge_sequences(plan):
    """Per destination row: real edge ids in accumulation order.

    Accumulation order is the streamed scatter-add's: tiles in plan order,
    lanes in lane order within a tile; a lane's contribution lands on the
    out_node of its segment. Padding lanes (edge_id -1) and sentinel
    segments (out_node == num_nodes) carry no edge.
    """
    rows = np.take_along_axis(plan.out_node, plan.seg_ids, axis=1)
    real = (plan.edge_ids >= 0) & (rows < plan.num_nodes)
    seqs = {}
    for r, e in zip(rows[real].tolist(), plan.edge_ids[real].tolist()):
        seqs.setdefault(r, []).append(e)
    return seqs


def _assert_repack_invariants(g, plan, packed, chunk_rows):
    from repro.core.aggregation import aggregate_edge_tiles, to_device_plan

    # Same edges, same per-row accumulation order — the order-preserving
    # permutation-with-repacking property. (Sequence equality subsumes the
    # per-row edge-multiset equality.)
    assert _row_edge_sequences(packed) == _row_edge_sequences(plan)
    assert packed.total_edges == plan.total_edges
    assert packed.num_nodes == plan.num_nodes
    # And the float semantics agree bitwise, not just structurally.
    x = jnp.asarray(g.features)
    ref = aggregate_edge_tiles(
        x, to_device_plan(plan),
        num_nodes=g.num_nodes, segments_per_tile=plan.segments_per_tile,
    )
    out = aggregate_edge_tiles(
        x, to_device_plan(packed),
        num_nodes=g.num_nodes, segments_per_tile=packed.segments_per_tile,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("seed,deg,ept,chunk_rows", [
    (0, 5.0, 64, 64),
    (1, 12.0, 32, 128),   # hubs overflow tiles -> verbatim multi-tile runs
    (2, 3.0, 16, 32),     # tiny tiles -> many single-segment spans
    (3, 8.0, 128, 64),
])
def test_packed_plan_is_order_preserving_repack(seed, deg, ept, chunk_rows):
    g = _graph(n=400, deg=deg, seed=seed, dim=16)
    plan = build_edge_tile_plan(g, edges_per_tile=ept)
    packed = pack_tiles_by_chunk(plan, chunk_rows)
    _assert_repack_invariants(g, plan, packed, chunk_rows)


@given(
    n=st.integers(8, 60),
    ept=st.sampled_from([8, 16, 32]),
    chunk_rows=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 1_000),
)
def test_packed_plan_property(n, ept, chunk_rows, seed):
    """Randomized repacking property: arbitrary small edge lists (dupes and
    self-loops included), tile widths and chunk sizes."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(n, 6 * n))
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    g = from_edge_list(src, dst, n).with_features(
        rng.standard_normal((n, 8)).astype(np.float32)
    )
    plan = build_edge_tile_plan(g, edges_per_tile=ept)
    packed = pack_tiles_by_chunk(plan, chunk_rows)
    _assert_repack_invariants(g, plan, packed, chunk_rows)


def test_packed_streamed_aggregate_bitwise_direct():
    """Packed plan + unreordered schedule through the prefetcher: bitwise
    equal to the in-memory reference at an eviction-forcing budget."""
    from repro.core.aggregation import aggregate_edge_tiles, to_device_plan

    g = _banded_graph(n=512, k=3, dim=16)
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    ref = aggregate_edge_tiles(
        jnp.asarray(g.features), to_device_plan(plan),
        num_nodes=g.num_nodes, segments_per_tile=plan.segments_per_tile,
    )
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    packed = pack_tiles_by_chunk(plan, 64)
    schedule = build_chunk_schedule(packed, 64, reorder=False)
    stats = StreamStats()
    pf = ChunkPrefetcher(
        store, schedule, stream="f32",
        budget_bytes=3 * store.chunk_bytes_f32, stats=stats,
    )
    out = pf.aggregate(packed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert stats.bytes_streamed > 0


# ------------------------------------- async staging: measured, not inferred
def test_async_staging_measures_wall_clock():
    g = _banded_graph(n=1024, k=2)
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    schedule = build_chunk_schedule(plan, 64)
    stats = StreamStats()
    pf = ChunkPrefetcher(
        store, schedule, stream="f32",
        budget_bytes=4 * store.chunk_bytes_f32, prefetch_depth=2, stats=stats,
    )
    out = pf.aggregate(plan)
    out.block_until_ready()
    assert stats.prefetched > 0
    assert stats.copy_ms > 0.0  # copies were actually timed
    assert stats.stall_ms >= 0.0
    assert 0.0 <= stats.prefetch_overlap <= 1.0


def test_sync_path_reports_zero_overlap():
    """prefetch_depth=0 (or async_stage off) is the untimed historical path:
    overlap must read 0, never a flattering inferred number."""
    g = _banded_graph(n=256, k=2)
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=32)
    schedule = build_chunk_schedule(plan, 64)
    for kw in ({"prefetch_depth": 0}, {"prefetch_depth": 2, "async_stage": False}):
        stats = StreamStats()
        ChunkPrefetcher(
            store, schedule, stream="f32",
            budget_bytes=4 * store.chunk_bytes_f32, stats=stats, **kw,
        ).aggregate(plan)
        assert stats.copy_ms == 0.0
        assert stats.prefetch_overlap == 0.0


def test_async_and_sync_staging_bitwise_identical():
    """Staging changes WHEN copies happen, never WHAT the device computes."""
    g = _graph(n=500, deg=6.0, seed=7, dim=16)
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=64)
    schedule = build_chunk_schedule(plan, 64)

    def run(**kw):
        pf = ChunkPrefetcher(
            store, schedule, stream="f32",
            budget_bytes=3 * store.chunk_bytes_f32, stats=StreamStats(), **kw,
        )
        return np.asarray(pf.aggregate(plan))

    ref = run(prefetch_depth=0)
    np.testing.assert_array_equal(run(prefetch_depth=2, async_stage=True), ref)
    np.testing.assert_array_equal(run(prefetch_depth=2, async_stage=False), ref)
    np.testing.assert_array_equal(run(prefetch_depth=4, async_stage=True), ref)


def test_sparse_residue_bitwise_and_counted():
    """Uniform-neighbour graph at a 2-slot budget: most chunk visits lose
    the Belady comparison and must be served as sparse row residue — still
    bitwise, with the rows counted."""
    from repro.core.aggregation import aggregate_edge_tiles, to_device_plan

    g = _graph(n=800, deg=8.0, seed=11, dim=16)
    store = FeatureStore.from_array(g.features, chunk_rows=64)
    plan = build_edge_tile_plan(g, edges_per_tile=64)
    schedule = build_chunk_schedule(plan, 64)
    ref = aggregate_edge_tiles(
        jnp.asarray(g.features), to_device_plan(plan),
        num_nodes=g.num_nodes, segments_per_tile=plan.segments_per_tile,
    )
    stats = StreamStats()
    pf = ChunkPrefetcher(
        store, schedule, stream="f32",
        budget_bytes=2 * store.chunk_bytes_f32, stats=stats,
    )
    out = pf.aggregate(plan)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert stats.sparse_rows > 0
    # sparse rows are charged to bytes_streamed but cost far less than
    # serving every miss as a full chunk upload would
    assert stats.bytes_streamed < stats.chunk_misses * store.chunk_bytes_f32


# --------------------------------------- serve-level knobs and new telemetry
@pytest.mark.parametrize("arch", ["gcn", "gin", "sage", "gat"])
def test_served_packed_stream_bitwise_identical(arch):
    """Engine-level acceptance for the packing mode: streamed == in-memory,
    bit for bit, with gnn_stream_packing on AND off, every arch, at an
    eviction-forcing budget."""
    cfg = get_config(f"ample-{arch}", reduced=True)
    g = make_dataset("cora", max_nodes=600, max_feature_dim=cfg.d_model, seed=0)
    ref_eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref = ref_eng.infer(g, g.features)
    for packing in (False, True):
        eng = GNNServeEngine(
            cfg, ref_eng.params,
            feature_budget_bytes=g.features.nbytes // 4,
            feature_chunk_rows=64, stream_packing=packing,
        )
        r = eng.infer(g, g.features)
        assert r.streamed
        np.testing.assert_array_equal(r.outputs, ref.outputs)


def test_stream_knobs_threaded_from_config():
    """gnn_stream_packing / gnn_stream_reorder flow config -> engine, with
    constructor kwargs overriding — the reorder/pack A/B needs no hand-built
    prefetchers."""
    import dataclasses

    base = get_config("ample-gcn", reduced=True)
    cfg = dataclasses.replace(
        base, gnn_stream_packing=True, gnn_stream_reorder=False
    )
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    assert eng.stream_packing is True and eng.stream_reorder is False
    eng2 = GNNServeEngine(
        cfg, stream_packing=False, stream_reorder=True,
        key=jax.random.PRNGKey(0),
    )
    assert eng2.stream_packing is False and eng2.stream_reorder is True
    # defaults match the historical behaviour
    eng3 = GNNServeEngine(base, key=jax.random.PRNGKey(0))
    assert eng3.stream_packing is False and eng3.stream_reorder is True


def test_reorder_control_arm_served_bitwise():
    """reorder=False (the control arm) must serve identical bytes too."""
    cfg = get_config("ample-gcn", reduced=True)
    g = make_dataset("cora", max_nodes=500, max_feature_dim=cfg.d_model, seed=0)
    ref_eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref = ref_eng.infer(g, g.features)
    eng = GNNServeEngine(
        cfg, ref_eng.params,
        feature_budget_bytes=g.features.nbytes // 4,
        feature_chunk_rows=64, stream_reorder=False,
    )
    r = eng.infer(g, g.features)
    assert r.streamed
    np.testing.assert_array_equal(r.outputs, ref.outputs)


def test_response_and_cache_info_carry_stall_copy_ms():
    cfg = get_config("ample-gcn", reduced=True)
    g = make_dataset("cora", max_nodes=600, max_feature_dim=cfg.d_model, seed=0)
    eng = GNNServeEngine(
        cfg, feature_budget_bytes=g.features.nbytes // 4,
        feature_chunk_rows=64, key=jax.random.PRNGKey(0),
    )
    r = eng.infer(g, g.features)
    assert r.streamed
    assert r.copy_ms > 0.0  # async staging is the serve default (depth 2)
    assert r.stall_ms >= 0.0
    info = eng.cache_info()
    assert info["copy_ms"] == pytest.approx(eng.stats["copy_ms"])
    assert info["stall_ms"] == pytest.approx(eng.stats["stall_ms"])
    assert 0.0 <= info["prefetch_overlap"] <= 1.0
