"""Degrade gracefully when ``hypothesis`` is not installed.

Test modules import ``given`` / ``settings`` / ``st`` from here instead of
from ``hypothesis`` directly. With hypothesis present these are the real
objects (and the hypothesis pytest plugin applies its own ``hypothesis``
marker). Without it, ``given`` turns each property test into a skipped,
``hypothesis``-marked test — so the tier-1 suite still collects and runs the
example-based subset in offline environments.

Select / deselect the property subset explicitly with::

    pytest -m hypothesis        # property tests only
    pytest -m "not hypothesis"  # offline-safe subset
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction; never draws (tests are skipped)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.hypothesis(
                pytest.mark.skip(reason="hypothesis not installed")(fn)
            )

        return deco

    def settings(*_args, **_kwargs):  # @settings(...) becomes a no-op
        return lambda fn: fn

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    class HealthCheck:  # attribute access only (conftest profile)
        too_slow = None
        data_too_large = None
