"""Quantization (Eq. 5), STE, Degree-Quant masks, Eq. 6 allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, st

from repro.core.degree_quant import (
    DegreeQuantConfig,
    allocate_nodeslots,
    inference_precision_tags,
    protection_probabilities,
    sample_protection_mask,
)
from repro.core.quantization import (
    compute_scale_zp,
    dequantize,
    fake_quant,
    quantize,
    quantize_per_channel,
)
from repro.graphs.datasets import make_lognormal_graph


@given(
    scale=st.floats(0.01, 10.0),
    seed=st.integers(0, 1000),
    symmetric=st.booleans(),
)
def test_quant_dequant_error_bounded(scale, seed, symmetric):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32) * scale)
    qp = compute_scale_zp(x, symmetric=symmetric)
    xq = quantize(x, qp)
    xhat = dequantize(xq, qp)
    # max error is half a quantization step (plus float slop)
    step = float(np.max(np.asarray(qp.scale)))
    assert float(jnp.abs(x - xhat).max()) <= 0.5 * step * 1.01 + 1e-6


def test_per_channel_beats_per_tensor():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 8)).astype(np.float32)
    w[:, 3] *= 100.0  # one outlier channel ruins per-tensor resolution
    w = jnp.asarray(w)
    keep = jnp.asarray([c for c in range(8) if c != 3])
    wq_pc, qp_pc = quantize_per_channel(w, axis=-1)
    # error on the *well-behaved* channels: per-channel scales are immune to
    # the outlier channel, per-tensor resolution is ruined by it
    err_pc = float(jnp.abs(dequantize(wq_pc, qp_pc) - w)[:, keep].max())
    qp_pt = compute_scale_zp(w, symmetric=True)
    err_pt = float(jnp.abs(dequantize(quantize(w, qp_pt), qp_pt) - w)[:, keep].max())
    assert err_pc < 0.25 * err_pt


def test_fake_quant_ste_gradient():
    x = jnp.linspace(-3.0, 3.0, 61)
    qp = compute_scale_zp(jnp.asarray([-1.0, 1.0]), symmetric=True)  # clips at ±1

    def f(x):
        return jnp.sum(fake_quant(x, qp) ** 2)

    g = jax.grad(f)(x)
    inside = jnp.abs(x / qp.scale) <= 127
    # gradient flows inside the representable range, zero outside
    assert bool(jnp.all(g[~inside] == 0.0))
    assert bool(jnp.any(g[inside] != 0.0))


def test_protection_probability_monotone_in_degree():
    g = make_lognormal_graph(300, 6.0, seed=5)
    p = protection_probabilities(g, DegreeQuantConfig(p_min=0.0, p_max=0.2))
    deg = g.degrees
    order = np.argsort(deg)
    ps = p[order]
    assert (np.diff(ps[np.argsort(deg[order], kind="stable")]) >= -1e-7).all()
    assert p.min() >= 0.0 and p.max() <= 0.2 + 1e-7


def test_sample_protection_mask_rate():
    g = make_lognormal_graph(5000, 6.0, seed=6)
    cfg = DegreeQuantConfig(p_min=0.1, p_max=0.1)  # uniform 10%
    rng = np.random.default_rng(0)
    mask = sample_protection_mask(g, cfg, rng)
    assert abs(mask.mean() - 0.1) < 0.02


@given(ratio=st.floats(0.001, 0.2))
def test_inference_tags_ratio(ratio):
    g = make_lognormal_graph(1000, 5.0, seed=7)
    tags = inference_precision_tags(g, DegreeQuantConfig(float_ratio=ratio))
    got = (tags == "float").mean()
    assert abs(got - ratio) <= 1.0 / 1000 + 1e-9


def test_eq6_nodeslot_allocation():
    # Eq. 6: N_p = ceil(min_r R^max_r / C_r); float is ~10x costlier → few slots
    budget = {
        "float": {"LUT": 1000, "DSP": 40},
        "int8": {"LUT": 9000, "DSP": 360},
    }
    cost = {
        "float": {"LUT": 900, "DSP": 35},
        "int8": {"LUT": 150, "DSP": 6},
    }
    slots = allocate_nodeslots(budget, cost)
    assert slots["float"] == 2  # ceil(min(1000/900, 40/35)) = ceil(1.11) = 2
    assert slots["int8"] == 60  # ceil(min(60, 60)) = 60
