"""Runtime edge coefficients: the edge_ids indirection and its invariants.

The tentpole contract: per-edge coefficients are no longer baked into compiled
plans — tile plans carry a structural ``edge_ids`` map (int32[T, E], -1 on
padding lanes) and a runtime vector scatters through it at request time.
Under test:

  * ``edge_ids`` relabelling invariants — a solo plan's valid lanes are a
    permutation of the graph's edge set; ``concat_tile_plans`` relabels member
    edge ids into a permutation of the union's member edges; shard plans slice
    a permutation of the global edge set; padding lanes are -1 everywhere.
  * Scatter equivalence — a random coefficient vector scattered through a
    union plan equals the member-sliced vectors scattered through each member
    plan (bitwise, per member block).
  * Losslessness — runtime-coeff GCN is **bitwise identical** to static-coeff
    GCN when fed the precomputed ``aggregation_coefficients`` vector (the
    acceptance criterion proving the indirection refactor changes nothing).
  * ``edge_softmax`` — the tile-driven destination-segment softmax matches a
    dense per-destination softmax, single-plan and sharded.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, st

from repro.core.aggregation import tile_edge_coeff, to_device_plan
from repro.core.message_passing import (
    AmpleEngine,
    EngineConfig,
    aggregation_coefficients,
    assemble_union_plan,
    compile_plans,
    compile_sharded_plans,
)
from repro.core.scheduler import build_edge_tile_plan, concat_tile_plans
from repro.distributed.graph_shard import ShardedAmpleEngine
from repro.graphs import disjoint_union
from repro.graphs.csr import add_self_loops
from repro.graphs.datasets import make_dataset, make_lognormal_graph


def _valid_edge_ids(plan) -> np.ndarray:
    """Edge ids on valid lanes (coeff != 0), flattened."""
    return plan.edge_ids[plan.coeff != 0]


# ----------------------------------------------------- edge_ids invariants
@given(
    n=st.integers(2, 80),
    md=st.floats(1.0, 10.0),
    ept=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 1000),
)
def test_edge_ids_are_edge_permutation(n, md, ept, seed):
    g = make_lognormal_graph(n, md, seed=seed)
    plan = build_edge_tile_plan(g, edges_per_tile=ept)
    valid = _valid_edge_ids(plan)
    assert sorted(valid.tolist()) == list(range(g.num_edges))
    # padding lanes are exactly the coeff-0 lanes, and carry -1
    assert (plan.edge_ids[plan.coeff == 0] == -1).all()
    # each lane's edge id names the edge whose source the lane gathers
    t, e = plan.gather_idx.shape
    for ti in range(t):
        sel = plan.edge_ids[ti] >= 0
        np.testing.assert_array_equal(
            g.indices[plan.edge_ids[ti][sel]], plan.gather_idx[ti][sel]
        )


@given(seed=st.integers(0, 200), min_tiles=st.sampled_from([0, 16]))
def test_concat_edge_ids_permute_member_edges(seed, min_tiles):
    """Union relabelling invariant: valid union lanes are a permutation of
    the members' (offset) edge sets; padding lanes stay -1."""
    a = make_lognormal_graph(30, 4.0, seed=seed)
    b = make_lognormal_graph(20, 3.0, seed=seed + 1)
    pa = build_edge_tile_plan(a, edges_per_tile=32)
    pb = build_edge_tile_plan(b, edges_per_tile=32)
    cat = concat_tile_plans(
        [pa, pb],
        [0, a.num_nodes],
        num_nodes=a.num_nodes + b.num_nodes,
        min_tiles=min_tiles,
        edge_offsets=[0, a.num_edges],
    )
    valid = _valid_edge_ids(cat)
    assert sorted(valid.tolist()) == list(range(a.num_edges + b.num_edges))
    assert (cat.edge_ids[cat.coeff == 0] == -1).all()


def test_concat_without_edge_offsets_opts_out():
    a = make_lognormal_graph(20, 3.0, seed=0)
    pa = build_edge_tile_plan(a, edges_per_tile=32)
    cat = concat_tile_plans([pa], [0], num_nodes=a.num_nodes)
    assert (cat.edge_ids == -1).all()


@pytest.mark.parametrize("num_shards", [2, 3])
def test_shard_plans_slice_edge_permutation(num_shards):
    """Shard slicing invariant: each shard's local edge ids + its edge_range
    offset tile the global edge set exactly once across shards."""
    g = make_lognormal_graph(120, 5.0, seed=7)
    splan = compile_sharded_plans(
        g, EngineConfig(edges_per_tile=32), num_shards=num_shards,
        modes=("runtime",),
    )
    global_ids = []
    for sp in splan.shards:
        e_lo, e_hi = sp.shard.edge_range
        local = np.concatenate(
            [
                _valid_edge_ids(p)
                for p in sp.plan.mode_plans["runtime"].values()
            ]
        )
        assert sorted(local.tolist()) == list(range(e_hi - e_lo))
        global_ids.append(local + e_lo)
    got = np.sort(np.concatenate(global_ids))
    np.testing.assert_array_equal(got, np.arange(g.num_edges))


def test_union_scatter_equals_member_scatter():
    """Scattering a random vector through the assembled union plan equals
    scattering member slices through each member plan — bitwise, both at the
    tile level and through the aggregation output blocks."""
    members = [make_lognormal_graph(25 + 7 * s, 4.0, seed=s) for s in range(3)]
    cfg = EngineConfig(edges_per_tile=32, mixed_precision=False)
    plans = [compile_plans(m, cfg, modes=("runtime",)) for m in members]
    union = disjoint_union(list(members), pad_num_nodes=96)
    uplan = assemble_union_plan(plans, union, cfg=cfg, edge_bucket=256)

    rng = np.random.default_rng(0)
    c = rng.uniform(0.5, 2.0, union.num_edges).astype(np.float32)
    # tile-level: every valid union lane reads c[edge id]; padding reads 0
    up = uplan.mode_plans["runtime"]["float"]
    scattered = np.asarray(tile_edge_coeff(to_device_plan(up), jnp.asarray(c)))
    expect = np.where(up.edge_ids >= 0, c[np.clip(up.edge_ids, 0, None)], 0.0)
    np.testing.assert_array_equal(scattered, expect)

    # block-level: union aggregate == member aggregates, bitwise per block
    dim = 8
    xs = [
        rng.standard_normal((m.num_nodes, dim)).astype(np.float32)
        for m in members
    ]
    x_u = np.concatenate(
        xs + [np.zeros((union.num_nodes - sum(m.num_nodes for m in members), dim),
                       np.float32)]
    )
    u_eng = AmpleEngine(union, plan=uplan)
    y_u = np.asarray(
        u_eng.aggregate(jnp.asarray(x_u), mode="runtime", edge_coeff=jnp.asarray(c))
    )
    e_off = 0
    n_off = 0
    for m, p, x in zip(members, plans, xs):
        eng = AmpleEngine(m, plan=p)
        y = np.asarray(
            eng.aggregate(
                jnp.asarray(x), mode="runtime",
                edge_coeff=jnp.asarray(c[e_off : e_off + m.num_edges]),
            )
        )
        np.testing.assert_array_equal(y_u[n_off : n_off + m.num_nodes], y)
        e_off += m.num_edges
        n_off += m.num_nodes
    # padding rows stay exactly zero
    assert (y_u[n_off:] == 0).all()


# ------------------------------------------------ losslessness (acceptance)
@pytest.mark.parametrize("mixed", [False, True])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_runtime_gcn_bitwise_equals_static_gcn(mixed, use_kernel):
    """Acceptance: feeding the precomputed GCN normalisation vector through
    the runtime path reproduces the static-coeff plan bit for bit (plans in
    both modes pack identically; 1.0 * c == c in f32)."""
    g = add_self_loops(make_dataset("citeseer", max_nodes=150, max_feature_dim=16, seed=3))
    eng = AmpleEngine(
        g,
        EngineConfig(
            edges_per_tile=64, mixed_precision=mixed, use_kernel=use_kernel
        ),
    )
    x = jnp.asarray(g.features)
    c = jnp.asarray(aggregation_coefficients(g, "gcn"))
    y_static = np.asarray(eng.aggregate(x, mode="gcn"))
    y_runtime = np.asarray(eng.aggregate(x, mode="runtime", edge_coeff=c))
    np.testing.assert_array_equal(y_static, y_runtime)


def test_runtime_gcn_bitwise_sharded():
    g = add_self_loops(make_dataset("citeseer", max_nodes=150, max_feature_dim=16, seed=3))
    x = jnp.asarray(g.features)
    c = jnp.asarray(aggregation_coefficients(g, "gcn"))
    splan = compile_sharded_plans(
        g, EngineConfig(edges_per_tile=64), num_shards=2,
        modes=("gcn", "runtime"),
    )
    eng = ShardedAmpleEngine(g, splan)
    y_static = np.asarray(eng.aggregate(x, mode="gcn"))
    y_runtime = np.asarray(eng.aggregate(x, mode="runtime", edge_coeff=c))
    np.testing.assert_array_equal(y_static, y_runtime)


def test_edge_coeff_shape_validated():
    g = make_lognormal_graph(40, 3.0, seed=1)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=32))
    x = jnp.zeros((g.num_nodes, 4), jnp.float32)
    with pytest.raises(ValueError, match="edge_coeff must be"):
        eng.aggregate(x, mode="runtime", edge_coeff=jnp.zeros(3))


# ------------------------------------------------- multi-head [E, H] layout
@given(h=st.sampled_from([1, 2, 4]), seed=st.integers(0, 300))
def test_head_vectorized_softmax_bitwise_per_head(h, seed):
    """Acceptance: the [E, H] jnp softmax is bitwise-equal per head to the
    per-head 1-D loop it replaced (every pass is elementwise-independent
    across the head axis)."""
    g = make_lognormal_graph(50, 4.0, seed=seed)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=32, mixed_precision=False))
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(
        rng.standard_normal((g.num_edges, h)).astype(np.float32)
    )
    vec = np.asarray(eng.edge_softmax(scores))
    assert vec.shape == (g.num_edges, h)
    for head in range(h):
        solo = np.asarray(eng.edge_softmax(scores[:, head]))
        np.testing.assert_array_equal(vec[:, head], solo)


def test_head_vectorized_softmax_bitwise_smoke():
    """Deterministic pin of the hypothesis property above (which skips when
    hypothesis is unavailable)."""
    g = make_lognormal_graph(50, 4.0, seed=7)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=32, mixed_precision=False))
    rng = np.random.default_rng(7)
    scores = jnp.asarray(rng.standard_normal((g.num_edges, 4)).astype(np.float32))
    vec = np.asarray(eng.edge_softmax(scores))
    for head in range(4):
        np.testing.assert_array_equal(
            vec[:, head], np.asarray(eng.edge_softmax(scores[:, head]))
        )


def test_multihead_aggregate_bitwise_per_head():
    """[E, H] coefficients with [N, H, dh] embeddings: one tile scan, each
    head's slice bitwise-equal to its solo 1-D aggregate."""
    g = make_lognormal_graph(60, 4.0, seed=5)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=32, mixed_precision=False))
    rng = np.random.default_rng(2)
    h, dh = 4, 6
    x = jnp.asarray(rng.standard_normal((g.num_nodes, h, dh)).astype(np.float32))
    c = jnp.asarray(rng.uniform(0.1, 1.0, (g.num_edges, h)).astype(np.float32))
    y = np.asarray(eng.aggregate(x, mode="runtime", edge_coeff=c))
    assert y.shape == (g.num_nodes, h, dh)
    for head in range(h):
        solo = np.asarray(
            eng.aggregate(
                x[:, head, :], mode="runtime", edge_coeff=c[:, head]
            )
        )
        np.testing.assert_array_equal(y[:, head], solo)


def test_multihead_shape_mismatch_rejected():
    g = make_lognormal_graph(30, 3.0, seed=0)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=32, mixed_precision=False))
    with pytest.raises(ValueError, match="multi-head edge_coeff"):
        eng.aggregate(
            jnp.zeros((g.num_nodes, 4)),
            mode="runtime",
            edge_coeff=jnp.ones((g.num_edges, 2)),
        )
    z = jnp.zeros((g.num_nodes, 2, 4))
    with pytest.raises(ValueError, match="scores must be"):
        eng.attention_aggregate(jnp.zeros((g.num_edges,)), z)
    with pytest.raises(ValueError, match="z must be"):
        eng.attention_aggregate(jnp.zeros((g.num_edges, 3)), z)


# --------------------------------------------------- fused attention kernel
@pytest.mark.parametrize("mixed", [False, True])
def test_attention_aggregate_fused_matches_oracle(mixed):
    """The single-launch fused kernel vs the vectorized jnp decomposition
    (LeakyReLU → softmax → aggregate) — same engine config, kernel toggled."""
    g = add_self_loops(
        make_dataset("citeseer", max_nodes=120, max_feature_dim=16, seed=3)
    )
    rng = np.random.default_rng(0)
    h, dh = 2, 8
    z = jnp.asarray(rng.standard_normal((g.num_nodes, h, dh)).astype(np.float32))
    scores = jnp.asarray(
        rng.standard_normal((g.num_edges, h)).astype(np.float32)
    )
    oracle = AmpleEngine(
        g, EngineConfig(edges_per_tile=64, mixed_precision=mixed)
    )
    fused = AmpleEngine(
        g,
        EngineConfig(edges_per_tile=64, mixed_precision=mixed, use_kernel=True),
    )
    y0 = np.asarray(oracle.attention_aggregate(scores, z))
    y1 = np.asarray(fused.attention_aggregate(scores, z))
    assert np.isfinite(y1).all()
    np.testing.assert_allclose(y1, y0, atol=1e-5, rtol=1e-5)


def test_attention_aggregate_fused_union_plan():
    """Fused attention over an assembled padded-union plan matches the jnp
    oracle on the same union; padding rows stay exactly zero."""
    members = [make_lognormal_graph(25 + 7 * s, 4.0, seed=s) for s in range(3)]
    union = disjoint_union(list(members), pad_num_nodes=96)
    rng = np.random.default_rng(3)
    h, dh = 2, 5
    z = jnp.asarray(
        rng.standard_normal((union.num_nodes, h, dh)).astype(np.float32)
    )
    sc = jnp.asarray(
        rng.standard_normal((union.num_edges, h)).astype(np.float32)
    )
    ys = {}
    for uk in (False, True):
        cfg = EngineConfig(
            edges_per_tile=32, mixed_precision=False, use_kernel=uk
        )
        plans = [compile_plans(m, cfg, modes=("runtime",)) for m in members]
        uplan = assemble_union_plan(plans, union, cfg=cfg, edge_bucket=256)
        eng = AmpleEngine(union, plan=uplan)
        ys[uk] = np.asarray(eng.attention_aggregate(sc, z))
    np.testing.assert_allclose(ys[True], ys[False], atol=1e-5, rtol=1e-5)
    n_real = sum(m.num_nodes for m in members)
    assert (ys[True][n_real:] == 0).all()


def test_attention_aggregate_sharded_matches_solo():
    """Sharded K=2 attention (per-shard [E, H] passes) vs the single-plan
    engine — same numerics up to float accumulation order."""
    g = make_lognormal_graph(120, 5.0, seed=4)
    rng = np.random.default_rng(5)
    h, dh = 4, 4
    z = jnp.asarray(rng.standard_normal((g.num_nodes, h, dh)).astype(np.float32))
    sc = jnp.asarray(rng.standard_normal((g.num_edges, h)).astype(np.float32))
    solo = AmpleEngine(g, EngineConfig(edges_per_tile=32))
    splan = compile_sharded_plans(
        g, EngineConfig(edges_per_tile=32), num_shards=2, modes=("runtime",)
    )
    sharded = ShardedAmpleEngine(g, splan)
    np.testing.assert_allclose(
        np.asarray(sharded.attention_aggregate(sc, z)),
        np.asarray(solo.attention_aggregate(sc, z)),
        atol=1e-5,
        rtol=1e-5,
    )


def test_edge_softmax_multihead_sharded_matches_unsharded():
    g = make_lognormal_graph(120, 5.0, seed=4)
    rng = np.random.default_rng(1)
    scores = jnp.asarray(
        rng.standard_normal((g.num_edges, 3)).astype(np.float32)
    )
    solo = AmpleEngine(g, EngineConfig(edges_per_tile=32))
    splan = compile_sharded_plans(
        g, EngineConfig(edges_per_tile=32), num_shards=3, modes=("runtime",)
    )
    sharded = ShardedAmpleEngine(g, splan)
    np.testing.assert_allclose(
        np.asarray(solo.edge_softmax(scores)),
        np.asarray(sharded.edge_softmax(scores)),
        atol=1e-6,
        rtol=1e-6,
    )


# ------------------------------------------------------------ edge_softmax
def _dense_edge_softmax(g, scores):
    """Per-destination softmax over the CSR edge list (oracle)."""
    out = np.zeros_like(scores)
    for i in range(g.num_nodes):
        lo, hi = int(g.indptr[i]), int(g.indptr[i + 1])
        if lo == hi:
            continue
        s = scores[lo:hi].astype(np.float64)
        e = np.exp(s - s.max())
        out[lo:hi] = (e / e.sum()).astype(np.float32)
    return out


@pytest.mark.parametrize("mixed", [False, True])
def test_edge_softmax_matches_dense(mixed):
    g = make_lognormal_graph(100, 5.0, seed=2)
    eng = AmpleEngine(g, EngineConfig(edges_per_tile=32, mixed_precision=mixed))
    rng = np.random.default_rng(0)
    scores = rng.standard_normal(g.num_edges).astype(np.float32)
    alpha = np.asarray(eng.edge_softmax(jnp.asarray(scores)))
    ref = _dense_edge_softmax(g, scores)
    np.testing.assert_allclose(alpha, ref, atol=1e-5, rtol=1e-5)
    # softmax sums to 1 per destination with in-edges
    deg = g.degrees
    sums = np.add.reduceat(alpha, g.indptr[:-1][deg > 0])
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)


def test_edge_softmax_sharded_matches_unsharded():
    g = make_lognormal_graph(120, 5.0, seed=4)
    rng = np.random.default_rng(1)
    scores = jnp.asarray(rng.standard_normal(g.num_edges).astype(np.float32))
    solo = AmpleEngine(g, EngineConfig(edges_per_tile=32))
    splan = compile_sharded_plans(
        g, EngineConfig(edges_per_tile=32), num_shards=3, modes=("runtime",)
    )
    sharded = ShardedAmpleEngine(g, splan)
    np.testing.assert_allclose(
        np.asarray(solo.edge_softmax(scores)),
        np.asarray(sharded.edge_softmax(scores)),
        atol=1e-6, rtol=1e-6,
    )


def test_runtime_coeff_rejects_plans_without_edge_ids():
    """Plans persisted before the indirection load with all-(-1) edge_ids;
    scattering through them would silently zero every coefficient — the
    engine must refuse loudly instead."""
    import dataclasses as dc

    g = make_lognormal_graph(40, 3.0, seed=1)
    plan = compile_plans(
        g, EngineConfig(edges_per_tile=32, mixed_precision=False),
        modes=("runtime",),
    )
    stripped = {
        m: {
            t: dc.replace(p, edge_ids=np.full_like(p.edge_ids, -1))
            for t, p in tp.items()
        }
        for m, tp in plan.mode_plans.items()
    }
    old = dc.replace(plan, mode_plans=stripped)
    eng = AmpleEngine(g, plan=old)
    x = jnp.zeros((g.num_nodes, 4), jnp.float32)
    with pytest.raises(ValueError, match="edge-id indirection"):
        eng.aggregate(x, mode="runtime", edge_coeff=jnp.ones(g.num_edges))
    with pytest.raises(ValueError, match="edge-id indirection"):
        eng.edge_softmax(jnp.zeros(g.num_edges))
    # static-coeff serving of the same plan keeps working
    assert np.asarray(eng.aggregate(x, mode="runtime")).shape == x.shape


def test_runtime_coeff_rejects_partially_legacy_union():
    """A union assembled from one pre-indirection (all -1) member and one
    fresh member must be refused too — the legacy member's lanes would be
    silently zeroed while the check saw live ids on the fresh member."""
    import dataclasses as dc

    a = make_lognormal_graph(25, 3.0, seed=0)
    b = make_lognormal_graph(20, 3.0, seed=1)
    cfg = EngineConfig(edges_per_tile=32, mixed_precision=False)
    pa = compile_plans(a, cfg, modes=("runtime",))
    pb = compile_plans(b, cfg, modes=("runtime",))
    stripped = dc.replace(
        pa,
        mode_plans={
            m: {
                t: dc.replace(p, edge_ids=np.full_like(p.edge_ids, -1))
                for t, p in tp.items()
            }
            for m, tp in pa.mode_plans.items()
        },
    )
    union = disjoint_union([a, b])
    uplan = assemble_union_plan([stripped, pb], union, cfg=cfg)
    eng = AmpleEngine(union, plan=uplan)
    x = jnp.zeros((union.num_nodes, 4), jnp.float32)
    with pytest.raises(ValueError, match="edge-id indirection"):
        eng.aggregate(x, mode="runtime", edge_coeff=jnp.ones(union.num_edges))
