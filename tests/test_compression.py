"""Gradient compression: bounded error, error feedback, convergence kept."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.distributed.compression import (
    Int8Compressor,
    TopKCompressor,
    wire_bytes_ratio,
)


def _tree(seed, shapes=((64, 32), (128,))):
    rng = np.random.default_rng(seed)
    return {
        f"w{i}": jnp.asarray(rng.standard_normal(s).astype(np.float32))
        for i, s in enumerate(shapes)
    }


@given(seed=st.integers(0, 100), ratio=st.sampled_from([0.01, 0.1, 0.5]))
@settings(max_examples=10)
def test_topk_keeps_largest(seed, ratio):
    g = _tree(seed)
    comp = TopKCompressor(ratio=ratio)
    out, err = comp.compress_decompress(g, None)
    for k in g:
        o = np.asarray(out[k]).ravel()
        orig = np.asarray(g[k]).ravel()
        nnz = (o != 0).sum()
        kk = max(1, int(orig.size * ratio))
        assert nnz <= orig.size  # ties may exceed k slightly; sanity only
        # kept entries are exactly the original values
        np.testing.assert_allclose(o[o != 0], orig[o != 0], rtol=1e-6)
        # error feedback holds the dropped mass
        np.testing.assert_allclose(
            o + np.asarray(err[k]).ravel().reshape(o.shape), orig, rtol=1e-5
        )


def test_error_feedback_recovers_dropped_mass():
    """With a CONSTANT gradient, EF guarantees the average transmitted
    gradient converges to the true one."""
    g = {"w": jnp.ones((100,)) * jnp.asarray([1.0] * 5 + [0.01] * 95)}
    comp = TopKCompressor(ratio=0.05)
    state = None
    total = np.zeros(100)
    n = 50
    for _ in range(n):
        out, state = comp.compress_decompress(g, state)
        total += np.asarray(out["w"])
    np.testing.assert_allclose(total / n, np.asarray(g["w"]), atol=0.01)


@given(seed=st.integers(0, 100))
@settings(max_examples=10)
def test_int8_bounded_error_and_unbiased(seed):
    g = _tree(seed)
    comp = Int8Compressor(seed=seed)
    out, err = comp.compress_decompress(g, None)
    for k in g:
        orig = np.asarray(g[k])
        scale = np.abs(orig).max() / 127.0
        assert np.abs(np.asarray(out[k]) - orig).max() <= scale * 1.01
        np.testing.assert_allclose(
            np.asarray(out[k]) + np.asarray(err[k]), orig, atol=1e-5
        )


def test_compressed_training_converges():
    """20 steps with top-k(10%) + EF reaches a loss close to uncompressed."""
    from repro.configs.base import get_config
    from repro.data.pipeline import synthetic_batch
    from repro.models.api import model_init
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("smollm-360m", reduced=True)

    def train(compressor):
        params = model_init(cfg, jax.random.PRNGKey(0))
        step = jax.jit(
            make_train_step(
                cfg, AdamWConfig(lr=3e-3, weight_decay=0.0),
                total_steps=40, warmup=2, compressor=compressor,
            )
        )
        state = init_train_state(cfg, params)
        if compressor is not None:
            state["compress"] = compressor.init_state(params)
        losses = []
        for i in range(20):
            b = {k: jnp.asarray(v) for k, v in synthetic_batch(
                seed=7, step=i, batch=4, seq=32, vocab=cfg.vocab_size).items()}
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        return losses

    base = train(None)
    comp = train(TopKCompressor(ratio=0.1))
    # compressed run must still learn (within 0.35 nats of uncompressed tail)
    assert np.mean(comp[-5:]) < np.mean(comp[:5])
    assert abs(np.mean(comp[-5:]) - np.mean(base[-5:])) < 0.35


def test_wire_ratios():
    assert wire_bytes_ratio(TopKCompressor(ratio=0.01)) == pytest.approx(0.02)
    assert wire_bytes_ratio(Int8Compressor()) == 0.25
