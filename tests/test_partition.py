"""graphs/partition.py invariants — the substrate under sharded execution.

Covers the contract the sharded planner relies on: shards are a disjoint
contiguous cover, edge counts are balanced on skewed power-law graphs up to
the cut granularity (one node's degree), halos are exactly the remote
neighbours, and degenerate shapes (more shards than nodes, empty graphs)
stay well-formed.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.graphs import (
    Partition,
    halo_nodes,
    make_lognormal_graph,
    partition_by_edges,
    shard_edge_counts,
    shard_subgraph,
    validate,
    validate_partition,
)
from repro.graphs.csr import Graph, from_edge_list


def _power_law_graph(n=400, seed=0):
    """Heavy-tailed in-degrees: a few hub rows own a large share of the edges."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, 8 * n)
    # Pareto-ranked destinations: low ids soak up most incoming edges (hubs)
    dst = (rng.pareto(1.2, 8 * n) * 2).astype(np.int64) % n
    return from_edge_list(src, dst, n, name="powerlaw")


@pytest.mark.parametrize("num_shards", [1, 2, 4, 7])
def test_shards_cover_nodes_exactly_once(num_shards):
    g = _power_law_graph(seed=1)
    part = partition_by_edges(g, num_shards)
    validate_partition(g, part)
    seen = np.zeros(g.num_nodes, np.int64)
    for k in range(part.num_shards):
        lo, hi = part.nodes(k)
        seen[lo:hi] += 1
    assert (seen == 1).all()
    for v in [0, g.num_nodes // 2, g.num_nodes - 1]:
        k = part.shard_of(v)
        lo, hi = part.nodes(k)
        assert lo <= v < hi


@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_edge_balance_on_skewed_graph(num_shards):
    """Contiguous edge-balanced cuts are off by at most the boundary node."""
    g = _power_law_graph(n=600, seed=2)
    assert g.degrees.max() > 4 * g.degrees.mean()  # the premise: skew exists
    part = partition_by_edges(g, num_shards)
    counts = shard_edge_counts(g, part)
    assert counts.sum() == g.num_edges
    ideal = g.num_edges / num_shards
    slack = int(g.degrees.max())  # cut granularity: one node's edges
    assert counts.max() <= ideal + slack + 1
    assert counts.min() >= max(ideal - num_shards * slack, 0) - 1


def test_halo_is_exactly_remote_neighbors():
    g = _power_law_graph(n=300, seed=3)
    part = partition_by_edges(g, 5)
    for k in range(5):
        lo, hi = part.nodes(k)
        halo = halo_nodes(g, part, k)
        want = set()
        for i in range(lo, hi):
            want.update(int(j) for j in g.neighbors(i) if j < lo or j >= hi)
        assert set(halo.tolist()) == want
        assert (np.diff(halo) > 0).all()  # sorted unique, the subgraph contract


def test_more_shards_than_nodes():
    g = make_lognormal_graph(5, 2.0, seed=4)
    part = partition_by_edges(g, 12)
    validate_partition(g, part)
    assert part.num_shards == 12
    counts = shard_edge_counts(g, part)
    assert counts.sum() == g.num_edges
    covered = sum(hi - lo for lo, hi in (part.nodes(k) for k in range(12)))
    assert covered == g.num_nodes
    for k in range(12):  # empty shards have empty halos and valid subgraphs
        sub = shard_subgraph(g, part, k)
        validate(sub.graph)


def test_empty_graph_partition():
    g = Graph(indptr=np.zeros(1, np.int64), indices=np.zeros(0, np.int32), num_nodes=0)
    part = partition_by_edges(g, 3)
    validate_partition(g, part)
    assert shard_edge_counts(g, part).sum() == 0
    for k in range(3):
        assert halo_nodes(g, part, k).size == 0
        sub = shard_subgraph(g, part, k)
        assert sub.num_owned == 0 and sub.num_local == 0
        validate(sub.graph)


def test_partition_validation_rejects_bad_covers():
    g = make_lognormal_graph(20, 3.0, seed=5)
    with pytest.raises(ValueError, match="span"):
        validate_partition(g, Partition(starts=np.asarray([0, 10, 19])))
    with pytest.raises(ValueError, match="span"):
        validate_partition(g, Partition(starts=np.asarray([1, 10, 20])))
    with pytest.raises(ValueError, match="monotone"):
        validate_partition(g, Partition(starts=np.asarray([0, 15, 10, 20])))
    with pytest.raises(ValueError):
        partition_by_edges(g, 0)


def test_shard_subgraph_local_structure():
    """Local subgraphs preserve edge order and re-index owned + halo rows."""
    g = _power_law_graph(n=250, seed=6)
    part = partition_by_edges(g, 4)
    for k in range(4):
        sub = shard_subgraph(g, part, k)
        validate(sub.graph)
        lo, hi = sub.lo, sub.hi
        # owned rows first, then halo; local_ids maps back to global ids
        assert (sub.local_ids[: sub.num_owned] == np.arange(lo, hi)).all()
        assert (sub.local_ids[sub.num_owned :] == sub.halo).all()
        # halo rows are sources only: no in-edges in the local graph
        assert (np.diff(sub.graph.indptr[sub.num_owned :]) == 0).all()
        # edge slice alignment: local edges == global edges, remapped
        e_lo, e_hi = sub.edge_range
        global_src = g.indices[e_lo:e_hi]
        local_src = sub.local_ids[sub.graph.indices]
        assert (local_src == global_src).all()


# ---------------------------------------------------------------------------
# Min-cut (multilevel) partitioner
# ---------------------------------------------------------------------------
from repro.graphs import (  # noqa: E402  (section-local imports keep diffs small)
    make_clustered_graph,
    make_partition,
    partition_cut_edges,
    partition_halo_volume,
    partition_min_cut,
)


@pytest.mark.parametrize("num_shards", [2, 4])
def test_min_cut_is_exact_cover(num_shards):
    g = _power_law_graph(n=300, seed=7)
    part = partition_min_cut(g, num_shards)
    validate_partition(g, part)
    seen = np.zeros(g.num_nodes, np.int64)
    for k in range(part.num_shards):
        owned = part.owned(k)
        seen[owned] += 1
        # owner_of must agree with block membership
        assert (part.owner_of(owned) == k).all()
    assert (seen == 1).all()


@pytest.mark.parametrize("num_shards", [2, 4])
def test_min_cut_respects_edge_balance(num_shards):
    g = _power_law_graph(n=500, seed=8)
    part = partition_min_cut(g, num_shards, balance=1.25)
    counts = shard_edge_counts(g, part)
    assert counts.sum() == g.num_edges
    assert counts.max() <= 1.25 * g.num_edges / num_shards + g.degrees.max()


@pytest.mark.parametrize("num_shards", [2, 4])
def test_min_cut_beats_contiguous_on_clustered_graph(num_shards):
    """Shuffled planted communities: contiguous ranges cut nearly every
    intra-cluster edge; the multilevel partitioner recovers the clusters."""
    g = make_clustered_graph(800, 8, seed=9, shuffle=True, inter_degree=0.5)
    base = partition_by_edges(g, num_shards)
    part = partition_min_cut(g, num_shards)
    assert partition_cut_edges(g, part) < 0.75 * partition_cut_edges(g, base)
    assert partition_halo_volume(g, part) < 0.75 * partition_halo_volume(g, base)


def test_min_cut_deterministic_in_seed():
    g = make_clustered_graph(400, 4, seed=10)
    a = partition_min_cut(g, 4, seed=3)
    b = partition_min_cut(g, 4, seed=3)
    assert (a.starts == b.starts).all()
    assert a.kind == b.kind
    if a.order is not None:
        assert (a.order == b.order).all()


def test_make_partition_dispatch_and_inline_params():
    g = make_clustered_graph(300, 2, seed=11)
    assert make_partition(g, 2, "edges").kind == "edges"
    p = make_partition(g, 2, "mincut", seed=5, balance=1.1, refine_passes=2)
    assert p.kind == "mincut(seed=5,balance=1.1,passes=2)"
    # the kind string round-trips through make_partition (fingerprint replay)
    q = make_partition(g, 2, p.kind)
    assert q.kind == p.kind
    assert (q.starts == p.starts).all()
    if p.order is not None:
        assert (q.order == p.order).all()
    with pytest.raises(ValueError, match="unknown partitioner"):
        make_partition(g, 2, "zoltan")


@pytest.mark.parametrize("num_shards", [2, 4])
def test_min_cut_shard_subgraph_invariants(num_shards):
    """Non-contiguous shards: edge_idx must realign local edges to global
    CSR positions and slice_edges must be the matching per-edge gather."""
    g = make_clustered_graph(350, num_shards, seed=12)
    part = partition_min_cut(g, num_shards)
    validate_partition(g, part)
    edge_ids = np.arange(g.num_edges, dtype=np.int64)
    covered = np.zeros(g.num_edges, np.int64)
    for k in range(num_shards):
        sub = shard_subgraph(g, part, k)
        validate(sub.graph)
        assert (sub.local_ids[: sub.num_owned] == part.owned(k)).all()
        if sub.edge_idx is not None:
            covered[sub.edge_idx] += 1
            assert (sub.slice_edges(edge_ids) == sub.edge_idx).all()
            src_global = sub.local_ids[sub.graph.indices[: sub.num_edges]]
            assert (src_global == g.indices[sub.edge_idx]).all()
        else:
            e_lo, e_hi = sub.edge_range
            covered[e_lo:e_hi] += 1
        # halo rows have no in-edges
        assert (np.diff(sub.graph.indptr[sub.num_owned :]) == 0).all()
    assert (covered == 1).all()
