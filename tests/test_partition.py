"""graphs/partition.py invariants — the substrate under sharded execution.

Covers the contract the sharded planner relies on: shards are a disjoint
contiguous cover, edge counts are balanced on skewed power-law graphs up to
the cut granularity (one node's degree), halos are exactly the remote
neighbours, and degenerate shapes (more shards than nodes, empty graphs)
stay well-formed.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.graphs import (
    Partition,
    halo_nodes,
    make_lognormal_graph,
    partition_by_edges,
    shard_edge_counts,
    shard_subgraph,
    validate,
    validate_partition,
)
from repro.graphs.csr import Graph, from_edge_list


def _power_law_graph(n=400, seed=0):
    """Heavy-tailed in-degrees: a few hub rows own a large share of the edges."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, 8 * n)
    # Pareto-ranked destinations: low ids soak up most incoming edges (hubs)
    dst = (rng.pareto(1.2, 8 * n) * 2).astype(np.int64) % n
    return from_edge_list(src, dst, n, name="powerlaw")


@pytest.mark.parametrize("num_shards", [1, 2, 4, 7])
def test_shards_cover_nodes_exactly_once(num_shards):
    g = _power_law_graph(seed=1)
    part = partition_by_edges(g, num_shards)
    validate_partition(g, part)
    seen = np.zeros(g.num_nodes, np.int64)
    for k in range(part.num_shards):
        lo, hi = part.nodes(k)
        seen[lo:hi] += 1
    assert (seen == 1).all()
    for v in [0, g.num_nodes // 2, g.num_nodes - 1]:
        k = part.shard_of(v)
        lo, hi = part.nodes(k)
        assert lo <= v < hi


@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_edge_balance_on_skewed_graph(num_shards):
    """Contiguous edge-balanced cuts are off by at most the boundary node."""
    g = _power_law_graph(n=600, seed=2)
    assert g.degrees.max() > 4 * g.degrees.mean()  # the premise: skew exists
    part = partition_by_edges(g, num_shards)
    counts = shard_edge_counts(g, part)
    assert counts.sum() == g.num_edges
    ideal = g.num_edges / num_shards
    slack = int(g.degrees.max())  # cut granularity: one node's edges
    assert counts.max() <= ideal + slack + 1
    assert counts.min() >= max(ideal - num_shards * slack, 0) - 1


def test_halo_is_exactly_remote_neighbors():
    g = _power_law_graph(n=300, seed=3)
    part = partition_by_edges(g, 5)
    for k in range(5):
        lo, hi = part.nodes(k)
        halo = halo_nodes(g, part, k)
        want = set()
        for i in range(lo, hi):
            want.update(int(j) for j in g.neighbors(i) if j < lo or j >= hi)
        assert set(halo.tolist()) == want
        assert (np.diff(halo) > 0).all()  # sorted unique, the subgraph contract


def test_more_shards_than_nodes():
    g = make_lognormal_graph(5, 2.0, seed=4)
    part = partition_by_edges(g, 12)
    validate_partition(g, part)
    assert part.num_shards == 12
    counts = shard_edge_counts(g, part)
    assert counts.sum() == g.num_edges
    covered = sum(hi - lo for lo, hi in (part.nodes(k) for k in range(12)))
    assert covered == g.num_nodes
    for k in range(12):  # empty shards have empty halos and valid subgraphs
        sub = shard_subgraph(g, part, k)
        validate(sub.graph)


def test_empty_graph_partition():
    g = Graph(indptr=np.zeros(1, np.int64), indices=np.zeros(0, np.int32), num_nodes=0)
    part = partition_by_edges(g, 3)
    validate_partition(g, part)
    assert shard_edge_counts(g, part).sum() == 0
    for k in range(3):
        assert halo_nodes(g, part, k).size == 0
        sub = shard_subgraph(g, part, k)
        assert sub.num_owned == 0 and sub.num_local == 0
        validate(sub.graph)


def test_partition_validation_rejects_bad_covers():
    g = make_lognormal_graph(20, 3.0, seed=5)
    with pytest.raises(ValueError, match="span"):
        validate_partition(g, Partition(starts=np.asarray([0, 10, 19])))
    with pytest.raises(ValueError, match="span"):
        validate_partition(g, Partition(starts=np.asarray([1, 10, 20])))
    with pytest.raises(ValueError, match="monotone"):
        validate_partition(g, Partition(starts=np.asarray([0, 15, 10, 20])))
    with pytest.raises(ValueError):
        partition_by_edges(g, 0)


def test_shard_subgraph_local_structure():
    """Local subgraphs preserve edge order and re-index owned + halo rows."""
    g = _power_law_graph(n=250, seed=6)
    part = partition_by_edges(g, 4)
    for k in range(4):
        sub = shard_subgraph(g, part, k)
        validate(sub.graph)
        lo, hi = sub.lo, sub.hi
        # owned rows first, then halo; local_ids maps back to global ids
        assert (sub.local_ids[: sub.num_owned] == np.arange(lo, hi)).all()
        assert (sub.local_ids[sub.num_owned :] == sub.halo).all()
        # halo rows are sources only: no in-edges in the local graph
        assert (np.diff(sub.graph.indptr[sub.num_owned :]) == 0).all()
        # edge slice alignment: local edges == global edges, remapped
        e_lo, e_hi = sub.edge_range
        global_src = g.indices[e_lo:e_hi]
        local_src = sub.local_ids[sub.graph.indices]
        assert (local_src == global_src).all()
