"""Per-architecture smoke tests: REDUCED config of each assigned arch runs one
forward + one train step + one decode step on CPU (shapes + finiteness)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.data.pipeline import synthetic_batch
from repro.models.api import (
    loss_fn,
    model_decode_step,
    model_forward,
    model_init,
    model_init_cache,
)
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

# token-model archs only; the GNN family is covered by test_model_api_gnn.py
ARCHS = [a for a in list_configs() if get_config(a).family != "gnn"]

B, S = 2, 16


def _batch(cfg):
    b = synthetic_batch(
        seed=0, step=0, batch=B, seq=S, vocab=cfg.vocab_size,
        family=cfg.family, d_model=cfg.d_model,
    )
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, reduced=True)
            params = model_init(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = _batch(cfg)
    logits, aux = model_forward(params, cfg, batch)
    assert logits.shape[:2] == (B, S)
    assert logits.shape[-1] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = _batch(cfg)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), total_steps=10, warmup=1)
    state = init_train_state(cfg, params)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss not finite"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
        for a, b in zip(
            jax.tree_util.tree_leaves(new_state["params"]),
            jax.tree_util.tree_leaves(state["params"]),
        )
    )
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_decode_step(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = _batch(cfg)
    cache = model_init_cache(cfg, params, batch, max_len=S + 4)
    tok = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if cfg.family == "vlm":  # nonzero embeds so written K/V differ from zeros
        tok = {"embeds": jax.random.normal(jax.random.PRNGKey(9), (B, 1, cfg.d_model))}
    logits, cache2 = model_decode_step(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape[0] == B and logits.shape[-1] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode logits not finite"
    # cache must have been written (some leaf changed)
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(
            jax.tree_util.tree_leaves(cache2), jax.tree_util.tree_leaves(cache)
        )
    )
    assert changed, f"{arch}: decode step did not write the cache"


def test_loss_decreases_briefly():
    """20 steps of the smallest arch on the synthetic task must reduce loss."""
    cfg = get_config("smollm-360m", reduced=True)
    params = model_init(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, weight_decay=0.0),
                                   total_steps=30, warmup=2))
    state = init_train_state(cfg, params)
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(
            seed=7, step=i, batch=4, seq=32, vocab=cfg.vocab_size).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
