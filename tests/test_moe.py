"""MoE dispatch properties: conservation, capacity, grouping, sharded path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.lm.mlp import mlp_apply
from repro.models.lm.moe import moe_apply, moe_init


@given(
    e=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
    t=st.sampled_from([8, 32]),
    seed=st.integers(0, 100),
)
@settings(max_examples=8, deadline=None)
def test_no_drop_equals_per_token_reference(e, k, t, seed):
    d, f = 16, 32
    p = moe_init(jax.random.PRNGKey(seed), d, f, e, "swiglu", dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, t, d))
    out, _ = moe_apply(p, x, num_experts=e, top_k=k, kind="swiglu",
                       capacity_factor=float(e))  # no drops possible
    xf = x.reshape(t, d)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / w.sum(-1, keepdims=True)
    ref = np.zeros((t, d), np.float32)
    for tt in range(t):
        for j in range(k):
            ep = jax.tree.map(lambda a: a[idx[tt, j]], p["experts"])
            ref[tt] += float(w[tt, j]) * np.asarray(
                mlp_apply(ep, xf[tt : tt + 1], "swiglu")
            )[0]
    np.testing.assert_allclose(np.asarray(out).reshape(t, d), ref, atol=1e-4)


def test_capacity_drops_monotone():
    """Lower capacity factor can only drop more tokens (output shrinks)."""
    e, k, t, d, f = 8, 2, 64, 16, 32
    p = moe_init(jax.random.PRNGKey(0), d, f, e, "swiglu", dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, t, d))
    norms = []
    for cf in [0.25, 1.0, 8.0]:
        out, aux, stats = moe_apply(
            p, x, num_experts=e, top_k=k, kind="swiglu",
            capacity_factor=cf, return_stats=True,
        )
        norms.append((cf, float(jnp.abs(out).sum()), float(stats["dropped_fraction"])))
    assert norms[0][2] >= norms[1][2] >= norms[2][2]
    assert norms[2][2] == 0.0  # ample capacity drops nothing


def test_stats_expert_load_conserved():
    e, k, t, d, f = 4, 2, 32, 8, 16
    p = moe_init(jax.random.PRNGKey(2), d, f, e, "swiglu", dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, d))
    _, _, stats = moe_apply(p, x, num_experts=e, top_k=k, kind="swiglu",
                            capacity_factor=4.0, return_stats=True)
    assert int(stats["expert_load"].sum()) == t * k


def test_grouped_dispatch_matches_global_when_balanced():
    """G groups with per-group capacity == global dispatch when no drops."""

    class FakePolicy:
        def moe_groups(self, t):
            return 4

        def ebuf(self, x):
            return x

        def ebuf_out(self, y):
            return y

        mesh = None
        mode = "none"  # sharded path not applicable

    e, k, d, f = 4, 2, 8, 16
    p = moe_init(jax.random.PRNGKey(4), d, f, e, "swiglu", dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 16, d))
    ref, _ = moe_apply(p, x, num_experts=e, top_k=k, kind="swiglu",
                       capacity_factor=16.0)
    out, _ = moe_apply(p, x, num_experts=e, top_k=k, kind="swiglu",
                       capacity_factor=16.0, policy=FakePolicy())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
