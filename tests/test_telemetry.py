"""Streaming telemetry: histogram quantiles vs the numpy oracle.

The contract under test: ``StreamingHistogram`` holds O(1) memory yet reads
back any percentile within its configured relative error of the exact sample
quantile (numpy is the oracle), with exact min/max/mean/count riding along;
``TenantTelemetry`` rolls per-tenant counters, SLO accounting and throughput
deterministically (time is injectable).
"""
from __future__ import annotations

import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serve.telemetry import StreamingHistogram, TenantTelemetry


def _oracle_tolerance(hist, true_value):
    """|estimate - oracle| bound: one bucket width at the oracle's scale."""
    return 2.0 * hist.rel_error * abs(true_value) + 1e-9


# ---------------------------------------------------- histogram vs numpy
@pytest.mark.parametrize(
    "name,samples",
    [
        ("uniform", np.linspace(0.5, 500.0, 2_000)),
        ("lognormal", np.exp(np.random.default_rng(0).normal(2.0, 1.0, 5_000))),
        ("exponential", np.random.default_rng(1).exponential(30.0, 3_000)),
        ("bimodal", np.concatenate([
            np.random.default_rng(2).normal(5.0, 0.5, 1_500).clip(0.1),
            np.random.default_rng(3).normal(800.0, 40.0, 1_500),
        ])),
        ("constant", np.full(100, 42.0)),
        ("tiny", np.array([7.0, 3.0, 11.0])),
    ],
)
@pytest.mark.parametrize("q", [0, 25, 50, 90, 99, 100])
def test_percentile_matches_numpy_oracle(name, samples, q):
    """Every quantile of every shape of distribution reads back within the
    histogram's relative-error budget of the exact rank statistic."""
    hist = StreamingHistogram()
    for v in samples:
        hist.record(v)
    want = float(np.percentile(samples, q, method="lower"))
    got = hist.percentile(q)
    assert abs(got - want) <= _oracle_tolerance(hist, want)


def test_exact_stats_ride_along():
    samples = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
    hist = StreamingHistogram()
    for v in samples:
        hist.record(v)
    assert hist.count == len(samples)
    assert hist.min == 1.0 and hist.max == 9.0
    assert hist.mean == pytest.approx(np.mean(samples))
    # extremes are exact, not bucket-approximate
    assert hist.percentile(0) == 1.0
    assert hist.percentile(100) == 9.0


def test_out_of_range_values_clamp_into_end_buckets():
    hist = StreamingHistogram(low=1.0, high=100.0)
    for v in (1e-6, 0.5, 50.0, 1e6):
        hist.record(v)
    assert hist.count == 4
    assert hist.min == 1e-6 and hist.max == 1e6  # exact despite clamping
    assert hist.percentile(0) == 1e-6
    assert hist.percentile(100) == 1e6
    # interior quantiles stay inside the observed range
    for q in (25, 50, 75):
        assert hist.min <= hist.percentile(q) <= hist.max


def test_empty_and_invalid_inputs():
    hist = StreamingHistogram()
    assert hist.percentile(50) == 0.0
    assert hist.mean == 0.0
    snap = hist.snapshot()
    assert snap["count"] == 0 and snap["min"] == 0.0 and snap["max"] == 0.0
    with pytest.raises(ValueError):
        hist.record(math.nan)
    with pytest.raises(ValueError):
        hist.percentile(101)
    with pytest.raises(ValueError):
        StreamingHistogram(low=10.0, high=1.0)
    with pytest.raises(ValueError):
        StreamingHistogram(rel_error=0.0)


def test_snapshot_keys():
    hist = StreamingHistogram()
    hist.record(10.0)
    snap = hist.snapshot()
    assert set(snap) == {"count", "mean", "min", "max", "p50", "p90", "p99"}
    assert snap["count"] == 1 and snap["p50"] == pytest.approx(10.0, rel=0.06)


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.floats(min_value=1e-3, max_value=1e6, allow_nan=False,
                  allow_infinity=False),
        min_size=1, max_size=300,
    ),
    st.integers(min_value=0, max_value=100),
)
def test_percentile_error_is_bounded_property(samples, q):
    """Property: for ANY sample list in range, the histogram quantile is
    within one bucket width of numpy's rank statistic."""
    hist = StreamingHistogram()
    for v in samples:
        hist.record(v)
    want = float(np.percentile(samples, q, method="lower"))
    got = hist.percentile(q)
    assert abs(got - want) <= _oracle_tolerance(hist, want)
    assert hist.min <= got <= hist.max


# -------------------------------------------------------- tenant rollups
def test_slo_accounting_and_counters():
    tel = TenantTelemetry()
    assert tel.record_completion("t", latency_ms=40.0, slo_ms=50.0) is True
    assert tel.record_completion("t", latency_ms=60.0, slo_ms=50.0) is False
    assert tel.record_completion("t", latency_ms=999.0) is True  # no SLO set
    tel.record_rejected("t")
    tel.record_preempted("t")
    tel.record_failure("t")
    snap = tel.tenant_snapshot("t")
    assert snap["completed"] == 3
    assert snap["slo_hits"] == 1 and snap["slo_violations"] == 1
    assert snap["slo_hit_rate"] == pytest.approx(0.5)
    assert snap["rejected"] == 1 and snap["preempted"] == 1
    assert snap["failed"] == 1


def test_throughput_is_deterministic_with_injected_time():
    tel = TenantTelemetry()
    tel.record_submitted("t", now=100.0)
    for i in range(8):
        tel.record_completion(
            "t", latency_ms=10.0, nodes=50, now=100.0 + (i + 1)
        )
    snap = tel.tenant_snapshot("t")
    assert snap["throughput_rps"] == pytest.approx(1.0)  # 8 done over 8s
    assert snap["node_throughput"] == pytest.approx(50.0)
    assert snap["completed_nodes"] == 400


def test_snapshot_includes_idle_tenants_from_queue_depths():
    tel = TenantTelemetry()
    tel.record_completion("busy", latency_ms=5.0)
    snap = tel.snapshot({"idle": 3, "busy": 1})
    assert set(snap) == {"busy", "idle"}
    assert snap["idle"]["completed"] == 0 and snap["idle"]["queue_depth"] == 3
    assert snap["busy"]["queue_depth"] == 1
    assert "idle" in tel and "never-seen" not in tel


def test_queue_wait_histogram_is_separate_from_latency():
    tel = TenantTelemetry()
    tel.record_completion("t", latency_ms=100.0, queue_ms=30.0)
    snap = tel.tenant_snapshot("t")
    assert snap["latency_ms"]["p50"] == pytest.approx(100.0, rel=0.06)
    assert snap["queue_wait_ms"]["p50"] == pytest.approx(30.0, rel=0.06)
