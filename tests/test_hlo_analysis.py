"""HLO collective parser: shape-byte math, loop multipliers, ring costs."""
from __future__ import annotations

import pytest

from repro.launch.hlo_analysis import (
    CollectiveStats,
    _shape_bytes,
    _split_computations,
    _trip_count,
    analyze_collectives,
)


def test_shape_bytes():
    assert _shape_bytes("bf16[2,3,4]") == 48
    assert _shape_bytes("f32[128]") == 512
    assert _shape_bytes("(f32[2,2], bf16[4])") == 24  # tuple shapes sum
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("badtype[10]") == 0


FAKE_HLO = """\
HloModule test

%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %gte = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %gte2 = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%gte2), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[8]) tuple(%gte2, %ar)
}

ENTRY %main (x: f32[16]) -> f32[16] {
  %ag = f32[16]{0} all-gather(%x), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[16] add(%ag, %ag)
}
"""


def test_loop_multiplier_and_kinds():
    stats = analyze_collectives(FAKE_HLO, ring_size=4)
    # all-gather in entry: once, 64 bytes; all-reduce in loop body: 5 × 32B
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 64
    assert stats.count_by_kind["all-reduce"] == 5
    assert stats.bytes_by_kind["all-reduce"] == 5 * 32
    # ring wire: AG 64*(3/4) + AR 2*160*(3/4)
    assert stats.wire_bytes == pytest.approx(64 * 0.75 + 2 * 160 * 0.75)


def test_split_computations_finds_entry():
    comps = _split_computations(FAKE_HLO)
    assert comps["__entry__"] == "main"
    assert "cond.1" in comps and "body.1" in comps


def test_trip_count_from_condition():
    comps = _split_computations(FAKE_HLO)
    assert _trip_count(comps["cond.1"]) == 5
