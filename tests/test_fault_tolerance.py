"""Fault tolerance: checkpoint atomicity, crash/resume bit-exactness, elastic."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.distributed.elastic import elastic_plan, rebalance_batch
from repro.optim.adamw import AdamWConfig
from repro.train.loop import Trainer, TrainerConfig


@pytest.fixture()
def small_trainer(tmp_path):
    cfg = get_config("smollm-360m", reduced=True)

    def make(ckpt_dir=None, steps=12, **kw):
        t = TrainerConfig(
            steps=steps, batch=2, seq=16, ckpt_dir=ckpt_dir, ckpt_every=5,
            log_every=1, opt=AdamWConfig(lr=1e-3), **kw,
        )
        return Trainer(cfg, t)

    return make, tmp_path


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
    }
    ckpt.save(state, str(tmp_path), step=3)
    assert ckpt.latest_step(str(tmp_path)) == 3
    restored = ckpt.restore(str(tmp_path), state)
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        an, bn = np.asarray(a), np.asarray(b)
        assert an.dtype == bn.dtype  # bf16 survives the roundtrip as bf16
        np.testing.assert_array_equal(
            an.astype(np.float32), bn.astype(np.float32)
        )


def test_checkpoint_retention_and_latest(tmp_path):
    state = {"x": jnp.zeros((2,))}
    for s in [1, 2, 3, 4]:
        ckpt.save(state, str(tmp_path), step=s, keep=2)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [3, 4]


def test_no_corrupt_checkpoint_on_partial_write(tmp_path):
    """A .tmp dir (simulated mid-crash write) must be invisible to restore."""
    state = {"x": jnp.arange(4.0)}
    ckpt.save(state, str(tmp_path), step=1)
    os.makedirs(tmp_path / "step_000000002.tmp")  # crashed write
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored = ckpt.restore(str(tmp_path), state)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(4.0))


def test_crash_resume_bit_exact(small_trainer):
    """Train 12 steps straight vs crash-at-7 + resume: identical params."""
    make, tmp = small_trainer
    straight = make(steps=12).run()

    d = str(tmp / "ckpt")
    with pytest.raises(RuntimeError, match="injected fault"):
        make(ckpt_dir=d, steps=12).run(crash_at=7)
    # the deterministic (seed, step) data contract makes resume exact
    resumed = make(ckpt_dir=d, steps=12).run()

    for a, b in zip(
        jax.tree_util.tree_leaves(straight["state"]["params"]),
        jax.tree_util.tree_leaves(resumed["state"]["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(small_trainer):
    make, tmp = small_trainer
    d = str(tmp / "async")
    make(ckpt_dir=d, steps=10, ckpt_async=True).run()
    assert ckpt.latest_step(d) == 10


# ----------------------------------------------------------------- elastic
def test_elastic_plan_preserves_global_batch():
    for alive in [512, 496, 384, 272, 96, 16]:
        plan = elastic_plan(alive_chips=alive, model_parallel=16, global_batch=256)
        assert plan.model_parallel == 16
        assert plan.chips_used <= alive
        assert plan.data_parallel * plan.per_shard_batch * plan.grad_accum == 256


def test_elastic_plan_fails_below_one_tp_group():
    with pytest.raises(RuntimeError, match="cannot continue"):
        elastic_plan(alive_chips=15, model_parallel=16, global_batch=256)


def test_rebalance_batch_exact_and_monotone():
    out = rebalance_batch(100, [1.0, 1.0, 2.0])
    assert sum(out) == 100
    assert out[2] >= out[0]
    out = rebalance_batch(7, [1.0, 3.0])
    assert sum(out) == 7 and out[1] > out[0]


def test_elastic_restore_onto_smaller_state(tmp_path):
    """Checkpoint written by a run can be restored and continued (resharding
    is a device_put against new shardings; here structure round-trips)."""
    cfg = get_config("qwen2-1.5b", reduced=True)
    t = TrainerConfig(steps=4, batch=2, seq=8, ckpt_dir=str(tmp_path), ckpt_every=2)
    tr = Trainer(cfg, t)
    out = tr.run()
    state2 = ckpt.restore(str(tmp_path), out["state"])
    assert int(state2["step"]) == 4
