"""GAT end-to-end: runtime attention coefficients on every serving path.

Acceptance under test: GAT outputs match the dense JAX reference (per-arch
tolerance; int8 flips accounted like sage) on sync serving, async
padded-union serving and the sharded path (K ∈ {1, 2}); and warm GAT traffic
has exactly GCN's plan-cache economics — plans are structure-keyed, so the
per-request attention coefficients never touch the planner (``plan_ms == 0``,
no planner calls after the cold request).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import AmpleEngine
from repro.graphs import make_dataset
from repro.models.gnn import api as gnn_api
from repro.serve.async_gnn import AsyncGNNEngine
from repro.serve.gnn_engine import GNNRequest, GNNServeEngine


def _cfg(*, precision="mixed", heads=2):
    return dataclasses.replace(
        get_config("ample-gat", reduced=True),
        d_model=24, d_ff=16, vocab_size=8, gnn_precision=precision,
        gnn_edges_per_tile=64, gnn_heads=heads,
    )


@pytest.fixture(scope="module")
def graph():
    return make_dataset("citeseer", max_nodes=150, max_feature_dim=24, seed=3)


@pytest.fixture(scope="module")
def pool():
    return [
        make_dataset("cora", max_nodes=n, max_feature_dim=24, seed=s)
        for n, s in [(60, 1), (45, 2), (75, 3)]
    ]


def _rel(y, yref):
    return np.abs(y - yref).max() / (np.abs(yref).max() + 1e-9)


# ----------------------------------------------------------- model numerics
@pytest.mark.parametrize("heads", [1, 2, 4])
def test_gat_matches_reference_float(graph, heads):
    cfg = _cfg(precision="float", heads=heads)
    params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(graph.features)
    prepared = gnn_api.prepare_graph(cfg, graph)
    eng = AmpleEngine(prepared, gnn_api.engine_config(cfg))
    y = gnn_api.gnn_apply(cfg, params, eng, x)
    yref = gnn_api.gnn_reference(cfg, params, graph, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=5e-4, rtol=1e-3)


def test_gat_mixed_precision_bounded_error(graph):
    cfg = _cfg()
    params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(1))
    x = jnp.asarray(graph.features)
    prepared = gnn_api.prepare_graph(cfg, graph)
    eng = AmpleEngine(prepared, gnn_api.engine_config(cfg))
    y = np.asarray(gnn_api.gnn_apply(cfg, params, eng, x))
    yref = np.asarray(gnn_api.gnn_reference(cfg, params, graph, x))
    assert _rel(y, yref) < 0.08, f"int8 mixed-precision rel err {_rel(y, yref)}"
    assert np.isfinite(y).all()


# ----------------------------------------------------- fused kernel parity
@pytest.mark.parametrize("heads", [1, 2, 4])
@pytest.mark.parametrize("precision", ["float", "mixed"])
def test_gat_fused_kernel_matches_reference(graph, heads, precision):
    """One fused Pallas launch per layer (gnn_use_kernel=True) vs both the
    dense reference (per-arch tolerance) and the always-on [E, H] jnp oracle
    (tight — same softmax decomposition, different association)."""
    cfg = dataclasses.replace(
        _cfg(precision=precision, heads=heads), gnn_use_kernel=True
    )
    params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(graph.features)
    prepared = gnn_api.prepare_graph(cfg, graph)
    eng = AmpleEngine(prepared, gnn_api.engine_config(cfg))
    y = np.asarray(gnn_api.gnn_apply(cfg, params, eng, x))
    yref = np.asarray(gnn_api.gnn_reference(cfg, params, graph, x))
    assert np.isfinite(y).all()
    if precision == "float":
        np.testing.assert_allclose(y, yref, atol=5e-4, rtol=1e-3)
    else:
        assert _rel(y, yref) < 0.08, f"fused int8 rel err {_rel(y, yref)}"
    jcfg = dataclasses.replace(cfg, gnn_use_kernel=False)
    jeng = AmpleEngine(prepared, gnn_api.engine_config(jcfg))
    yj = np.asarray(gnn_api.gnn_apply(jcfg, params, jeng, x))
    np.testing.assert_allclose(y, yj, atol=5e-5, rtol=1e-4)


def test_gat_use_kernel_refuses_streaming(graph):
    """Satellite: use_kernel + out-of-core streaming must fail loudly with
    both flags named, not silently fall back to the jnp path."""
    cfg = dataclasses.replace(_cfg(), gnn_use_kernel=True)
    with pytest.raises(
        ValueError, match="feature_budget_bytes and use_kernel"
    ):
        GNNServeEngine(
            cfg, key=jax.random.PRNGKey(0), feature_budget_bytes=1024
        )


def test_gat_sharded_multihead_matches_unsharded(graph):
    cfg = _cfg(heads=4)
    solo = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    y1 = solo.infer(graph, graph.features).outputs
    sharded = GNNServeEngine(cfg, solo.params, num_shards=2)
    y2 = sharded.infer(graph, graph.features).outputs
    np.testing.assert_allclose(y1, y2, atol=5e-5, rtol=1e-4)


def test_gat_heads_must_divide_hidden():
    cfg = dataclasses.replace(_cfg(), gnn_heads=5)  # d_ff=16 not divisible
    with pytest.raises(ValueError, match="divisible"):
        gnn_api.gnn_init(cfg, jax.random.PRNGKey(0))


def test_registry_has_gat():
    assert "gat" in gnn_api.list_archs()
    spec = gnn_api.get_arch("gat")
    assert spec.default_agg == "runtime"
    assert spec.needs_self_loops


# ------------------------------------------------------------ sync serving
def test_gat_served_sync_matches_reference_and_caches(graph):
    cfg = _cfg()
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    cold = eng.infer(graph, graph.features)
    yref = np.asarray(
        gnn_api.gnn_reference(cfg, eng.params, graph, jnp.asarray(graph.features))
    )
    assert _rel(cold.outputs, yref) < 0.08
    assert not cold.cache_hit and eng.stats["planner_calls"] == 1
    # warm: structure-keyed — attention changes nothing about the plan
    warm = eng.infer(graph, graph.features)
    assert warm.cache_hit
    assert warm.plan_ms == 0.0
    assert eng.stats["planner_calls"] == 1  # no planner after the cold request
    np.testing.assert_array_equal(warm.outputs, cold.outputs)


# ------------------------------------------------- async padded-union path
def test_gat_async_padded_union_matches_reference(pool):
    cfg = _cfg()
    eng = GNNServeEngine(
        cfg, key=jax.random.PRNGKey(0),
        union_node_bucket=128, union_edge_bucket=1024,
    )
    assert eng.padded_unions
    async_eng = AsyncGNNEngine(eng, window=len(pool))
    for g in pool:
        async_eng.submit(g, g.features)
    got = async_eng.drain()
    for g, r in zip(pool, got):
        yref = np.asarray(
            gnn_api.gnn_reference(cfg, eng.params, g, jnp.asarray(g.features))
        )
        assert r.outputs.shape == yref.shape
        assert _rel(r.outputs, yref) < 0.08
    # same composition again: member pieces + assembled plan all warm
    planner_before = eng.stats["planner_calls"]
    for g in pool:
        async_eng.submit(g, g.features)
    again = async_eng.drain()
    assert eng.stats["planner_calls"] == planner_before
    for a, b in zip(got, again):
        np.testing.assert_array_equal(a.outputs, b.outputs)


# ---------------------------------------------------------- sharded path
@pytest.mark.parametrize("num_shards", [1, 2])
def test_gat_served_sharded_matches_reference(graph, num_shards):
    cfg = _cfg()
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0), num_shards=num_shards)
    r = eng.infer(graph, graph.features)
    yref = np.asarray(
        gnn_api.gnn_reference(cfg, eng.params, graph, jnp.asarray(graph.features))
    )
    assert r.num_shards == num_shards
    assert _rel(r.outputs, yref) < 0.08
    warm = eng.infer(graph, graph.features)
    assert warm.cache_hit and warm.plan_ms == 0.0
    np.testing.assert_array_equal(warm.outputs, r.outputs)


def test_gat_sharded_matches_unsharded(graph):
    cfg = _cfg()
    solo = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    y1 = solo.infer(graph, graph.features).outputs
    sharded = GNNServeEngine(cfg, solo.params, num_shards=2)
    y2 = sharded.infer(graph, graph.features).outputs
    np.testing.assert_allclose(y1, y2, atol=5e-5, rtol=1e-4)


# ------------------------------------------------------- out-of-core path
def test_gat_served_outofcore_bitwise(graph):
    """GAT streams through the FTE (attention needs dense projections, so
    only transform sees the store); outputs stay bitwise-identical."""
    cfg = _cfg()
    ref_eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref = ref_eng.infer(graph, graph.features)
    assert not ref.streamed
    eng = GNNServeEngine(
        cfg, ref_eng.params,
        feature_budget_bytes=graph.features.nbytes // 4,
        feature_chunk_rows=32,
    )
    r = eng.infer(graph, graph.features)
    assert r.streamed
    np.testing.assert_array_equal(r.outputs, ref.outputs)


@pytest.mark.parametrize("num_shards", [2, 4])
def test_gat_mincut_overlap_matches_unsharded(graph, num_shards):
    """GAT (runtime [E,H] attention) served over min-cut shards with
    overlapped halo exchange — parity plus halo telemetry on the response."""
    cfg = _cfg(heads=2)
    solo = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    y1 = solo.infer(graph, graph.features).outputs
    sharded = GNNServeEngine(
        cfg, solo.params, num_shards=num_shards, partitioner="mincut",
        halo_overlap=True,
    )
    r = sharded.infer(graph, graph.features)
    np.testing.assert_allclose(y1, r.outputs, atol=5e-5, rtol=1e-4)
    assert r.halo_bytes > 0
    assert 0.0 <= r.halo_overlap <= 1.0
    assert sharded.shard_report()["partitioner"].startswith("mincut(")
