"""Distributed layer: collective matmuls, sharding rules, serve engine, and a
small-mesh dry-run smoke — run in subprocesses so the fake multi-device
backend never leaks into the rest of the suite (device count locks at init).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, mesh: str = "2x4", timeout=520):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        REPRO_DEBUG_MESH=mesh,
        PYTHONPATH=os.path.join(REPO, "src"),
    )
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_collective_matmuls_match_reference():
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed.collective_matmul import allgather_matmul, reduce_scatter_matmul
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
        ref = x @ w
        y1 = allgather_matmul(jax.device_put(x, NamedSharding(mesh, P("model", None))),
                              jax.device_put(w, NamedSharding(mesh, P(None, "model"))), mesh)
        y2 = reduce_scatter_matmul(jax.device_put(x, NamedSharding(mesh, P(None, "model"))),
                                   jax.device_put(w, NamedSharding(mesh, P("model", None))), mesh)
        assert float(jnp.abs(y1 - ref).max()) < 1e-4
        assert float(jnp.abs(y2 - ref).max()) < 1e-4
        print("collective matmuls OK")
    """))


def test_sharded_train_step_runs_and_matches_single_device():
    """One REAL sharded train step on 8 fake devices == unsharded step."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import get_config
        from repro.data.pipeline import synthetic_batch
        from repro.distributed.sharding import state_shardings, batch_shardings, make_policy, replicated
        from repro.launch.mesh import make_production_mesh
        from repro.models.api import model_init
        from repro.train.train_step import init_train_state, make_train_step
        import dataclasses

        cfg = get_config("qwen3-8b", reduced=True)
        cfg = dataclasses.replace(cfg, vocab_size=512)
        params = model_init(cfg, jax.random.PRNGKey(0))
        state = init_train_state(cfg, params)
        raw = synthetic_batch(seed=0, step=0, batch=8, seq=16, vocab=cfg.vocab_size)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}

        # single-device reference
        step0 = make_train_step(cfg)
        s1, m1 = jax.jit(step0)(state, batch)

        mesh = make_production_mesh()  # 2x4 debug mesh from env
        policy = make_policy(mesh)
        st_sh = state_shardings(cfg, state, mesh)
        b_sh = batch_shardings(cfg, batch, mesh)
        state_d = jax.device_put(state, st_sh)
        batch_d = jax.device_put(batch, b_sh)
        step = jax.jit(make_train_step(cfg, policy=policy),
                       in_shardings=(st_sh, b_sh))
        s2, m2 = step(state_d, batch_d)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3, (m1["loss"], m2["loss"])
        d = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                                jax.tree_util.tree_leaves(s2["params"])))
        assert d < 5e-2, d
        print("sharded==single loss", float(m1["loss"]), "max param delta", d)
    """))


def test_dryrun_cell_small_mesh():
    """lower_cell compiles a real cell on the debug mesh and reports terms."""
    out = _run("""
        from repro.launch.dryrun import lower_cell
        import json
        rec = lower_cell("smollm-360m", "decode_32k")
        assert rec.get("error") is None, rec.get("error")
        assert rec["roofline_terms_s"]["compute_s"] > 0
        assert rec["memory"]["peak_bytes_per_device"] > 0
        print(json.dumps({"dom": rec["dominant_term"]}))
    """)
    assert "dom" in out


def test_sharded_decode_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs.base import get_config
        from repro.distributed.sharding import param_shardings, cache_shardings, make_policy
        from repro.launch.mesh import make_production_mesh
        from repro.models.api import model_init, model_init_cache, model_decode_step
        cfg = get_config("qwen2-1.5b", reduced=True)
        params = model_init(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 1), jnp.int32)}
        cache = model_init_cache(cfg, params, batch, max_len=32)
        lg1, _ = model_decode_step(params, cfg, batch, cache, jnp.int32(0))
        mesh = make_production_mesh()
        p_sh = param_shardings(cfg, params, mesh)
        c_sh = cache_shardings(cfg, cache, mesh, batch=8)
        params_d = jax.device_put(params, p_sh)
        cache_d = jax.device_put(cache, c_sh)
        lg2, _ = jax.jit(lambda p, b, c, n: model_decode_step(p, cfg, b, c, n,
                         policy=make_policy(mesh)))(params_d, batch, cache_d, jnp.int32(0))
        err = float(jnp.abs(lg1 - lg2).max())
        assert err < 5e-3, err
        print("decode sharded==single, err", err)
    """))


def test_shard_map_gnn_matches_host_loop():
    """Sharded GNN execution over a real 4-device ("shard",) mesh: the
    shard_map backend (owned blocks sharded, all-gather halo exchange) must
    match both the host-loop backend and the unsharded engine."""
    print(_run("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import get_config
        from repro.core import AmpleEngine, compile_sharded_plans
        from repro.distributed.graph_shard import ShardedAmpleEngine
        from repro.graphs import make_dataset
        from repro.models.gnn import api as gnn_api

        mesh = jax.make_mesh((4,), ("shard",))
        for arch in ["gcn", "gin", "sage"]:
            cfg = dataclasses.replace(get_config(f"ample-{arch}", reduced=True),
                                      d_model=20, d_ff=12, vocab_size=6,
                                      gnn_precision="mixed", gnn_edges_per_tile=64)
            g0 = make_dataset("citeseer", max_nodes=180, max_feature_dim=20, seed=4)
            g = gnn_api.prepare_graph(cfg, g0)
            x = jnp.asarray(g0.features)
            params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(0))
            y_ref = np.asarray(gnn_api.gnn_apply(
                cfg, params, AmpleEngine(g, gnn_api.engine_config(cfg)), x))
            splan = compile_sharded_plans(g, gnn_api.engine_config(cfg),
                                          num_shards=4,
                                          modes=(gnn_api.agg_mode(cfg),))
            y_spmd = np.asarray(gnn_api.gnn_apply(
                cfg, params, ShardedAmpleEngine(g, splan, mesh=mesh), x))
            y_host = np.asarray(gnn_api.gnn_apply(
                cfg, params, ShardedAmpleEngine(g, splan), x))
            d1 = np.abs(y_spmd - y_ref).max()
            d2 = np.abs(y_spmd - y_host).max()
            assert d1 < 5e-4, (arch, d1)
            assert d2 < 5e-4, (arch, d2)
            print(arch, "shard_map==unsharded", d1, "shard_map==host_loop", d2)
        print("sharded gnn shard_map OK")
    """, devices=4, mesh="4"))


def test_shard_map_moe_matches_plain():
    """The explicit EP dispatch (moe_sharded) == plain moe on 8 fake devices,
    including gradients — the §Perf cell C code path."""
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.models.lm.moe import moe_init, moe_apply
        from repro.models.lm.moe_sharded import moe_apply_sharded, sharded_applicable
        from repro.launch.mesh import make_production_mesh
        from repro.distributed.sharding import make_policy
        mesh = make_production_mesh()
        policy = make_policy(mesh)
        D, F, E, K = 32, 64, 8, 2
        p = moe_init(jax.random.PRNGKey(2), D, F, E, "swiglu", shared_expert=True,
                     dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, D))
        assert sharded_applicable(policy, E, 16, F)
        ref, _ = moe_apply(p, x, num_experts=E, top_k=K, kind="swiglu",
                           capacity_factor=16.0)
        out, aux = moe_apply_sharded(p, x, num_experts=E, top_k=K, kind="swiglu",
                                     capacity_factor=16.0, policy=policy)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        g = jax.grad(lambda pp: moe_apply_sharded(pp, x, num_experts=E, top_k=K,
            kind="swiglu", capacity_factor=16.0, policy=policy)[0].sum())(p)
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree_util.tree_leaves(g))
        print("shard_map moe == plain, err", err)
    """))


def test_shard_map_runtime_coeff_and_gat_bitwise_vs_host_loop():
    """Runtime per-edge operands through shard_map: a raw f32[E] coefficient
    vector and full GAT attention ([E,H] softmax scores) must be BITWISE
    equal between the mesh backend and the host loop, for both partitioners
    and with overlapped halo exchange on the mesh path."""
    print(_run("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import get_config
        from repro.core import compile_sharded_plans
        from repro.distributed.graph_shard import ShardedAmpleEngine
        from repro.graphs import make_dataset, make_partition
        from repro.models.gnn import api as gnn_api

        mesh = jax.make_mesh((4,), ("shard",))
        cfg = dataclasses.replace(get_config("ample-gat", reduced=True),
                                  d_model=24, d_ff=16, vocab_size=8,
                                  gnn_precision="mixed", gnn_edges_per_tile=64,
                                  gnn_heads=2)
        g0 = make_dataset("citeseer", max_nodes=150, max_feature_dim=24, seed=3)
        g = gnn_api.prepare_graph(cfg, g0)
        x = jnp.asarray(g0.features)
        params = gnn_api.gnn_init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        coeff = jnp.asarray(rng.standard_normal(g.num_edges), jnp.float32)
        ecfg = gnn_api.engine_config(cfg)
        for kind in ("edges", "mincut"):
            part = make_partition(g, 4, kind)
            splan = compile_sharded_plans(g, ecfg, partition=part,
                                          modes=("runtime",))
            host = ShardedAmpleEngine(g, splan)
            spmd = ShardedAmpleEngine(g, splan, mesh=mesh, halo_overlap=True)
            # raw runtime coefficient vector (float precision for exactness)
            a = np.asarray(host.aggregate(x, mode="runtime", edge_coeff=coeff))
            b = np.asarray(spmd.aggregate(x, mode="runtime", edge_coeff=coeff))
            assert (a == b).all(), (kind, np.abs(a - b).max())
            # full GAT forward: per-head attention through edge_softmax +
            # attention_aggregate inside the arch apply fn
            yh = np.asarray(gnn_api.gnn_apply(cfg, params, host, x))
            ys = np.asarray(gnn_api.gnn_apply(cfg, params, spmd, x))
            assert (yh == ys).all(), (kind, np.abs(yh - ys).max())
            assert spmd.halo_stats.get("halo_bytes", 0) > 0
            print(kind, "runtime-coeff + gat bitwise OK")
        print("shard_map runtime coeff OK")
    """, devices=4, mesh="4"))
