"""Observability: span recorder, metrics registry, lifecycle reconciliation.

Three layers of guarantees:

1. **Primitives** — the ring buffer bounds memory, the Chrome-trace export
   is well-formed (Perfetto-loadable), the disabled recorder is a shared
   no-op singleton (the zero-overhead default).
2. **Consolidation** — the engines' ``stats`` dicts, ``cache_info()`` and
   the Prometheus dump all read the *same* registry cells, so they can
   never disagree; value semantics (ints stay ints) are unchanged.
3. **Reconciliation** — spans are recorded from the same ``perf_counter``
   stamps the ``*_ms`` accounting uses, so trace-derived totals match the
   reported fields: exactly for queue/run, within tolerance for the
   prefetcher's reconstructed stall/copy intervals. Per-lane span sets
   must be laminar (disjoint or nested) — overlapping spans on one lane
   mean a bookkeeping bug, not concurrency.
"""
import json
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs.base import get_config
from repro.graphs import make_dataset
from repro.observe import metrics as ometrics
from repro.observe import trace as otrace
from repro.observe.trace import NULL_SPAN, TraceRecorder
from repro.serve.async_gnn import AsyncGNNEngine
from repro.serve.gnn_engine import GNNRequest, GNNServeEngine, request_stamp
from repro.serve.telemetry import TenantTelemetry
from repro.serve.tenancy import TenantRouter


@pytest.fixture()
def recorder():
    """A fresh enabled recorder installed for the test, disabled after."""
    rec = otrace.enable(capacity=1 << 14)
    yield rec
    otrace.disable()


def _cfg(arch="gcn"):
    return get_config(f"ample-{arch}", reduced=True)


def _graph(n=300, seed=0, dim=None):
    return make_dataset(
        "cora", max_nodes=n, max_feature_dim=dim or _cfg().d_model, seed=seed
    )


# ------------------------------------------------------------- primitives
def test_ring_bounds_memory_and_counts_drops():
    rec = TraceRecorder(capacity=4)
    for i in range(10):
        rec.add_span(f"s{i}", 0.0, 1.0)
    spans = rec.spans()
    assert len(spans) == 4
    assert [s.name for s in spans] == ["s6", "s7", "s8", "s9"]  # oldest evicted
    assert rec.dropped == 6
    rec.clear()
    assert rec.spans() == [] and rec.dropped == 0


def test_disabled_recorder_is_noop_singleton():
    rec = TraceRecorder(capacity=16, enabled=False)
    # Zero-allocation claim: every disabled span() is the same object.
    assert rec.span("a") is NULL_SPAN
    assert rec.span("b", cat="x", trace_id="t") is NULL_SPAN
    with rec.span("c") as sp:
        sp.set(k=1)  # no-op, no error
    rec.add_span("d", 0.0, 1.0)
    rec.add_instant("e")
    assert rec.spans() == []


def test_module_recorder_default_disabled_and_toggles():
    assert not otrace.is_enabled()  # the process default is off
    rec = otrace.enable(capacity=64)
    try:
        assert otrace.is_enabled() and otrace.get_recorder() is rec
        with otrace.get_recorder().span("x", cat="t"):
            pass
        assert [s.name for s in rec.spans()] == ["x"]
    finally:
        otrace.disable()
    assert not otrace.is_enabled()
    # the old recorder still holds its spans; the fresh one is empty
    assert len(rec.spans()) == 1 and otrace.get_recorder().spans() == []


def test_nested_spans_and_total_ms():
    rec = TraceRecorder()
    tid = "req-x"
    with rec.span("outer", trace_id=tid):
        time.sleep(0.002)
        with rec.span("inner", trace_id=tid):
            time.sleep(0.001)
    inner, outer = rec.spans()[0], rec.spans()[1]  # inner commits first
    assert (inner.name, outer.name) == ("inner", "outer")
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1  # properly nested
    assert rec.total_ms("outer") >= rec.total_ms("inner") > 0.0
    assert rec.total_ms("outer", trace_id="other") == 0.0


def test_chrome_trace_export_shape(tmp_path):
    rec = TraceRecorder()
    rec.add_span("work", 1.0, 1.5, cat="c", lane="laneA", trace_id="req-1",
                 args={"k": 2})
    rec.add_span("work2", 1.5, 1.7, lane="laneB")
    rec.add_instant("mark", t=1.2, lane="laneA")
    doc = rec.chrome_trace()
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    complete = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] == "i"]
    # one thread_name record per lane, stable tid mapping
    assert {m["args"]["name"] for m in meta} == {"laneA", "laneB"}
    tid = {m["args"]["name"]: m["tid"] for m in meta}
    w = next(e for e in complete if e["name"] == "work")
    assert w["tid"] == tid["laneA"]
    assert w["dur"] == pytest.approx(0.5e6)  # microseconds
    assert w["args"] == {"k": 2, "trace_id": "req-1"}
    assert instants[0]["s"] == "t"
    assert doc["otherData"]["dropped_spans"] == 0
    # export round-trips through json (the Perfetto load path)
    path = rec.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        assert json.load(f)["traceEvents"] == events


def test_new_trace_ids_are_unique():
    ids = {otrace.new_trace_id() for _ in range(100)}
    assert len(ids) == 100 and all(i.startswith("req-") for i in ids)


# -------------------------------------------------------- metrics registry
def test_registry_counters_and_labels():
    reg = ometrics.MetricsRegistry()
    fam = reg.counter("reqs_total", help="h", labels=("engine",))
    fam.labels(engine="a").inc()
    fam.labels(engine="a").inc(2)
    fam.labels(engine="b").inc()
    assert fam.labels(engine="a").value == 3.0
    assert fam.labels(engine="b").value == 1.0
    with pytest.raises(ValueError):
        fam.labels(wrong="a")
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")  # kind conflict on an existing name
    text = reg.prometheus_text()
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{engine="a"} 3' in text
    assert 'reqs_total{engine="b"} 1' in text


def test_registry_histogram_summary_exposition():
    reg = ometrics.MetricsRegistry()
    h = reg.histogram("lat_ms", help="h").labels()
    for v in (10.0, 20.0, 30.0, 40.0):
        h.record(v)
    text = reg.prometheus_text()
    assert "# TYPE lat_ms summary" in text
    assert 'lat_ms{quantile="0.5"}' in text
    assert "lat_ms_sum 100" in text
    assert "lat_ms_count 4" in text
    snap = reg.snapshot()["lat_ms"]
    assert snap["kind"] == "histogram"
    assert snap["samples"][0]["value"]["count"] == 4


def test_register_histogram_adopts_shared_object():
    reg = ometrics.MetricsRegistry()
    from repro.serve.telemetry import StreamingHistogram

    hist = StreamingHistogram()
    reg.register_histogram("ext_ms", hist, tenant="t0")
    hist.record(5.0)  # recorded through the ORIGINAL object
    fam = reg.get("ext_ms")
    (labels, child), = fam.samples()
    assert child is hist and labels == {"tenant": "t0"}
    assert 'ext_ms_count{tenant="t0"} 1' in reg.prometheus_text()


def test_stats_view_value_semantics():
    reg = ometrics.MetricsRegistry()
    sv = ometrics.StatsView(
        reg, "eng", {"engine": "e0"}, keys=("hits", "stall_ms"),
        float_keys=("stall_ms",),
    )
    sv["hits"] += 1
    sv["stall_ms"] += 1.25
    assert sv["hits"] == 1 and isinstance(sv["hits"], int)
    assert sv["stall_ms"] == 1.25 and isinstance(sv["stall_ms"], float)
    assert dict(sv) == {"hits": 1, "stall_ms": 1.25}
    # the view IS the registry cell — no second copy to drift
    assert reg.get("eng_hits").labels(engine="e0").value == 1.0
    sv["hits"] = 7
    assert reg.get("eng_hits").labels(engine="e0").value == 7.0


def test_next_instance_unique():
    a, b = ometrics.next_instance("x"), ometrics.next_instance("x")
    assert a != b and a.startswith("x-") and b.startswith("x-")


# ------------------------------------ consolidation: stats == registry cells
def test_engine_stats_cache_info_and_prometheus_agree():
    g = _graph(n=200)
    eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(0))
    eng.infer(g, g.features)
    eng.infer(g, g.features)
    # one storage: the stats dict view, cache_info and the registry cell
    reg = ometrics.get_registry()
    cell = reg.get("gnn_serve_requests").labels(engine=eng.instance)
    assert eng.stats["requests"] == 2 == int(cell.value)
    info = eng.cache_info()
    for k, v in eng.stats.items():
        assert info[k] == v, k
    assert isinstance(eng.stats["cache_hits"], int)
    assert isinstance(eng.stats["stall_ms"], float)
    text = reg.prometheus_text()
    assert f'gnn_serve_requests{{engine="{eng.instance}"}} 2' in text


def test_concurrent_engines_do_not_alias_counters():
    g = _graph(n=150)
    e1 = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(0))
    e2 = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(0))
    e1.infer(g, g.features)
    assert e1.stats["requests"] == 1
    assert e2.stats["requests"] == 0  # per-instance labels keep them apart
    assert e1.instance != e2.instance


def test_async_cache_info_is_thin_view_over_stats():
    pool = [_graph(n=60, seed=s) for s in (1, 2, 3)]
    async_eng = AsyncGNNEngine(_cfg(), window=2, key=jax.random.PRNGKey(1))
    tickets = [async_eng.submit(g, g.features) for g in pool]
    async_eng.drain()
    info = async_eng.cache_info()
    for k, v in async_eng.stats.items():
        assert info[k] == v, k
    assert info["completed"] == len(tickets)
    assert all(t.done for t in tickets)


def test_tenant_telemetry_histograms_land_in_registry():
    tel = TenantTelemetry()
    tel.record_submitted("gold")
    tel.record_completion("gold", latency_ms=12.0, queue_ms=3.0, nodes=10)
    fam = ometrics.get_registry().get("tenant_latency_ms")
    children = {
        tuple(sorted(labels.items())): child for labels, child in fam.samples()
    }
    key = (("telemetry", tel.instance), ("tenant", "gold"))
    assert children[key] is tel._tenants["gold"].latency  # adopted, not copied
    assert children[key].count == 1
    text = ometrics.get_registry().prometheus_text()
    assert f'tenant_latency_ms_count{{telemetry="{tel.instance}",tenant="gold"}} 1' in text


# ---------------------------------------- lifecycle spans + reconciliation
def _laminar(spans, eps=1.5e-3):
    """Assert the intervals form a laminar family: any two are (eps-)disjoint
    or one (eps-)contains the other."""
    ivs = sorted(
        [(s.t0, s.t1, s.name) for s in spans if s.t1 > s.t0],
        key=lambda iv: (iv[0], -iv[1]),
    )
    for i, (a0, a1, an) in enumerate(ivs):
        for b0, b1, bn in ivs[i + 1:]:
            if b0 >= a1 - eps:
                continue  # disjoint (b starts after a ends)
            assert b1 <= a1 + eps, (
                f"lane overlap: {an} [{a0:.6f},{a1:.6f}) vs "
                f"{bn} [{b0:.6f},{b1:.6f})"
            )


def test_direct_request_spans_reconcile_with_response(recorder):
    g = _graph(n=400)
    eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(0))
    eng.infer(g, g.features)  # warm the plan cache outside the window
    admitted = request_stamp() - 0.05
    r = eng.infer(g, g.features, admitted_at=admitted)
    assert r.trace_id
    mine = [s for s in recorder.spans() if s.trace_id == r.trace_id]
    names = {s.name for s in mine}
    assert {"queue", "plan", "execute"} <= names
    by = {s.name: s for s in mine}
    # same stamps as the accounting -> exact, not approximate
    assert by["execute"].dur_ms == pytest.approx(r.run_ms, rel=1e-9)
    assert by["queue"].dur_ms == pytest.approx(r.queue_ms, rel=1e-9)
    assert r.queue_ms >= 50.0  # the backdated admission is visible
    assert by["plan"].args["cache_hit"]
    assert by["plan"].t1 <= by["execute"].t0  # plan precedes execute
    # the queue span ends where planning starts
    assert by["queue"].t1 == pytest.approx(by["plan"].t0, abs=1e-9)


def test_streamed_request_trace_tree_and_totals(recorder):
    g = _graph(n=600)
    eng = GNNServeEngine(
        _cfg(), feature_budget_bytes=g.features.nbytes // 4,
        feature_chunk_rows=64, key=jax.random.PRNGKey(0),
    )
    r = eng.infer(g, g.features)
    assert r.streamed and r.copy_ms > 0.0
    mine = [s for s in recorder.spans() if s.trace_id == r.trace_id]
    names = {s.name for s in mine}
    assert "execute" in names
    assert any(n.startswith("stream:") for n in names)
    copies = [s for s in mine if s.name.startswith("copy:")]
    assert copies, "streamed request recorded no copy spans"
    # per-lane span sets must be laminar — overlap within a lane is a bug
    lanes = {}
    for s in mine:
        lanes.setdefault(s.lane, []).append(s)
    for lane, spans in lanes.items():
        _laminar(spans)
    # copy spans live on the staging lanes, not the consumer lane
    assert {s.lane for s in copies} <= {"copy", "copy-inline"}
    # trace-derived totals reconcile with the response accounting (10%
    # acceptance tolerance + a small absolute floor for sub-ms noise)
    copy_total = sum(s.dur_ms for s in copies)
    assert copy_total == pytest.approx(r.copy_ms, rel=0.10, abs=1.0)
    stall_total = sum(s.dur_ms for s in mine if s.name == "stall")
    assert stall_total == pytest.approx(r.stall_ms, rel=0.10, abs=1.0)
    # stream spans nest inside the execute window
    ex = next(s for s in mine if s.name == "execute")
    for s in mine:
        if s.name.startswith("stream:") or s.name.startswith("layer:"):
            assert s.t0 >= ex.t0 - 1e-4 and s.t1 <= ex.t1 + 1e-4, s.name


def test_batch_spans_per_member_queue_and_scatter(recorder):
    pool = [_graph(n=80, seed=s) for s in (1, 2, 3)]
    eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(0))
    at = request_stamp() - 0.02
    reqs = [
        GNNRequest(graph=g, features=g.features, admitted_at=at,
                   trace_id=f"req-batch-{i}")
        for i, g in enumerate(pool)
    ]
    out = eng.infer_batch(reqs)
    assert [r.trace_id for r in out] == [r.trace_id for r in reqs]
    spans = recorder.spans()
    queues = [s for s in spans if s.name == "queue"]
    assert {s.trace_id for s in queues} == {r.trace_id for r in reqs}
    for r, q in zip(out, sorted(queues, key=lambda s: s.trace_id)):
        assert q.dur_ms == pytest.approx(r.queue_ms, rel=1e-9)
    assert any(s.name == "scatter" for s in spans)
    plan = next(s for s in spans if s.name == "plan")
    assert plan.args["batch"] == len(reqs)


def test_async_and_routed_paths_stamp_same_clock(recorder):
    """Satellite: queue_ms means the same thing on every path — a wait on
    the ``request_stamp`` (perf_counter) timeline, ending at execution."""
    g = _graph(n=100)
    # direct engine path: backdated admitted_at
    eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(0))
    r_direct = eng.infer(g, g.features, admitted_at=request_stamp() - 0.2)
    assert r_direct.queue_ms >= 195.0
    # async path: backdated arrival flows through the ticket
    async_eng = AsyncGNNEngine(_cfg(), window=1, key=jax.random.PRNGKey(0))
    t = async_eng.submit(g, g.features, arrival=request_stamp() - 0.2)
    r_async = t.result()
    assert r_async.queue_ms >= 195.0
    assert t.trace_id and r_async.trace_id == t.trace_id
    # routed path: arrival is stamped at the door on the same clock, so
    # queue_ms is bounded by the submit->result wall time on that clock
    router = TenantRouter(
        AsyncGNNEngine(_cfg(), window=1, key=jax.random.PRNGKey(0))
    )
    router.add_tenant("t0")
    t0 = request_stamp()
    ticket = router.submit("t0", g, g.features)
    router.step()
    resp = ticket.result()
    wall_ms = (request_stamp() - t0) * 1e3
    assert 0.0 <= resp.queue_ms <= wall_ms
    assert ticket.trace_id and resp.trace_id == ticket.trace_id
    # every path records queue + execute spans under the request's id
    for tid in (r_direct.trace_id, r_async.trace_id, resp.trace_id):
        names = {s.name for s in recorder.spans() if s.trace_id == tid}
        assert "execute" in names, tid
    assert any(
        s.name == "dwrr_fill" for s in recorder.spans()
    ), "router fill left no span"


def test_trace_export_of_live_serving_loads_as_chrome_json(recorder, tmp_path):
    g = _graph(n=500)
    eng = GNNServeEngine(
        _cfg(), feature_budget_bytes=g.features.nbytes // 4,
        feature_chunk_rows=64, key=jax.random.PRNGKey(0),
    )
    r = eng.infer(g, g.features)
    assert r.streamed
    path = recorder.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert "X" in phases and "M" in phases
    lanes = {
        e["args"]["name"] for e in doc["traceEvents"] if e["ph"] == "M"
    }
    assert any(l.startswith("copy") for l in lanes), lanes


# ------------------------------------------------------------ overhead guard
def test_disabled_tracing_overhead_under_two_percent():
    """The disabled recorder must cost <2% of a warm serve request.

    Hybrid guard (robust on noisy CI): measure the per-call cost of the
    disabled-path idioms (``rec.enabled`` guard; ``span()`` returning the
    singleton), multiply by a *generous* per-request call count, and compare
    against the measured warm per-request time.
    """
    assert not otrace.is_enabled()
    rec = otrace.get_recorder()
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        if rec.enabled:  # the guard every instrumentation point pays
            pass
        rec.span("x")  # the context-manager form pays this instead
    per_call_s = (time.perf_counter() - t0) / n

    g = _graph(n=200)
    eng = GNNServeEngine(_cfg(), key=jax.random.PRNGKey(0))
    eng.infer(g, g.features)  # warm the plan cache
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        eng.infer(g, g.features)
    per_request_s = (time.perf_counter() - t0) / reps

    # 200 trace points per request is far above the real count (~a dozen
    # plus a few per streamed chunk; this warm path streams nothing).
    overhead = 200 * per_call_s
    assert overhead < 0.02 * per_request_s, (
        f"disabled tracing overhead {overhead * 1e6:.1f}us vs "
        f"request {per_request_s * 1e3:.2f}ms"
    )


# ------------------------------------------------- bench regression checker
def _load_checker():
    """benchmarks/ is a namespace package rooted at the repo root; load the
    checker by path so the test works regardless of invocation cwd."""
    import importlib.util
    import os

    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "benchmarks", "check_regression.py",
    )
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_regression_findings(tmp_path):
    cr = _load_checker()

    base = {
        "quick": True,
        "rows": [
            {"name": "a", "us_per_call": 100.0, "chunk_hit_rate": "0.8",
             "prefetch_overlap": "0.9"},
            {"name": "b", "us_per_call": 50.0},
        ],
    }
    fresh = {
        "quick": True,
        "rows": [
            {"name": "a", "us_per_call": 120.0, "chunk_hit_rate": "0.6",
             "prefetch_overlap": "0.2"},
            {"name": "c", "us_per_call": 10.0},
        ],
    }
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    frows, fq = cr.load_rows(str(fp))
    brows, bq = cr.load_rows(str(bp))
    assert fq and bq
    hard = cr.check_hard_gates(frows, brows)
    assert {f.severity for f in hard} == {"FAIL"}
    msgs = " | ".join(f.message for f in hard)
    assert "prefetch_overlap" in msgs and "chunk_hit_rate" in msgs
    soft = cr.check_soft_drift(frows, brows, same_scale=True)
    assert any("no baseline row" in f.message for f in soft)  # new bench c
    assert any("missing from fresh" in f.message for f in soft)  # lost b
    # slowdown 1.2x is inside the 1.5x tolerance -> no wall-clock warn
    assert not any("us_per_call" in f.message for f in soft)
    # exit code: 1 with fails, 0 when the gate is disabled
    rc = cr.main(["--fresh", str(fp), "--baseline", str(bp)])
    assert rc == 1


def test_check_regression_gate_disable(tmp_path, monkeypatch):
    cr = _load_checker()

    fresh = {"quick": True,
             "rows": [{"name": "a", "prefetch_overlap": "0.1"}]}
    fp = tmp_path / "fresh.json"
    fp.write_text(json.dumps(fresh))
    assert cr.main(["--fresh", str(fp)]) == 1
    monkeypatch.setenv("REPRO_BENCH_NO_GATE", "1")
    assert cr.main(["--fresh", str(fp)]) == 0


def test_halo_overlap_spans_reconcile_with_response(recorder):
    """Overlapped sharded request: halo_gather/halo_wait spans on the halo
    lane, recorded from the same stamps as the halo_ms/halo_wait_ms
    accounting, carrying the request's trace_id."""
    g = _graph(n=400)
    eng = GNNServeEngine(
        _cfg(), key=jax.random.PRNGKey(0), num_shards=2,
        partitioner="mincut", halo_overlap=True,
    )
    eng.infer(g, g.features)  # warm plans + jit outside the window
    r = eng.infer(g, g.features)
    assert r.halo_bytes > 0
    mine = [s for s in recorder.spans() if s.trace_id == r.trace_id]
    gathers = [s for s in mine if s.name == "halo_gather"]
    waits = [s for s in mine if s.name == "halo_wait"]
    assert gathers and waits
    assert all(s.cat == "halo" for s in gathers)
    # span-derived totals match the reported fields (same stamps -> exact)
    assert sum(s.dur_ms for s in gathers) == pytest.approx(r.halo_ms, rel=1e-6)
    wait_total = sum(s.dur_ms for s in waits)
    stats_wait = eng.stats["halo_wait_ms"]
    assert wait_total >= 0.0 and stats_wait >= 0.0
    assert 0.0 <= r.halo_overlap <= 1.0
    # the gather runs on its own lane, apart from the consumer's spans
    assert {s.lane for s in gathers} == {"halo"}
