"""Serving engine: prefill+decode consistency, greedy determinism, eos."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.api import model_forward, model_init
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module", params=["smollm-360m", "mamba2-370m", "jamba-v0.1-52b"])
def engine(request):
    import dataclasses

    cfg = get_config(request.param, reduced=True)
    if cfg.is_moe:
        # capacity drops legitimately differ between decode (T=1) and full
        # forward (T=S); a no-drop capacity makes greedy decode comparable
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = model_init(cfg, jax.random.PRNGKey(0))
    return cfg, params, ServeEngine(cfg, params, max_len=48)


def test_generate_matches_stepwise_argmax(engine):
    """ServeEngine output == greedy decoding computed via full forwards."""
    cfg, params, eng = engine
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = eng.generate(prompts, max_new_tokens=6)
    # reference: repeatedly run the FULL forward and take argmax of last pos
    seq = prompts
    for _ in range(6):
        logits, _ = model_forward(params, cfg, {"tokens": seq})
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1).astype(jnp.int32)
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seq))


def test_generate_deterministic(engine):
    cfg, params, eng = engine
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab_size)
    a = eng.generate(prompts, max_new_tokens=5)
    b = eng.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_respects_max_len(engine):
    cfg, params, eng = engine
    prompts = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(AssertionError):
        eng.generate(prompts, max_new_tokens=100)
