"""Shared fixtures/strategies. NOTE: no XLA_FLAGS here — tests must see the
single real CPU device; only launch/dryrun.py fakes 512 devices."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# Property tests trace JAX under the hood — generous deadlines, no shrink spam.
settings.register_profile(
    "repro",
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


def random_graph(n: int, mean_deg: float, seed: int):
    from repro.graphs.datasets import make_lognormal_graph

    return make_lognormal_graph(n, mean_deg, seed=seed)


@pytest.fixture(scope="session")
def small_cora():
    from repro.graphs import make_dataset

    return make_dataset("cora", max_nodes=200, max_feature_dim=24, seed=0)
