"""Shared fixtures/strategies. NOTE: no XLA_FLAGS here — tests must see the
single real CPU device; only launch/dryrun.py fakes 512 devices.

``hypothesis`` is optional: when missing, property tests are skipped via the
stubs in ``_hypothesis_compat`` instead of dying at collection."""
from __future__ import annotations

import os

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS

# Large-graph tests regenerate Table-4 lognormal graphs per process; cache
# the structures on disk (repo-local, gitignored) so repeat runs skip the
# dominant setup cost. Explicit REPRO_DATASET_CACHE settings win.
os.environ.setdefault(
    "REPRO_DATASET_CACHE",
    os.path.join(os.path.dirname(os.path.dirname(__file__)), ".dataset-cache"),
)

if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, settings

    # Property tests trace JAX under the hood — generous deadlines, no shrink spam.
    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("repro")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "hypothesis: property-based tests (require the hypothesis package; "
        "select with -m hypothesis, deselect with -m 'not hypothesis')",
    )


def random_graph(n: int, mean_deg: float, seed: int):
    from repro.graphs.datasets import make_lognormal_graph

    return make_lognormal_graph(n, mean_deg, seed=seed)


@pytest.fixture(scope="session")
def small_cora():
    from repro.graphs import make_dataset

    return make_dataset("cora", max_nodes=200, max_feature_dim=24, seed=0)
