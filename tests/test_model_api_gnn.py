"""family="gnn" through the unified model API + the plan-cached serve engine."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import compile_plans
from repro.models.api import (
    model_decode_step,
    model_forward,
    model_init,
    model_init_cache,
    model_prefill,
)
from repro.models.gnn import api as gnn_api
from repro.graphs import disjoint_union, make_dataset
from repro.serve.gnn_engine import GNNRequest, GNNServeEngine

ARCHS = ["gcn", "gin", "sage", "gat"]


def _cfg(arch, *, precision="float"):
    return dataclasses.replace(
        get_config(f"ample-{arch}", reduced=True),
        d_model=20, d_ff=12, vocab_size=6, gnn_precision=precision,
        gnn_edges_per_tile=64,
    )


@pytest.fixture(scope="module")
def graph():
    return make_dataset("cora", max_nodes=120, max_feature_dim=20, seed=1)


# --------------------------------------------------- unified five-function API
@pytest.mark.parametrize("arch", ARCHS)
def test_model_forward_matches_dense_reference(arch, graph):
    """Acceptance: model_forward(params, cfg, {graph, features}) == oracle."""
    cfg = _cfg(arch)
    params = model_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(graph.features)
    y, aux = model_forward(params, cfg, {"graph": graph, "features": x})
    yref = gnn_api.gnn_reference(cfg, params, graph, x)
    assert y.shape == (graph.num_nodes, cfg.vocab_size)
    assert float(aux) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=5e-4, rtol=1e-3)


def test_model_forward_accepts_precompiled_engine(graph):
    """The serving path hands model_forward a plan-backed engine; results match."""
    from repro.core import AmpleEngine

    cfg = _cfg("gcn")
    params = model_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(graph.features)
    prepared = gnn_api.prepare_graph(cfg, graph)
    plan = compile_plans(prepared, gnn_api.engine_config(cfg), modes=("gcn",))
    eng = AmpleEngine(prepared, plan=plan)
    y_plan, _ = model_forward(params, cfg, {"graph": graph, "features": x, "engine": eng})
    y_cold, _ = model_forward(params, cfg, {"graph": graph, "features": x})
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_cold))


def test_token_entry_points_reject_gnn(graph):
    cfg = _cfg("gcn")
    params = model_init(cfg, jax.random.PRNGKey(0))
    batch = {"graph": graph, "features": graph.features}
    with pytest.raises(TypeError, match="GNNServeEngine"):
        model_prefill(params, cfg, batch, 8)
    with pytest.raises(TypeError, match="GNNServeEngine"):
        model_init_cache(cfg, params, batch, 8)
    with pytest.raises(TypeError, match="GNNServeEngine"):
        model_decode_step(params, cfg, batch, None, 0)


# ------------------------------------------------------------ ExecutionPlan
def test_compile_plans_fingerprint_stability(graph):
    cfg = gnn_api.engine_config(_cfg("gcn"))
    p1 = compile_plans(graph, cfg, modes=("gcn",))
    p2 = compile_plans(graph, cfg, modes=("gcn",))
    assert p1.fingerprint == p2.fingerprint and p1 == p2 and hash(p1) == hash(p2)
    p3 = compile_plans(graph, cfg, modes=("sum",))
    assert p3.fingerprint != p1.fingerprint
    g2 = make_dataset("cora", max_nodes=110, max_feature_dim=20, seed=1)
    assert compile_plans(g2, cfg, modes=("gcn",)).fingerprint != p1.fingerprint


def test_engine_rejects_mismatched_plan(graph):
    from repro.core import AmpleEngine

    cfg = gnn_api.engine_config(_cfg("gin"))
    plan = compile_plans(graph, cfg, modes=("sum",))
    other = make_dataset("cora", max_nodes=80, max_feature_dim=20, seed=2)
    with pytest.raises(ValueError, match="plan was compiled"):
        AmpleEngine(other, plan=plan)


# ------------------------------------------------------------- serve engine
def test_serve_engine_plan_cache_hit(graph, monkeypatch):
    """Acceptance: a second request on the same graph skips plan compilation
    (planner invoked once) and returns bitwise-identical results to a cold
    engine."""
    import repro.serve.gnn_engine as gnn_engine_mod

    calls = {"n": 0}
    real_compile = gnn_engine_mod.compile_plans

    def counting_compile(*args, **kwargs):
        calls["n"] += 1
        return real_compile(*args, **kwargs)

    monkeypatch.setattr(gnn_engine_mod, "compile_plans", counting_compile)

    cfg = _cfg("gcn", precision="mixed")
    params = model_init(cfg, jax.random.PRNGKey(0))
    warm_eng = GNNServeEngine(cfg, params)
    r1 = warm_eng.infer(graph, graph.features)
    r2 = warm_eng.infer(graph, graph.features)
    assert calls["n"] == 1, "planner must run once across repeated requests"
    assert warm_eng.stats["planner_calls"] == 1
    assert not r1.cache_hit and r2.cache_hit
    assert r1.fingerprint == r2.fingerprint

    cold_eng = GNNServeEngine(cfg, params)
    r_cold = cold_eng.infer(graph, graph.features)
    np.testing.assert_array_equal(r2.outputs, r_cold.outputs)
    np.testing.assert_array_equal(r2.outputs, r1.outputs)


def test_serve_engine_lru_eviction(graph):
    cfg = _cfg("gin")
    eng = GNNServeEngine(cfg, plan_cache_size=1)
    g2 = make_dataset("cora", max_nodes=90, max_feature_dim=20, seed=5)
    eng.infer(graph, graph.features)
    eng.infer(g2, g2.features)  # evicts graph's plan
    assert eng.cache_info()["size"] == 1
    assert eng.stats["evictions"] == 1
    r = eng.infer(graph, graph.features)  # recompiled, not a hit
    assert not r.cache_hit
    assert eng.stats["planner_calls"] == 3


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_batch_matches_individual(arch):
    """Disjoint-union batching == per-request serving, for every arch."""
    cfg = _cfg(arch)
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(7))
    graphs = [
        make_dataset("cora", max_nodes=n, max_feature_dim=20, seed=s)
        for n, s in [(60, 1), (45, 2), (75, 3)]
    ]
    reqs = [GNNRequest(graph=g, features=g.features) for g in graphs]
    batched = eng.infer_batch(reqs)
    assert [r.outputs.shape[0] for r in batched] == [g.num_nodes for g in graphs]
    for g, r in zip(graphs, batched):
        solo = eng.infer(g, g.features)
        np.testing.assert_allclose(r.outputs, solo.outputs, atol=1e-5, rtol=1e-5)


def test_serve_batch_cache_hit_on_repeat_mix(graph):
    cfg = _cfg("sage")
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(8))
    g2 = make_dataset("cora", max_nodes=70, max_feature_dim=20, seed=9)
    reqs = [GNNRequest(graph=graph, features=graph.features),
            GNNRequest(graph=g2, features=g2.features)]
    first = eng.infer_batch(reqs)
    second = eng.infer_batch(reqs)
    assert not first[0].cache_hit and second[0].cache_hit
    assert eng.stats["planner_calls"] == 1
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a.outputs, b.outputs)


def test_serve_rejects_foreign_arch(graph):
    """Params are arch-specific, so requests for another arch must be routed
    to an engine configured for it, not silently misinterpreted."""
    cfg = _cfg("gcn")
    eng = GNNServeEngine(cfg)
    with pytest.raises(ValueError, match="holds 'gcn' params"):
        eng.infer(graph, graph.features, arch="gin")
    reqs = [GNNRequest(graph=graph, features=graph.features, arch="gcn"),
            GNNRequest(graph=graph, features=graph.features, arch="gin")]
    with pytest.raises(ValueError, match="holds 'gcn' params"):
        eng.infer_batch(reqs)
    # explicit matching arch is fine
    r = eng.infer(graph, graph.features, arch="gcn")
    assert r.outputs.shape == (graph.num_nodes, cfg.vocab_size)


def test_serve_batch_mixed_precision_tags_per_member(graph):
    """Degree-Quant protection in a batched union matches solo serving: a
    member graph's tags are computed on its own degree distribution."""
    from repro.core.degree_quant import inference_precision_tags

    cfg = _cfg("gin", precision="mixed")
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    g2 = make_dataset("cora", max_nodes=50, max_feature_dim=20, seed=11)
    reqs = [GNNRequest(graph=graph, features=graph.features),
            GNNRequest(graph=g2, features=g2.features)]
    eng.infer_batch(reqs)
    (_, plan, _), = [v for v in eng._cache.values()]
    solo = np.concatenate([
        inference_precision_tags(g, eng.engine_cfg.dq) for g in (graph, g2)
    ])
    np.testing.assert_array_equal(plan.precision_tags, solo)


def test_model_forward_rejects_wrong_feature_rows(graph):
    cfg = _cfg("gcn")
    params = model_init(cfg, jax.random.PRNGKey(0))
    bad = np.asarray(graph.features)[: graph.num_nodes // 2]
    with pytest.raises(ValueError, match="features must be"):
        model_forward(params, cfg, {"graph": graph, "features": bad})


def test_disjoint_union_structure():
    a = make_dataset("cora", max_nodes=40, max_feature_dim=8, seed=1)
    b = make_dataset("cora", max_nodes=30, max_feature_dim=8, seed=2)
    u = disjoint_union([a, b])
    assert u.num_nodes == a.num_nodes + b.num_nodes
    assert u.num_edges == a.num_edges + b.num_edges
    # block-diagonal: no edge crosses the offset boundary
    rows = np.repeat(np.arange(u.num_nodes), u.degrees)
    src = u.indices
    assert ((rows < a.num_nodes) == (src < a.num_nodes)).all()
    assert u.features.shape == (u.num_nodes, 8)


def test_disjoint_union_with_empty_member():
    from repro.graphs.csr import Graph, validate

    a = make_dataset("cora", max_nodes=40, max_feature_dim=8, seed=1)
    empty = Graph(indptr=np.zeros(1, np.int64), indices=np.zeros(0, np.int32),
                  num_nodes=0)
    b = make_dataset("cora", max_nodes=30, max_feature_dim=8, seed=2)
    u = disjoint_union([a, empty, b])
    assert u.num_nodes == a.num_nodes + b.num_nodes
    assert u.num_edges == a.num_edges + b.num_edges
    validate(u)
