"""PAPER_DATASETS calibration + on-disk dataset cache.

Table 4's statistics are what the synthetic regeneration is calibrated to:
mean degree within tolerance, heavy-tailed hubs (max ≫ mean — the property
the event-driven flow exploits), and determinism in ``seed``. Previously
exercised only indirectly through the simulator benches.
"""
import numpy as np
import pytest

from repro.graphs.csr import validate
from repro.graphs.datasets import (
    PAPER_DATASETS,
    dataset_cache_dir,
    make_dataset,
    make_lognormal_graph,
)

# Size caps keep the big graphs CPU-cheap; the generator draws per-node
# degrees i.i.d. from the calibrated lognormal, so a prefix-sized graph
# targets the same mean degree as the full one.
_CAPS = {"cora": None, "citeseer": None, "pubmed": None,
         "flickr": 30_000, "reddit": 20_000, "yelp": 30_000}


@pytest.mark.parametrize("name", sorted(PAPER_DATASETS))
def test_mean_degree_matches_table4(name):
    spec = PAPER_DATASETS[name]
    g = make_dataset(name, max_nodes=_CAPS[name], with_features=False, seed=0)
    validate(g)
    # Dedup + self-loop removal shave a little off the raw target; the
    # realized mean must still sit within 12% of the published figure.
    assert g.mean_degree == pytest.approx(spec.mean_degree, rel=0.12)


@pytest.mark.parametrize("name", sorted(PAPER_DATASETS))
def test_degree_distribution_has_hubs(name):
    """Heavy tail: the hottest node's degree dwarfs the mean — the skew that
    makes double-buffered batching pay max-degree padding per batch."""
    g = make_dataset(name, max_nodes=_CAPS[name], with_features=False, seed=0)
    deg = g.degrees
    assert deg.min() >= 1
    assert deg.max() >= 8 * g.mean_degree


@pytest.mark.parametrize("name", ["cora", "reddit"])
def test_deterministic_in_seed(name):
    cap = _CAPS[name] and min(_CAPS[name], 10_000)
    a = make_dataset(name, max_nodes=cap, seed=7)
    b = make_dataset(name, max_nodes=cap, seed=7)
    c = make_dataset(name, max_nodes=cap, seed=8)
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.features, b.features)
    assert not (
        a.indices.shape == c.indices.shape and np.array_equal(a.indices, c.indices)
    )


def test_feature_matrix_matches_spec_shape():
    spec = PAPER_DATASETS["pubmed"]
    g = make_dataset("pubmed", seed=0)
    assert g.features.shape == (spec.num_nodes, spec.feature_dim)
    assert g.features.dtype == np.float32


# --------------------------------------------------------- on-disk cache
def test_cache_roundtrip_bitwise(tmp_path):
    direct = make_dataset("cora", max_nodes=1_000, seed=3, cache_dir=None)
    first = make_dataset("cora", max_nodes=1_000, seed=3, cache_dir=str(tmp_path))
    cached = make_dataset("cora", max_nodes=1_000, seed=3, cache_dir=str(tmp_path))
    assert list(tmp_path.glob("cora-*.npz"))  # structure landed on disk
    for g in (first, cached):
        np.testing.assert_array_equal(g.indptr, direct.indptr)
        np.testing.assert_array_equal(g.indices, direct.indices)
        np.testing.assert_array_equal(g.features, direct.features)
        assert g.name == direct.name


def test_cache_key_separates_spec_and_seed(tmp_path):
    make_dataset("cora", max_nodes=500, seed=0, cache_dir=str(tmp_path))
    make_dataset("cora", max_nodes=500, seed=1, cache_dir=str(tmp_path))
    make_dataset("citeseer", max_nodes=500, seed=0, cache_dir=str(tmp_path))
    assert len(list(tmp_path.glob("*.npz"))) == 3


def test_cache_env_var_controls_default(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_DATASET_CACHE", raising=False)
    assert dataset_cache_dir() is None
    make_dataset("cora", max_nodes=200, seed=0)  # no cache dir -> no writes
    monkeypatch.setenv("REPRO_DATASET_CACHE", str(tmp_path))
    assert dataset_cache_dir() == str(tmp_path)
    g = make_dataset("cora", max_nodes=200, seed=0)
    assert list(tmp_path.glob("cora-*.npz"))
    again = make_dataset("cora", max_nodes=200, seed=0)
    np.testing.assert_array_equal(g.indices, again.indices)


def test_lognormal_generator_hits_edge_target():
    g = make_lognormal_graph(5_000, 12.0, seed=0)
    validate(g)
    assert g.mean_degree == pytest.approx(12.0, rel=0.1)
