"""SSD intra-chunk Pallas kernel vs oracle vs the mamba layer einsums."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ops
from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref


@pytest.mark.parametrize("b,nc,q,n,h,p", [
    (1, 2, 16, 8, 2, 8),
    (2, 3, 32, 16, 4, 16),
    (1, 1, 64, 32, 1, 32),
])
def test_kernel_matches_oracle(b, nc, q, n, h, p):
    ks = jax.random.split(jax.random.PRNGKey(q * h), 4)
    cc = jax.random.normal(ks[0], (b, nc, q, n))
    bc = jax.random.normal(ks[1], (b, nc, q, n))
    xdt = jax.random.normal(ks[2], (b, nc, h, q, p))
    # realistic decreasing log-decay (negative cumsum)
    acum = -jnp.cumsum(jax.random.uniform(ks[3], (b, nc, h, q)), axis=-1)
    out = ops.ssd_intra_chunk(cc, bc, xdt, acum)
    ref = ssd_intra_chunk_ref(cc, bc, xdt, acum)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_matches_mamba_layer_term():
    """The kernel computes exactly mamba_apply's y_diag einsum (layout match)."""
    b, nc, q, n, h, p = 1, 2, 8, 4, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    cc = jax.random.normal(ks[0], (b, nc, q, n))
    bc = jax.random.normal(ks[1], (b, nc, q, n))
    xdt = jax.random.normal(ks[2], (b, nc, q, h, p))  # mamba layout [.., Q, H, P]
    adt = -jax.random.uniform(ks[3], (b, nc, q, h))
    acum = jnp.cumsum(adt, axis=2)
    # mamba_apply's formulation
    li = acum[:, :, :, None, :] - acum[:, :, None, :, :]
    iota = jnp.arange(q)
    lmat = jnp.where((iota[:, None] >= iota[None, :])[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    y_ref = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, lmat, xdt)
    # kernel layout [B,NC,H,Q,P] / acum [B,NC,H,Q]
    out = ops.ssd_intra_chunk(cc, bc, xdt.transpose(0, 1, 3, 2, 4),
                              acum.transpose(0, 1, 3, 2))
    np.testing.assert_allclose(
        np.asarray(out.transpose(0, 1, 3, 2, 4)), np.asarray(y_ref),
        atol=1e-4, rtol=1e-4,
    )
