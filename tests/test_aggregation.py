"""All aggregation paths agree with the dense oracle (property-tested)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, st

from repro.core import (
    build_bucket_plan,
    build_edge_tile_plan,
    build_mixed_precision_plans,
    build_padded_plan,
)
from repro.core.aggregation import (
    aggregate_bucket_plan,
    aggregate_edge_tiles,
    aggregate_mixed_precision,
    aggregate_padded_plan,
    dense_reference,
    to_device_plan,
)
from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags
from repro.graphs.csr import gcn_norm_coeffs
from repro.graphs.datasets import make_lognormal_graph


def _setup(n, md, d, seed, coeff=None):
    g = make_lognormal_graph(n, md, seed=seed)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    a = g.dense_adjacency()
    if coeff is not None:
        rows = np.repeat(np.arange(n), g.degrees)
        a = np.zeros_like(a)
        a[rows, g.indices] = coeff
    return g, x, a


@given(
    n=st.integers(2, 60),
    md=st.floats(1.0, 8.0),
    d=st.sampled_from([1, 7, 32]),
    ept=st.sampled_from([16, 64]),
    seed=st.integers(0, 500),
)
def test_edge_tiles_match_dense(n, md, d, ept, seed):
    g, x, a = _setup(n, md, d, seed)
    plan = build_edge_tile_plan(g, edges_per_tile=ept)
    out = aggregate_edge_tiles(
        x,
        to_device_plan(plan),
        num_nodes=n,
        segments_per_tile=plan.segments_per_tile,
    )
    np.testing.assert_allclose(out, dense_reference(x, a), atol=1e-4, rtol=1e-4)


@given(n=st.integers(2, 50), seed=st.integers(0, 300))
def test_gcn_coeff_tiles_match_dense(n, seed):
    g = make_lognormal_graph(n, 4.0, seed=seed)
    coeff = gcn_norm_coeffs(g)
    g2, x, a = _setup(n, 4.0, 9, seed, coeff=coeff)
    plan = build_edge_tile_plan(g, edges_per_tile=32, coeff=coeff)
    out = aggregate_edge_tiles(
        x, to_device_plan(plan), num_nodes=n, segments_per_tile=plan.segments_per_tile
    )
    np.testing.assert_allclose(out, dense_reference(x, a), atol=1e-4, rtol=1e-4)


@given(n=st.integers(2, 50), op=st.sampled_from(["sum", "mean", "max"]), seed=st.integers(0, 300))
def test_bucket_plan_ops(n, op, seed):
    g, x, a = _setup(n, 4.0, 8, seed)
    plan = build_bucket_plan(g)
    out = aggregate_bucket_plan(x, plan, op=op)
    xn = np.asarray(x)
    want = np.zeros((n, 8), np.float32)
    for i in range(n):
        nb = g.neighbors(i)
        if nb.size == 0:
            continue
        if op == "sum":
            want[i] = xn[nb].sum(0)
        elif op == "mean":
            want[i] = xn[nb].mean(0)
        else:
            want[i] = xn[nb].max(0)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


@given(n=st.integers(2, 50), bs=st.sampled_from([4, 16, 64]), seed=st.integers(0, 300))
def test_padded_plan_matches_dense(n, bs, seed):
    g, x, a = _setup(n, 4.0, 8, seed)
    plan = build_padded_plan(g, batch_size=bs)
    out = aggregate_padded_plan(x, plan)
    np.testing.assert_allclose(out, dense_reference(x, a), atol=1e-4, rtol=1e-4)


def test_mixed_precision_close_to_float():
    g, x, a = _setup(200, 5.0, 16, 42)
    tags = inference_precision_tags(g, DegreeQuantConfig(float_ratio=0.03))
    plans = build_mixed_precision_plans(g, tags)
    out = aggregate_mixed_precision(x, plans, num_nodes=200)
    ref = np.asarray(dense_reference(x, a))
    rel = np.abs(np.asarray(out) - ref).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.05  # int8 path bounded error
    # protected (hub) rows are exact float
    fl = plans["float"].node_ids
    np.testing.assert_allclose(np.asarray(out)[fl], ref[fl], atol=1e-4, rtol=1e-4)
