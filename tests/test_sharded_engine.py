"""Partition-aware execution: sharded planner, engine, serving and persistence.

Acceptance criteria of the sharded refactor:
  * sharded outputs == unsharded outputs within float tolerance for
    num_shards ∈ {1, 2, 4}, every arch, mixed precision on;
  * num_shards=1 reduces to the existing single-plan path;
  * repeat sharded traffic is a plan-cache hit (plan_ms == 0.0, bitwise
    identical outputs);
  * plans (sharded and not) round-trip through checkpoint/plan_store and
    warm-start a restarted serve engine.
Plus regression tests for the engine-level satellites: the weight-quant
cache id-reuse fix and the static activation scale/zp state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import (
    AmpleEngine,
    EngineConfig,
    compile_plans,
    compile_sharded_plans,
)
from repro.distributed.graph_shard import ShardedAmpleEngine, sharded_aggregate
from repro.graphs import make_dataset, partition_by_edges
from repro.graphs.partition import Partition
from repro.models.gnn import api as gnn_api
from repro.serve.gnn_engine import GNNRequest, GNNServeEngine

ARCHS = ["gcn", "gin", "sage"]


def _cfg(arch, *, precision="mixed"):
    return dataclasses.replace(
        get_config(f"ample-{arch}", reduced=True),
        d_model=20, d_ff=12, vocab_size=6, gnn_precision=precision,
        gnn_edges_per_tile=64,
    )


@pytest.fixture(scope="module")
def graph():
    return make_dataset("cora", max_nodes=160, max_feature_dim=20, seed=2)


# ----------------------------------------------------------- sharded planner
def test_sharded_plan_fingerprints_stable_and_distinct(graph):
    cfg = EngineConfig(edges_per_tile=64)
    a = compile_sharded_plans(graph, cfg, num_shards=3, modes=("gcn",))
    b = compile_sharded_plans(graph, cfg, num_shards=3, modes=("gcn",))
    assert a.fingerprint == b.fingerprint and a == b and hash(a) == hash(b)
    assert [s.fingerprint for s in a.shards] == [s.fingerprint for s in b.shards]
    c = compile_sharded_plans(graph, cfg, num_shards=4, modes=("gcn",))
    assert c.fingerprint != a.fingerprint
    d = compile_sharded_plans(graph, cfg, num_shards=3, modes=("sum",))
    assert d.fingerprint != a.fingerprint
    assert len({s.fingerprint for s in a.shards}) == 3  # per-shard identity


def test_sharded_plan_shape_invariants(graph):
    splan = compile_sharded_plans(graph, EngineConfig(edges_per_tile=64),
                                  num_shards=4, modes=("sum",))
    assert splan.num_shards == 4
    assert sum(s.num_owned for s in splan.shards) == graph.num_nodes
    assert sum(s.num_edges for s in splan.shards) == graph.num_edges
    assert splan.edge_balance >= 1.0
    assert splan.halo_total == sum(s.halo_size for s in splan.shards)
    # global tags sliced into local tag arrays (owned prefix)
    for s in splan.shards:
        np.testing.assert_array_equal(
            s.plan.precision_tags[: s.num_owned],
            splan.precision_tags[s.shard.lo : s.shard.hi],
        )


def test_sharded_aggregate_matches_unsharded(graph):
    cfg = EngineConfig(edges_per_tile=64, mixed_precision=True)
    eng = AmpleEngine(graph, cfg)
    x = jnp.asarray(graph.features)
    ref = np.asarray(eng.aggregate(x, mode="gcn"))
    from repro.core.quantization import compute_scale_zp

    qp = compute_scale_zp(x, symmetric=True)
    splan = compile_sharded_plans(graph, cfg, num_shards=3, modes=("gcn",))
    out = np.asarray(sharded_aggregate(x, splan, mode="gcn", qp=qp))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_sharded_engine_rejects_mismatched_graph(graph):
    cfg = EngineConfig(edges_per_tile=64)
    splan = compile_sharded_plans(graph, cfg, num_shards=2, modes=("sum",))
    other = make_dataset("cora", max_nodes=90, max_feature_dim=20, seed=7)
    with pytest.raises(ValueError, match="different graph structure"):
        ShardedAmpleEngine(other, splan)


def test_sharded_engine_rejects_unknown_mode(graph):
    splan = compile_sharded_plans(graph, EngineConfig(edges_per_tile=64),
                                  num_shards=2, modes=("sum",))
    eng = ShardedAmpleEngine(graph, splan)
    with pytest.raises(KeyError, match="recompile"):
        eng.aggregate(jnp.asarray(graph.features), mode="gcn")


# -------------------------------------------------- acceptance: serve parity
@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_sharded_serving_matches_unsharded(arch, num_shards, graph):
    """Acceptance: sharded GNNServeEngine == unsharded, mixed precision on."""
    cfg = _cfg(arch, precision="mixed")
    base = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref = base.infer(graph, graph.features)
    eng = GNNServeEngine(cfg, base.params, num_shards=num_shards)
    r = eng.infer(graph, graph.features)
    assert r.num_shards == num_shards if num_shards > 1 else r.num_shards == 1
    np.testing.assert_allclose(r.outputs, ref.outputs, atol=5e-4, rtol=1e-4)


def test_num_shards_one_is_the_single_plan_path(graph):
    """num_shards=1 must reduce to the existing unsharded engine exactly."""
    cfg = _cfg("gcn")
    base = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    eng = GNNServeEngine(cfg, base.params, num_shards=1)
    assert not eng.sharded
    r = eng.infer(graph, graph.features)
    ref = base.infer(graph, graph.features)
    np.testing.assert_array_equal(r.outputs, ref.outputs)
    assert r.fingerprint == ref.fingerprint  # same cache key, same plan
    (_, plan, engine), = list(eng._cache.values())
    assert not isinstance(engine, ShardedAmpleEngine)


def test_sharded_plan_cache_hit_bitwise(graph):
    """Acceptance: warm sharded request — cache_hit, plan_ms == 0.0, bitwise."""
    cfg = _cfg("gin")
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(1), num_shards=3)
    r1 = eng.infer(graph, graph.features)
    r2 = eng.infer(graph, graph.features)
    assert not r1.cache_hit and r2.cache_hit
    assert r1.plan_ms > 0.0 and r2.plan_ms == 0.0
    assert r1.fingerprint == r2.fingerprint
    np.testing.assert_array_equal(r1.outputs, r2.outputs)
    assert eng.stats["planner_calls"] == 3  # one per shard, once ever
    rep = eng.shard_report()
    assert rep is not None and rep["num_shards"] == 3
    assert GNNServeEngine(cfg).shard_report() is None  # nothing cached yet


def test_per_shard_cache_reuse_across_assembled_entries(graph):
    """Shards live in their own LRU: a re-assembled plan reuses warm shards."""
    cfg = _cfg("gin")
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(1), num_shards=2)
    eng.infer(graph, graph.features)
    assert eng.stats["planner_calls"] == 2
    # drop only the assembled entry; the per-shard LRU stays warm
    eng._cache.clear()
    r = eng.infer(graph, graph.features)
    assert eng.stats["planner_calls"] == 2  # no shard recompiled
    assert eng.stats["shard_hits"] == 2
    assert r.cache_hit and r.plan_ms == 0.0


def test_explicit_partition_knob(graph):
    cfg = _cfg("gcn")
    prepared = gnn_api.prepare_graph(cfg, graph)
    part = partition_by_edges(prepared, 2)
    base = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    eng = GNNServeEngine(cfg, base.params, partition=part)
    assert eng.num_shards == 2 and eng.sharded
    r = eng.infer(graph, graph.features)
    ref = base.infer(graph, graph.features)
    np.testing.assert_allclose(r.outputs, ref.outputs, atol=5e-4, rtol=1e-4)
    # a partition that does not cover the prepared graph is rejected
    bad = GNNServeEngine(
        cfg, base.params,
        partition=Partition(starts=np.asarray([0, 10, prepared.num_nodes - 1])),
    )
    with pytest.raises(ValueError, match="span"):
        bad.infer(graph, graph.features)


def test_sharded_batch_matches_individual(graph):
    # float precision: batching is exact there (mixed batches share int8
    # activation scales batch-wide, the documented granularity trade-off)
    cfg = _cfg("sage", precision="float")
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(3), num_shards=2)
    g2 = make_dataset("cora", max_nodes=70, max_feature_dim=20, seed=9)
    reqs = [GNNRequest(graph=graph, features=graph.features),
            GNNRequest(graph=g2, features=g2.features)]
    batched = eng.infer_batch(reqs)
    second = eng.infer_batch(reqs)
    assert not batched[0].cache_hit and second[0].cache_hit
    for a, b in zip(batched, second):
        np.testing.assert_array_equal(a.outputs, b.outputs)
    solo_eng = GNNServeEngine(cfg, eng.params)
    for g_, r in zip((graph, g2), batched):
        solo = solo_eng.infer(g_, g_.features)
        np.testing.assert_allclose(r.outputs, solo.outputs, atol=1e-4, rtol=1e-4)


def test_model_forward_with_cfg_num_shards(graph):
    """cfg.gnn_num_shards threads the sharded engine through model_forward."""
    from repro.models.api import model_forward, model_init

    cfg = _cfg("gcn")
    params = model_init(cfg, jax.random.PRNGKey(0))
    y_ref, _ = model_forward(params, cfg, {"graph": graph, "features": graph.features})
    cfg_sh = dataclasses.replace(cfg, gnn_num_shards=3)
    y_sh, _ = model_forward(params, cfg_sh, {"graph": graph, "features": graph.features})
    np.testing.assert_allclose(
        np.asarray(y_sh), np.asarray(y_ref), atol=5e-4, rtol=1e-4
    )


# ------------------------------------------------- satellite: weight-q cache
def test_weight_q_cache_survives_id_reuse(graph):
    """id() of a dead array can be recycled; the cache must not serve the old
    quantized weights for a new array that happens to alias the id."""
    eng = AmpleEngine(graph, EngineConfig(edges_per_tile=64, mixed_precision=True))
    w = jnp.asarray(np.random.default_rng(0).standard_normal((20, 6)), jnp.float32)
    w_q, w_qp, _ = eng._weight_q(w)
    entry = eng._wq_cache[id(w)]
    assert entry[0] is w  # strong ref pins the id for the cache's lifetime
    # simulate CPython id reuse: a stale entry left under this array's id
    w2 = jnp.asarray(np.random.default_rng(1).standard_normal((20, 6)), jnp.float32)
    eng._wq_cache[id(w2)] = (object(), "stale_q", "stale_qp", None)
    w2_q, w2_qp, _ = eng._weight_q(w2)
    assert not isinstance(w2_q, str), "stale entry served for a recycled id"
    np.testing.assert_array_equal(
        np.asarray(w2_q),
        np.asarray(__import__("repro.core.quantization", fromlist=["x"]).quantize_per_channel(w2, axis=-1)[0]),
    )
    # repeated lookups of the live weight stay cached (same objects)
    again_q, again_qp, _ = eng._weight_q(w)
    assert again_q is w_q and again_qp is w_qp


def test_weight_q_cache_is_bounded(graph):
    """Feeding ever-fresh weight arrays (a training loop) must not grow the
    weight-quant cache without limit — LRU eviction bounds it."""
    eng = AmpleEngine(graph, EngineConfig(edges_per_tile=64, mixed_precision=True))
    for i in range(eng._WQ_CACHE_CAP + 20):
        w = jnp.full((20, 6), float(i % 7) + 1.0, jnp.float32)
        eng._weight_q(w)
    assert len(eng._wq_cache) <= eng._WQ_CACHE_CAP


# -------------------------------------- satellite: static activation scale/zp
def test_warm_requests_skip_activation_calibration(graph, monkeypatch):
    """Cold request calibrates int8 scale/zp once per call site; warm cache
    hits reuse that static state — compute_scale_zp must not run again."""
    import repro.core.aggregation as agg_mod
    import repro.core.message_passing as mp_mod

    calls = {"n": 0}
    real = mp_mod.compute_scale_zp

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(mp_mod, "compute_scale_zp", counting)
    monkeypatch.setattr(agg_mod, "compute_scale_zp", counting)

    cfg = _cfg("gcn", precision="mixed")
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    cold = eng.infer(graph, graph.features)
    assert calls["n"] > 0  # cold request calibrated
    calls["n"] = 0
    warm = eng.infer(graph, graph.features)
    assert calls["n"] == 0, "warm cache hit recomputed activation scale/zp"
    assert warm.cache_hit
    np.testing.assert_array_equal(cold.outputs, warm.outputs)


def test_engine_reuse_across_trace_and_eager(graph):
    """Static quant state must not capture tracers: an engine used inside
    jit/grad (training) and then eagerly (serving/eval) keeps working."""
    eng = AmpleEngine(graph, EngineConfig(edges_per_tile=64, mixed_precision=True))
    x = jnp.asarray(graph.features)

    def loss(x_):
        eng.begin_forward()
        return eng.aggregate(x_, mode="sum").sum()

    g1 = jax.grad(loss)(x)  # traced use: nothing traced may persist
    assert np.isfinite(np.asarray(g1)).all()
    eng.begin_forward()
    y = eng.aggregate(x, mode="sum")  # eager reuse after the trace
    assert np.isfinite(np.asarray(y)).all()
    y2 = jax.jit(lambda x_: eng.aggregate(x_, mode="sum"))(x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y), atol=1e-5)


def test_direct_engine_use_keeps_dynamic_calibration(graph):
    """Without begin_forward, aggregate stays per-call dynamic (no stale qp)."""
    eng = AmpleEngine(graph, EngineConfig(edges_per_tile=64, mixed_precision=True))
    x1 = jnp.asarray(graph.features)
    x2 = x1 * 7.5  # very different range
    a = np.asarray(eng.aggregate(x1, mode="sum"))
    b = np.asarray(eng.aggregate(x2, mode="sum"))
    # dynamic calibration scales with the input: b uses x2's own range
    np.testing.assert_allclose(b, a * 7.5, rtol=5e-2, atol=5e-2)
    assert not eng._act_qp  # no static slots were populated


# ------------------------------------------------ satellite: plan persistence
def test_plan_store_roundtrip_unsharded(graph, tmp_path):
    from repro.checkpoint.plan_store import load_plan, save_plan

    cfg = EngineConfig(edges_per_tile=64)
    plan = compile_plans(graph, cfg, modes=("gcn", "sum"))
    path = save_plan(str(tmp_path / "p.npz"), plan, graph=graph, extra={"k": "v"})
    rec = load_plan(path)
    assert rec.plan == plan and rec.plan.fingerprint == plan.fingerprint
    assert rec.extra == {"k": "v"}
    assert rec.plan.cfg == cfg
    np.testing.assert_array_equal(rec.graph.indptr, graph.indptr)
    np.testing.assert_array_equal(rec.plan.precision_tags, plan.precision_tags)
    for mode in ("gcn", "sum"):
        for tag, p in plan.mode_plans[mode].items():
            q = rec.plan.mode_plans[mode][tag]
            np.testing.assert_array_equal(p.gather_idx, q.gather_idx)
            np.testing.assert_array_equal(p.coeff, q.coeff)
            assert p.total_edges == q.total_edges


def test_plan_store_mmap_roundtrip(graph, tmp_path):
    """mmap_mode="r" loads the same plan with file-backed read-only arrays:
    values compare equal, in-place writes raise, and mutating a copy never
    reaches the file."""
    from repro.checkpoint.plan_store import _PLAN_ARRAYS, load_plan, save_plan

    cfg = EngineConfig(edges_per_tile=64)
    plan = compile_plans(graph, cfg, modes=("gcn", "sum"))
    path = save_plan(str(tmp_path / "m.npz"), plan, graph=graph, extra={"k": "v"})
    rec = load_plan(path, mmap_mode="r")
    assert rec.plan == plan and rec.extra == {"k": "v"}
    np.testing.assert_array_equal(rec.graph.indptr, graph.indptr)
    for mode in ("gcn", "sum"):
        for tag, p in plan.mode_plans[mode].items():
            q = rec.plan.mode_plans[mode][tag]
            for name in _PLAN_ARRAYS:
                a, b = getattr(p, name), getattr(q, name)
                np.testing.assert_array_equal(a, b)
                assert not b.flags.writeable
                with pytest.raises(ValueError):
                    b[...] = 0
                c = b.copy()
                c[...] = 0  # writable copy, detached from the file
    # nothing above reached the disk bytes: a fresh load still equals plan
    assert load_plan(path, mmap_mode="r").plan == plan
    # sharded files memmap too
    splan = compile_sharded_plans(graph, cfg, num_shards=2, modes=("sum",))
    spath = save_plan(str(tmp_path / "ms.npz"), splan)
    assert load_plan(spath, mmap_mode="r").plan == splan
    with pytest.raises(ValueError):
        load_plan(path, mmap_mode="r+")


def test_plan_store_roundtrip_sharded(graph, tmp_path):
    from repro.checkpoint.plan_store import load_plan, save_plan

    cfg = EngineConfig(edges_per_tile=64)
    splan = compile_sharded_plans(graph, cfg, num_shards=3, modes=("sum",))
    path = save_plan(str(tmp_path / "s.npz"), splan, graph=graph)
    rec = load_plan(path)
    assert rec.plan == splan
    assert rec.plan.partition_fp == splan.partition_fp
    np.testing.assert_array_equal(rec.plan.partition.starts, splan.partition.starts)
    for a, b in zip(rec.plan.shards, splan.shards):
        assert a.fingerprint == b.fingerprint
        np.testing.assert_array_equal(a.shard.halo, b.shard.halo)
        np.testing.assert_array_equal(a.shard.local_ids, b.shard.local_ids)
        np.testing.assert_array_equal(a.plan.precision_tags, b.plan.precision_tags)
    # the loaded plan executes: sharded aggregation equals the original's
    x = jnp.asarray(graph.features)
    eng_a = ShardedAmpleEngine(graph, splan)
    eng_b = ShardedAmpleEngine(rec.graph, rec.plan)
    np.testing.assert_array_equal(
        np.asarray(eng_a.aggregate(x, mode="sum")),
        np.asarray(eng_b.aggregate(x, mode="sum")),
    )


@pytest.mark.parametrize("num_shards", [1, 2])
def test_serve_engine_warm_start_from_disk(graph, tmp_path, num_shards):
    """Restarted engine warms its cache from disk: first request is a hit."""
    cfg = _cfg("gcn")
    a = GNNServeEngine(cfg, key=jax.random.PRNGKey(0), num_shards=num_shards)
    cold = a.infer(graph, graph.features)
    assert not cold.cache_hit
    a.save_plan_cache(str(tmp_path))

    b = GNNServeEngine(cfg, a.params, num_shards=num_shards)
    assert b.load_plan_cache(str(tmp_path)) == 1
    warm = b.infer(graph, graph.features)
    assert warm.cache_hit and warm.plan_ms == 0.0
    assert b.stats["planner_calls"] == 0
    np.testing.assert_array_equal(cold.outputs, warm.outputs)


# -------------------------------- min-cut partitioner + overlapped halo serve
@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("num_shards", [2, 4])
def test_mincut_serving_matches_unsharded(arch, num_shards, graph):
    """Acceptance: the halo-minimizing partitioner serves every arch with the
    same outputs as the unsharded engine (non-contiguous shards, edge_idx
    coefficient slicing)."""
    cfg = _cfg(arch, precision="mixed")
    base = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref = base.infer(graph, graph.features)
    eng = GNNServeEngine(
        cfg, base.params, num_shards=num_shards, partitioner="mincut"
    )
    r = eng.infer(graph, graph.features)
    assert r.num_shards == num_shards
    np.testing.assert_allclose(r.outputs, ref.outputs, atol=5e-4, rtol=1e-4)
    rep = eng.shard_report()
    assert rep["partitioner"].startswith("mincut(")


def test_partitioner_cache_keys_distinct(graph):
    """edges vs mincut plans must never collide in the serve cache."""
    cfg = _cfg("gcn")
    eng_a = GNNServeEngine(cfg, key=jax.random.PRNGKey(0), num_shards=2)
    eng_b = GNNServeEngine(
        cfg, eng_a.params, num_shards=2, partitioner="mincut"
    )
    ra = eng_a.infer(graph, graph.features)
    rb = eng_b.infer(graph, graph.features)
    assert ra.fingerprint != rb.fingerprint
    np.testing.assert_allclose(ra.outputs, rb.outputs, atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("partitioner", ["edges", "mincut"])
def test_halo_overlap_bitwise_vs_unsplit(graph, partitioner):
    """Acceptance: interior/boundary split execution is bitwise-identical to
    the unsplit scan — overlap must be a pure scheduling change."""
    cfg = EngineConfig(edges_per_tile=64, mixed_precision=True)
    from repro.graphs import make_partition

    part = make_partition(graph, 3, partitioner)
    splan = compile_sharded_plans(graph, cfg, partition=part, modes=("gcn",))
    x = jnp.asarray(graph.features)
    plain = ShardedAmpleEngine(graph, splan)
    split = ShardedAmpleEngine(graph, splan, halo_overlap=True)
    np.testing.assert_array_equal(
        np.asarray(plain.aggregate(x, mode="gcn")),
        np.asarray(split.aggregate(x, mode="gcn")),
    )
    assert split.halo_stats.get("halo_exchanges", 0) > 0
    assert split.halo_stats.get("halo_bytes", 0) > 0
    assert split.halo_stats.get("halo_ms", 0.0) >= 0.0


def test_halo_overlap_serving_and_response_fields(graph):
    """halo_* telemetry rides the response and reconciles with engine stats."""
    cfg = _cfg("gcn")
    base = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    ref = base.infer(graph, graph.features)
    eng = GNNServeEngine(
        cfg, base.params, num_shards=2, partitioner="mincut", halo_overlap=True
    )
    r = eng.infer(graph, graph.features)
    np.testing.assert_allclose(r.outputs, ref.outputs, atol=5e-4, rtol=1e-4)
    assert r.halo_bytes > 0 and r.halo_ms >= 0.0
    assert 0.0 <= r.halo_overlap <= 1.0
    info = eng.cache_info()
    assert info["halo_exchanges"] > 0
    assert info["halo_bytes"] >= r.halo_bytes
    assert 0.0 <= info["halo_overlap"] <= 1.0
    # unsharded requests carry no halo telemetry
    assert ref.halo_bytes == 0 and ref.halo_overlap == 0.0


def test_halo_overlap_rejects_kernel_path(graph):
    cfg = dataclasses.replace(_cfg("gcn"), gnn_use_kernel=True)
    with pytest.raises(ValueError, match="mutually exclusive"):
        GNNServeEngine(
            cfg, key=jax.random.PRNGKey(0), num_shards=2, halo_overlap=True
        )
    ecfg = EngineConfig(edges_per_tile=64, use_kernel=True)
    splan = compile_sharded_plans(graph, ecfg, num_shards=2, modes=("sum",))
    with pytest.raises(ValueError, match="gnn_halo_overlap"):
        ShardedAmpleEngine(graph, splan, halo_overlap=True)


def test_mesh_size_mismatch_rejected_at_construction(graph):
    """--num-shards must match the mesh: fail at engine construction with a
    message naming the flags, not deep inside shard_map."""
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("shard",))
    with pytest.raises(ValueError, match="--num-shards"):
        GNNServeEngine(
            _cfg("gcn"), key=jax.random.PRNGKey(0), num_shards=2, mesh=mesh
        )


def test_plan_store_roundtrip_mincut(graph, tmp_path):
    """Non-contiguous partitions persist: kind, order and edge_idx survive."""
    from repro.checkpoint.plan_store import load_plan, save_plan
    from repro.graphs import make_partition

    cfg = EngineConfig(edges_per_tile=64)
    part = make_partition(graph, 3, "mincut", seed=4)
    splan = compile_sharded_plans(graph, cfg, partition=part, modes=("sum",))
    path = save_plan(str(tmp_path / "mc.npz"), splan, graph=graph)
    rec = load_plan(path)
    assert rec.plan == splan
    assert rec.plan.partition.kind == part.kind
    assert rec.plan.partition_fp == splan.partition_fp
    np.testing.assert_array_equal(rec.plan.partition.order, part.order)
    for a, b in zip(rec.plan.shards, splan.shards):
        assert a.fingerprint == b.fingerprint
        if b.shard.edge_idx is not None:
            np.testing.assert_array_equal(a.shard.edge_idx, b.shard.edge_idx)
        np.testing.assert_array_equal(a.shard.local_ids, b.shard.local_ids)
    x = jnp.asarray(graph.features)
    np.testing.assert_array_equal(
        np.asarray(ShardedAmpleEngine(graph, splan).aggregate(x, mode="sum")),
        np.asarray(ShardedAmpleEngine(rec.graph, rec.plan).aggregate(x, mode="sum")),
    )
