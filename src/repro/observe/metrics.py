"""Process-wide labeled metrics registry with a Prometheus-style dump.

One registry holds every serving counter/gauge/histogram, keyed by metric
name + label values — the single sink the engines' historical ``stats``
dicts now feed. Three metric kinds:

- **counter / gauge** — a single float cell (:class:`MetricValue`). The
  distinction is exposition-only (``# TYPE``): counters are monotonically
  increasing by convention, gauges move both ways.
- **histogram** — a :class:`repro.serve.telemetry.StreamingHistogram`
  child per label set (O(1) memory, bounded relative quantile error).
  Existing histogram objects can be *adopted* via
  :meth:`MetricsRegistry.register_histogram`, so ``TenantTelemetry``'s
  per-tenant latency histograms appear in the registry dump without a
  second copy being maintained.

:class:`StatsView` is the compatibility bridge: a ``MutableMapping`` with
the exact shape and value semantics of the old ad-hoc ``stats`` dicts
(integer counters read back as ``int``; keys listed in ``float_keys`` stay
``float``) whose storage *is* registry cells. ``engine.stats["cache_hits"]``
and the Prometheus dump can never disagree because they read the same cell.
"""
from __future__ import annotations

import itertools
import threading
from collections.abc import MutableMapping
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

__all__ = [
    "MetricValue",
    "MetricFamily",
    "MetricsRegistry",
    "StatsView",
    "get_registry",
    "set_registry",
    "next_instance",
]


class MetricValue:
    """One counter/gauge cell. Mutations are GIL-atomic float ops."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def set(self, value: float) -> None:
        self.value = float(value)

    def __repr__(self) -> str:
        return f"MetricValue({self.value!r})"


class MetricFamily:
    """All children of one metric name, one child per label-value tuple."""

    def __init__(
        self,
        name: str,
        kind: str,
        help: str,
        label_names: Tuple[str, ...],
        make_child,
    ):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        self._make_child = make_child
        self._children: Dict[Tuple[str, ...], Any] = {}
        self._lock = threading.Lock()

    def labels(self, **labels: Any) -> Any:
        """The child cell for this label set (created on first touch)."""
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(labels))}"
            )
        key = tuple(str(labels[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    def adopt(self, child: Any, **labels: Any) -> Any:
        """Install an externally-owned child object for a label set."""
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(labels))}"
            )
        key = tuple(str(labels[n]) for n in self.label_names)
        with self._lock:
            self._children[key] = child
        return child

    def samples(self) -> List[Tuple[Dict[str, str], Any]]:
        with self._lock:
            items = list(self._children.items())
        return [
            (dict(zip(self.label_names, key)), child) for key, child in items
        ]


def _fmt_value(v: float) -> str:
    # Prometheus text format: integers without a trailing .0 read cleaner.
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class MetricsRegistry:
    """Name → :class:`MetricFamily` map with text/dict exports."""

    def __init__(self) -> None:
        self._families: Dict[str, MetricFamily] = {}
        self._lock = threading.RLock()

    # ------------------------------------------------------------ register
    def _family(
        self,
        name: str,
        kind: str,
        help: str,
        labels: Iterable[str],
        make_child,
    ) -> MetricFamily:
        label_names = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = MetricFamily(name, kind, help, label_names, make_child)
                self._families[name] = fam
                return fam
        if fam.kind != kind or fam.label_names != label_names:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.label_names}; asked for {kind} {label_names}"
            )
        return fam

    def counter(
        self, name: str, help: str = "", labels: Iterable[str] = ()
    ) -> MetricFamily:
        return self._family(name, "counter", help, labels, MetricValue)

    def gauge(
        self, name: str, help: str = "", labels: Iterable[str] = ()
    ) -> MetricFamily:
        return self._family(name, "gauge", help, labels, MetricValue)

    def histogram(
        self,
        name: str,
        help: str = "",
        labels: Iterable[str] = (),
        rel_error: float = 0.025,
    ) -> MetricFamily:
        # Lazy import: observe sits below serve in the layering; only the
        # histogram kind reaches up for the shared implementation.
        from repro.serve.telemetry import StreamingHistogram

        return self._family(
            name,
            "histogram",
            help,
            labels,
            lambda: StreamingHistogram(rel_error=rel_error),
        )

    def register_histogram(
        self, name: str, hist: Any, help: str = "", **labels: Any
    ) -> Any:
        """Adopt an existing ``StreamingHistogram`` as a registry child."""
        fam = self._family(
            name, "histogram", help, tuple(sorted(labels)), lambda: None
        )
        return fam.adopt(hist, **labels)

    # --------------------------------------------------------------- query
    def get(self, name: str) -> Optional[MetricFamily]:
        with self._lock:
            return self._families.get(name)

    def families(self) -> List[MetricFamily]:
        with self._lock:
            return [self._families[n] for n in sorted(self._families)]

    def clear(self) -> None:
        with self._lock:
            self._families.clear()

    # -------------------------------------------------------------- export
    def snapshot(self) -> Dict[str, Any]:
        """Everything as plain dicts (histograms via their snapshot())."""
        out: Dict[str, Any] = {}
        for fam in self.families():
            rows = []
            for labels, child in fam.samples():
                if fam.kind == "histogram":
                    value = child.snapshot() if child is not None else {}
                else:
                    value = child.value
                rows.append({"labels": labels, "value": value})
            out[fam.name] = {"kind": fam.kind, "samples": rows}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (histograms as quantile summaries)."""
        lines: List[str] = []
        for fam in self.families():
            samples = fam.samples()
            if not samples:
                continue
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            kind = "summary" if fam.kind == "histogram" else fam.kind
            lines.append(f"# TYPE {fam.name} {kind}")
            for labels, child in samples:
                if fam.kind == "histogram":
                    if child is None or child.count == 0:
                        continue
                    for q in (0.5, 0.9, 0.99):
                        ql = dict(labels)
                        ql["quantile"] = repr(q)
                        lines.append(
                            f"{fam.name}{_fmt_labels(ql)} "
                            f"{_fmt_value(child.percentile(q * 100))}"
                        )
                    lab = _fmt_labels(labels)
                    lines.append(
                        f"{fam.name}_sum{lab} {_fmt_value(child.total)}"
                    )
                    lines.append(f"{fam.name}_count{lab} {child.count}")
                else:
                    lines.append(
                        f"{fam.name}{_fmt_labels(labels)} "
                        f"{_fmt_value(child.value)}"
                    )
        return "\n".join(lines) + ("\n" if lines else "")


class StatsView(MutableMapping):
    """Dict-shaped live view over registry counter cells.

    The engines' historical ``stats`` dicts (``self.stats["cache_hits"] +=
    1``, ``cache_info()`` merges, exact-value test assertions) keep working
    unchanged, but the storage is the registry: key ``k`` reads/writes the
    cell of metric ``{prefix}_{k}`` under this view's label set. Values
    read back as ``int`` unless the key is in ``float_keys`` — the old
    dicts held ints for counters and floats for the ``*_ms`` accumulators,
    and tests assert on that distinction.
    """

    def __init__(
        self,
        registry: MetricsRegistry,
        prefix: str,
        labels: Dict[str, str],
        keys: Iterable[str],
        float_keys: Iterable[str] = (),
    ):
        self._registry = registry
        self._prefix = prefix
        self._labels = dict(labels)
        self._float = frozenset(float_keys)
        self._cells: Dict[str, MetricValue] = {}
        for k in keys:
            self._cell(k)

    def _cell(self, key: str) -> MetricValue:
        cell = self._cells.get(key)
        if cell is None:
            fam = self._registry.counter(
                f"{self._prefix}_{key}", labels=tuple(sorted(self._labels))
            )
            cell = fam.labels(**self._labels)
            self._cells[key] = cell
        return cell

    def __getitem__(self, key: str):
        v = self._cells[key].value
        return v if key in self._float else int(v)

    def __setitem__(self, key: str, value) -> None:
        self._cell(key).value = float(value)

    def __delitem__(self, key: str) -> None:
        del self._cells[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._cells)

    def __len__(self) -> int:
        return len(self._cells)

    def __repr__(self) -> str:
        return repr(dict(self))


# ------------------------------------------------- module-level registry
_REGISTRY = MetricsRegistry()
_INSTANCE_COUNTERS: Dict[str, Any] = {}
_INSTANCE_LOCK = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every serving component records into."""
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _REGISTRY
    _REGISTRY = registry
    return registry


def next_instance(prefix: str) -> str:
    """A process-unique instance label (``gnn_serve-0``, ``gnn_serve-1``...).

    Engines label their registry cells with this so concurrent engine
    instances (common in tests) never alias each other's counters.
    """
    with _INSTANCE_LOCK:
        c = _INSTANCE_COUNTERS.get(prefix)
        if c is None:
            c = _INSTANCE_COUNTERS[prefix] = itertools.count()
        return f"{prefix}-{next(c)}"
