"""Observability: request tracing (Perfetto export) + unified metrics.

Two host-side facilities with zero accelerator-path footprint:

- :mod:`repro.observe.trace` — a thread-safe span recorder with a bounded
  ring buffer and Chrome-trace-event JSON export (loadable in Perfetto /
  ``chrome://tracing``). Disabled by default; the disabled hot path is a
  single attribute check returning a shared no-op span.
- :mod:`repro.observe.metrics` — a process-wide labeled metrics registry
  (counters, gauges, streaming histograms) with a Prometheus-style text
  dump. The serving engines' historical ``stats`` dicts are live views over
  this registry (:class:`repro.observe.metrics.StatsView`), so there is one
  copy of every counter.
"""
from repro.observe.trace import (  # noqa: F401
    NULL_SPAN,
    TraceRecorder,
    disable,
    enable,
    get_recorder,
    is_enabled,
    new_trace_id,
    set_recorder,
)
from repro.observe.metrics import (  # noqa: F401
    MetricsRegistry,
    StatsView,
    get_registry,
    set_registry,
)
