"""Thread-safe span recorder with Chrome-trace-event export.

The recorder collects *spans* — named ``[t0, t1)`` intervals stamped with
``time.perf_counter()`` — into a bounded ring buffer and exports them in the
Chrome trace-event JSON format, which loads directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``. Spans carry:

- ``lane``: the horizontal track the span renders on. Defaults to the
  recording thread's name, so context-manager spans nest naturally per
  thread; workers recording on behalf of a pipeline stage pass an explicit
  lane (e.g. the prefetcher's staging thread records on ``"copy"``).
- ``trace_id``: the per-request correlation id threaded through
  ``GNNRequest`` / ``GNNTicket`` / ``RoutedTicket`` / ``GNNResponse``, so
  one request's queue → plan → copy/stall → execute lifecycle can be
  filtered out of a busy timeline.

Design constraints (these are load-bearing for the serving hot path):

- **Disabled is free.** The module-level default recorder is disabled; call
  sites guard with ``rec.enabled`` and :meth:`TraceRecorder.span` returns a
  shared no-op singleton, so a disabled trace point costs one attribute
  read and no allocation.
- **One clock.** All stamps are ``time.perf_counter()`` — the same clock
  the serving stack uses for every lifecycle stamp and duration — so spans
  recorded from any thread land on a single consistent timeline and
  trace-derived sums reconcile with the reported ``*_ms`` fields.
- **Bounded.** The ring buffer (``collections.deque(maxlen=...)``) evicts
  the oldest spans; ``dropped`` reports how many were lost.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, NamedTuple, Optional


class Span(NamedTuple):
    """One recorded interval (times are raw ``perf_counter`` seconds)."""

    name: str
    cat: str
    lane: str
    trace_id: str
    t0: float
    t1: float
    args: Optional[Dict[str, Any]]

    @property
    def dur_ms(self) -> float:
        return (self.t1 - self.t0) * 1e3


class _NullSpan:
    """Shared no-op context manager for the disabled path (zero alloc)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **_kw) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager that stamps enter/exit and commits to the ring."""

    __slots__ = ("_rec", "name", "cat", "lane", "trace_id", "args", "t0")

    def __init__(self, rec, name, cat, lane, trace_id, args):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.lane = lane
        self.trace_id = trace_id
        self.args = args
        self.t0 = 0.0

    def __enter__(self) -> "_LiveSpan":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._rec.add_span(
            self.name,
            self.t0,
            time.perf_counter(),
            cat=self.cat,
            lane=self.lane,
            trace_id=self.trace_id,
            args=self.args,
        )
        return False

    def set(self, **kw) -> "_LiveSpan":
        """Attach args discovered mid-span (e.g. cache_hit after lookup)."""
        if self.args is None:
            self.args = {}
        self.args.update(kw)
        return self


class TraceRecorder:
    """Bounded, thread-safe span ring with Chrome-trace JSON export."""

    def __init__(self, capacity: int = 1 << 16, enabled: bool = True):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.epoch = time.perf_counter()
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._added = 0

    # ------------------------------------------------------------- record
    def span(
        self,
        name: str,
        *,
        cat: str = "",
        lane: Optional[str] = None,
        trace_id: str = "",
        args: Optional[Dict[str, Any]] = None,
    ):
        """Context manager recording ``[enter, exit)`` as one span."""
        if not self.enabled:
            return NULL_SPAN
        return _LiveSpan(self, name, cat, lane, trace_id, args)

    def add_span(
        self,
        name: str,
        t0: float,
        t1: float,
        *,
        cat: str = "",
        lane: Optional[str] = None,
        trace_id: str = "",
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """Record an interval from explicit ``perf_counter`` stamps.

        This is the after-the-fact form used when the duration was already
        measured for accounting (e.g. the prefetcher's fenced copy/stall
        timings) — recording the *same* stamps guarantees the trace
        reconciles with the reported ``*_ms`` sums by construction.
        """
        if not self.enabled:
            return
        if lane is None:
            lane = threading.current_thread().name
        with self._lock:
            self._added += 1
            self._ring.append(Span(name, cat, lane, trace_id, t0, t1, args))

    def add_instant(
        self,
        name: str,
        *,
        t: Optional[float] = None,
        cat: str = "",
        lane: Optional[str] = None,
        trace_id: str = "",
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """Record a zero-duration marker (admission, preemption, ...)."""
        if not self.enabled:
            return
        t0 = time.perf_counter() if t is None else t
        self.add_span(
            name, t0, t0, cat=cat, lane=lane, trace_id=trace_id, args=args
        )

    # -------------------------------------------------------------- query
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._ring)

    @property
    def dropped(self) -> int:
        with self._lock:
            return max(0, self._added - len(self._ring))

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._added = 0

    def total_ms(
        self, name: str, *, trace_id: Optional[str] = None
    ) -> float:
        """Sum of span durations matching ``name`` (and ``trace_id``)."""
        out = 0.0
        for s in self.spans():
            if s.name != name:
                continue
            if trace_id is not None and s.trace_id != trace_id:
                continue
            out += s.t1 - s.t0
        return out

    # ------------------------------------------------------------- export
    def chrome_trace(self) -> Dict[str, Any]:
        """The span ring as a Chrome trace-event JSON object.

        Each lane becomes a ``tid`` with a ``thread_name`` metadata record;
        spans become ``ph:"X"`` complete events with microsecond ``ts``
        (relative to the recorder's epoch) and ``dur``. Zero-duration spans
        export as ``ph:"i"`` instant events.
        """
        spans = self.spans()
        lanes: Dict[str, int] = {}
        for s in spans:
            if s.lane not in lanes:
                lanes[s.lane] = len(lanes)
        events: List[Dict[str, Any]] = []
        for lane, tid in lanes.items():
            events.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": 0,
                    "tid": tid,
                    "args": {"name": lane},
                }
            )
        for s in spans:
            args = dict(s.args) if s.args else {}
            if s.trace_id:
                args["trace_id"] = s.trace_id
            ev: Dict[str, Any] = {
                "name": s.name,
                "cat": s.cat or "span",
                "pid": 0,
                "tid": lanes[s.lane],
                "ts": (s.t0 - self.epoch) * 1e6,
            }
            if s.t1 > s.t0:
                ev["ph"] = "X"
                ev["dur"] = (s.t1 - s.t0) * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            if args:
                ev["args"] = args
            events.append(ev)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": self.dropped},
        }

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


# -------------------------------------------------- module-level recorder
_RECORDER = TraceRecorder(capacity=0, enabled=False)
_ID_COUNTER = itertools.count(1)


def get_recorder() -> TraceRecorder:
    """The process-wide recorder (disabled no-op unless :func:`enable`\\ d)."""
    return _RECORDER


def set_recorder(rec: TraceRecorder) -> TraceRecorder:
    global _RECORDER
    _RECORDER = rec
    return rec


def enable(capacity: int = 1 << 18) -> TraceRecorder:
    """Install a fresh enabled recorder and return it."""
    return set_recorder(TraceRecorder(capacity=capacity, enabled=True))


def disable() -> TraceRecorder:
    """Install a disabled recorder (the zero-overhead default)."""
    return set_recorder(TraceRecorder(capacity=0, enabled=False))


def is_enabled() -> bool:
    return _RECORDER.enabled


def new_trace_id() -> str:
    """A process-unique request correlation id (``req-000001``, ...)."""
    return f"req-{next(_ID_COUNTER):06d}"
