"""Sharded, atomic, async checkpointing — the fault-tolerance substrate.

Layout per step::

    <dir>/step_000123/
        manifest.json       tree structure, leaf dtypes/shapes, metadata
        leaf_00000.npy ...  one file per leaf (array_split over hosts at scale)

Properties a 1000-node deployment needs, all present here:
* **Atomicity** — written to ``step_X.tmp`` then renamed; a crash mid-write
  never corrupts the latest checkpoint (restore picks the newest complete dir).
* **Async** — ``save_async`` snapshots to host RAM synchronously (cheap) and
  writes to disk on a worker thread, so the train loop never blocks on IO.
* **Resharding on restore** — leaves are stored unsharded (numpy); restore
  device_puts against any target sharding, so the surviving cluster can have
  a different mesh than the writer (elastic restart).
* **Retention** — keep the newest K checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes  # noqa: F401 — registers bf16/fp8 dtypes with numpy
import numpy as np

# numpy cannot np.save/load extension dtypes faithfully; store them as
# same-width unsigned ints and restore via .view using the manifest dtype.
_EXT_DTYPES = {"bfloat16", "float8_e4m3fn", "float8_e5m2", "float16"}


def _to_storable(a: np.ndarray):
    if str(a.dtype) in _EXT_DTYPES or a.dtype.kind == "V":
        return a.view(np.dtype(f"u{a.dtype.itemsize}"))
    return a


def _from_storable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(a.dtype) != dtype_str:
        return a.view(np.dtype(dtype_str))
    return a

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

_PENDING: List[threading.Thread] = []


def _leaf_paths(tree) -> List[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        paths.append("/".join(parts))
    return paths


def save(state: Any, ckpt_dir: str, step: int, *, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    host_leaves = [np.asarray(x) for x in leaves]
    return _write(host_leaves, _leaf_paths(state), str(treedef), ckpt_dir, step, keep)


def save_async(state: Any, ckpt_dir: str, step: int, *, keep: int = 3) -> None:
    """Snapshot now (device→host copy), write on a background thread."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    host_leaves = [np.asarray(x) for x in leaves]  # synchronous snapshot
    paths = _leaf_paths(state)
    td = str(treedef)

    t = threading.Thread(
        target=_write, args=(host_leaves, paths, td, ckpt_dir, step, keep)
    )
    t.start()
    _PENDING.append(t)


def wait_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def _write(host_leaves, paths, treedef_str, ckpt_dir, step, keep) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": treedef_str,
        "leaves": [
            {"path": p, "file": f"leaf_{i:05d}.npy", "dtype": str(a.dtype),
             "shape": list(a.shape)}
            for i, (p, a) in enumerate(zip(paths, host_leaves))
        ],
    }
    for i, a in enumerate(host_leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), _to_storable(a))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"), ignore_errors=True)


def _list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    like: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``like``; device_put with ``shardings``
    (pytree of NamedSharding) when given — elastic restore onto a new mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = jax.tree_util.tree_flatten(like)
    host_leaves = [
        _from_storable(np.load(os.path.join(d, rec["file"])), rec["dtype"])
        for rec in manifest["leaves"]
    ]
    state = jax.tree_util.tree_unflatten(treedef, host_leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    return state
