"""Compiled-plan persistence — warm a serving plan cache from disk.

``ExecutionPlan`` / ``ShardedExecutionPlan`` are pure host-side artifacts
(numpy arrays + a frozen EngineConfig), so they round-trip losslessly through
a single ``.npz`` file: every tile array is stored under a namespaced key and
everything scalar rides in a JSON header entry. A restarted ``GNNServeEngine``
loads these instead of re-running the planner — the disk analogue of the
in-memory plan cache (and of AMPLE's host programming nodeslots once per
graph, not once per boot).

No pickle anywhere: headers are UTF-8 JSON stored as a uint8 array, tags are
fixed-width unicode, so files are inspectable and load with
``allow_pickle=False``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.core.degree_quant import DegreeQuantConfig
from repro.core.message_passing import (
    EngineConfig,
    ExecutionPlan,
    ShardPlan,
    ShardedExecutionPlan,
)
from repro.core.scheduler import EdgeTilePlan
from repro.graphs.csr import Graph
from repro.graphs.partition import Partition, ShardSubgraph

__all__ = ["save_plan", "load_plan", "PlanRecord"]

_PLAN_ARRAYS = ("gather_idx", "coeff", "seg_ids", "out_node", "node_ids", "edge_ids")


@dataclasses.dataclass(frozen=True)
class PlanRecord:
    """What ``load_plan`` returns: the plan plus optional sidecar state."""

    plan: Union[ExecutionPlan, ShardedExecutionPlan]
    graph: Optional[Graph]  # structure only (no features); None if not saved
    extra: Dict[str, Any]  # caller metadata (e.g. the serve-cache key)


# ------------------------------------------------------------------- encode
def _cfg_header(cfg: EngineConfig) -> Dict[str, Any]:
    d = dataclasses.asdict(cfg)
    d["dq"] = dataclasses.asdict(cfg.dq)
    return d


def _plan_header(plan: ExecutionPlan) -> Dict[str, Any]:
    return {
        "fingerprint": plan.fingerprint,
        "graph_fp": plan.graph_fp,
        "num_nodes": plan.num_nodes,
        "num_edges": plan.num_edges,
        "modes": list(plan.mode_plans),
        "tiles": {
            mode: {
                tag: {
                    "num_nodes": p.num_nodes,
                    "edges_per_tile": p.edges_per_tile,
                    "segments_per_tile": p.segments_per_tile,
                    "total_edges": p.total_edges,
                }
                for tag, p in tag_plans.items()
            }
            for mode, tag_plans in plan.mode_plans.items()
        },
    }


def _pack_plan(plan: ExecutionPlan, prefix: str, arrays: Dict[str, np.ndarray]) -> None:
    arrays[f"{prefix}tags"] = np.asarray(plan.precision_tags, dtype="U8")
    for mode, tag_plans in plan.mode_plans.items():
        for tag, p in tag_plans.items():
            base = f"{prefix}p/{mode}/{tag}/"
            for name in _PLAN_ARRAYS:
                arrays[base + name] = getattr(p, name)


# ------------------------------------------------------------------- decode
def _cfg_from_header(d: Dict[str, Any]) -> EngineConfig:
    d = dict(d)
    d["dq"] = DegreeQuantConfig(**d["dq"])
    return EngineConfig(**d)


def _unpack_plan(
    header: Dict[str, Any], cfg: EngineConfig, prefix: str, z
) -> ExecutionPlan:
    tags = np.asarray(z[f"{prefix}tags"]).astype(str)
    # "pad" marks size-class padding nodes of an assembled union plan: they
    # belong to no precision group (their rows must stay zero through the
    # FTE), so they are excluded here exactly as assemble_union_plan does.
    groups = {
        tag: np.nonzero(tags == tag)[0]
        for tag in np.unique(tags)
        if tag != "pad"
    }
    mode_plans: Dict[str, Dict[str, EdgeTilePlan]] = {}
    for mode, tag_meta in header["tiles"].items():
        mode_plans[mode] = {}
        for tag, meta in tag_meta.items():
            base = f"{prefix}p/{mode}/{tag}/"
            arrays = {
                name: np.asarray(z[base + name])
                for name in _PLAN_ARRAYS
                if base + name in z
            }
            if "edge_ids" not in arrays:
                # Files written before the runtime-coefficient indirection:
                # structurally valid, but opted out of runtime coeffs
                # (every lane reads the -1 padding slot).
                arrays["edge_ids"] = np.full(
                    arrays["gather_idx"].shape, -1, np.int32
                )
            mode_plans[mode][tag] = EdgeTilePlan(
                **arrays,
                num_nodes=int(meta["num_nodes"]),
                edges_per_tile=int(meta["edges_per_tile"]),
                segments_per_tile=int(meta["segments_per_tile"]),
                total_edges=int(meta["total_edges"]),
            )
    return ExecutionPlan(
        fingerprint=header["fingerprint"],
        graph_fp=header["graph_fp"],
        num_nodes=int(header["num_nodes"]),
        num_edges=int(header["num_edges"]),
        cfg=cfg,
        precision_tags=tags,
        node_groups=groups,
        mode_plans=mode_plans,
    )


# ---------------------------------------------------------------------- API
def save_plan(
    path: str,
    plan: Union[ExecutionPlan, ShardedExecutionPlan],
    *,
    graph: Optional[Graph] = None,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    """Write a compiled plan (and optionally its graph structure) to ``path``.

    ``graph`` stores topology only (indptr/indices — features are runtime
    inputs, not plan state); pass the *prepared* graph the plan was compiled
    for so a restarted server can rebuild an engine without re-preparing.
    ``extra`` is an arbitrary JSON-serialisable dict returned verbatim by
    ``load_plan`` (the serving layer stashes its cache key there).
    """
    arrays: Dict[str, np.ndarray] = {}
    header: Dict[str, Any] = {"version": 1, "extra": extra or {}}
    if isinstance(plan, ShardedExecutionPlan):
        header["kind"] = "sharded_plan"
        header["sharded"] = {
            "fingerprint": plan.fingerprint,
            "graph_fp": plan.graph_fp,
            "partition_fp": plan.partition_fp,
            "num_nodes": plan.num_nodes,
            "num_edges": plan.num_edges,
        }
        header["cfg"] = _cfg_header(plan.cfg)
        header["partition_kind"] = plan.partition.kind
        arrays["partition_starts"] = np.asarray(plan.partition.starts, np.int64)
        if plan.partition.order is not None:
            # non-contiguous (min-cut) assignment: the permutation is part of
            # the partition identity and must survive the round-trip
            arrays["partition_order"] = np.asarray(plan.partition.order, np.int64)
        arrays["tags"] = np.asarray(plan.precision_tags, dtype="U8")
        shard_headers = []
        for k, sp in enumerate(plan.shards):
            prefix = f"s{k}/"
            shard_headers.append(
                {
                    "fingerprint": sp.fingerprint,
                    "lo": sp.shard.lo,
                    "hi": sp.shard.hi,
                    "edge_range": (
                        list(sp.shard.edge_range)
                        if sp.shard.edge_range is not None
                        else None
                    ),
                    "graph_name": sp.shard.graph.name,
                    "plan": _plan_header(sp.plan),
                }
            )
            if sp.shard.edge_idx is not None:
                arrays[f"{prefix}edge_idx"] = np.asarray(
                    sp.shard.edge_idx, np.int64
                )
            arrays[f"{prefix}halo"] = np.asarray(sp.shard.halo, np.int64)
            arrays[f"{prefix}indptr"] = sp.shard.graph.indptr
            arrays[f"{prefix}indices"] = sp.shard.graph.indices
            _pack_plan(sp.plan, prefix, arrays)
        header["shards"] = shard_headers
    elif isinstance(plan, ExecutionPlan):
        header["kind"] = "plan"
        header["plan"] = _plan_header(plan)
        header["cfg"] = _cfg_header(plan.cfg)
        _pack_plan(plan, "", arrays)
    else:
        raise TypeError(f"cannot persist {type(plan).__name__}")
    if graph is not None:
        header["graph"] = {"num_nodes": graph.num_nodes, "name": graph.name}
        arrays["graph/indptr"] = graph.indptr
        arrays["graph/indices"] = graph.indices
    arrays["header"] = np.frombuffer(
        json.dumps(header).encode("utf-8"), dtype=np.uint8
    )
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)  # atomic publish, like checkpoint/
    return path


def _mmap_npz(path: str) -> Dict[str, np.ndarray]:
    """Read-only memmap views of every member of an uncompressed ``.npz``.

    ``np.load(..., mmap_mode=...)`` silently ignores the mode inside zip
    archives (each member would need its own offset), so the member data
    offsets are resolved by hand: ``np.savez`` stores members uncompressed
    (ZIP_STORED), meaning each ``.npy`` payload sits verbatim in the file at
    ``local header + magic/header`` and maps directly. Returns a plain dict
    — the ``z[key]`` / ``key in z`` surface ``_unpack_plan`` reads.
    """
    import zipfile

    out: Dict[str, np.ndarray] = {}
    with zipfile.ZipFile(path) as zf, open(path, "rb") as f:
        for info in zf.infolist():
            if info.compress_type != zipfile.ZIP_STORED:
                raise ValueError(
                    f"{path}: member {info.filename!r} is compressed; "
                    "mmap_mode needs an uncompressed archive (np.savez)"
                )
            # Local file header: 30 fixed bytes, then filename + extra field
            # (their lengths live at offsets 26/28); the .npy stream follows.
            f.seek(info.header_offset)
            hdr = f.read(30)
            fn_len = int.from_bytes(hdr[26:28], "little")
            extra_len = int.from_bytes(hdr[28:30], "little")
            f.seek(info.header_offset + 30 + fn_len + extra_len)
            version = np.lib.format.read_magic(f)
            if version == (1, 0):
                shape, fortran, dtype = np.lib.format.read_array_header_1_0(f)
            elif version == (2, 0):
                shape, fortran, dtype = np.lib.format.read_array_header_2_0(f)
            else:
                raise ValueError(f"unsupported npy version {version} in {path}")
            if dtype.hasobject:
                raise ValueError(f"{path}: object arrays cannot be memmapped")
            name = info.filename
            if name.endswith(".npy"):
                name = name[: -len(".npy")]
            out[name] = np.memmap(
                path,
                dtype=dtype,
                shape=shape,
                order="F" if fortran else "C",
                mode="r",
                offset=f.tell(),
            )
    return out


def load_plan(path: str, *, mmap_mode: Optional[str] = None) -> PlanRecord:
    """Load a plan written by ``save_plan``; fingerprints round-trip exactly.

    ``mmap_mode="r"`` maps every tile array read-only straight out of the
    file instead of materialising it: large ``EdgeTilePlan`` arrays then
    cost address space and page cache, not private resident memory, which
    bounds warm-start RSS (plan files on big graphs rival the feature
    matrix). The returned arrays are views onto the file — read-only, so
    accidental mutation raises instead of silently corrupting the plan;
    copy before writing.
    """
    if mmap_mode is not None:
        if mmap_mode != "r":
            raise ValueError(f"mmap_mode must be 'r' or None, got {mmap_mode!r}")
        return _decode_record(path, _mmap_npz(path))
    with np.load(path, allow_pickle=False) as z:
        return _decode_record(path, z)


def _decode_record(path: str, z) -> PlanRecord:
    header = json.loads(bytes(np.asarray(z["header"]).tobytes()).decode("utf-8"))
    cfg = _cfg_from_header(header["cfg"])
    graph = None
    if "graph" in header:
        graph = Graph(
            indptr=np.asarray(z["graph/indptr"], np.int64),
            indices=np.asarray(z["graph/indices"], np.int32),
            num_nodes=int(header["graph"]["num_nodes"]),
            name=header["graph"]["name"],
        )
    if header["kind"] == "plan":
        plan: Union[ExecutionPlan, ShardedExecutionPlan] = _unpack_plan(
            header["plan"], cfg, "", z
        )
    elif header["kind"] == "sharded_plan":
        starts = np.asarray(z["partition_starts"], np.int64)
        order = (
            np.asarray(z["partition_order"], np.int64)
            if "partition_order" in z
            else None
        )
        # files from before the partitioner field default to the contiguous
        # edge-balanced kind (the only partitioner that existed then)
        part = Partition(
            starts=starts, order=order, kind=header.get("partition_kind", "edges")
        )
        tags = np.asarray(z["tags"]).astype(str)
        groups = {t: np.nonzero(tags == t)[0] for t in np.unique(tags)}
        shards = []
        for k, sh in enumerate(header["shards"]):
            prefix = f"s{k}/"
            halo = np.asarray(z[f"{prefix}halo"], np.int64)
            lo, hi = int(sh["lo"]), int(sh["hi"])
            local_g = Graph(
                indptr=np.asarray(z[f"{prefix}indptr"], np.int64),
                indices=np.asarray(z[f"{prefix}indices"], np.int32),
                num_nodes=(hi - lo) + int(halo.size),
                name=sh["graph_name"],
            )
            edge_range = sh.get("edge_range")
            sub = ShardSubgraph(
                index=k,
                lo=lo,
                hi=hi,
                halo=halo,
                local_ids=np.concatenate([part.owned(k), halo]),
                graph=local_g,
                edge_range=tuple(edge_range) if edge_range is not None else None,
                edge_idx=(
                    np.asarray(z[f"{prefix}edge_idx"], np.int64)
                    if f"{prefix}edge_idx" in z
                    else None
                ),
            )
            shards.append(
                ShardPlan(
                    fingerprint=sh["fingerprint"],
                    shard=sub,
                    plan=_unpack_plan(sh["plan"], cfg, prefix, z),
                )
            )
        meta = header["sharded"]
        plan = ShardedExecutionPlan(
            fingerprint=meta["fingerprint"],
            graph_fp=meta["graph_fp"],
            partition_fp=meta["partition_fp"],
            partition=part,
            num_nodes=int(meta["num_nodes"]),
            num_edges=int(meta["num_edges"]),
            cfg=cfg,
            precision_tags=tags,
            node_groups=groups,
            shards=tuple(shards),
        )
    else:
        raise ValueError(f"unknown plan kind {header['kind']!r} in {path}")
    return PlanRecord(plan=plan, graph=graph, extra=header.get("extra", {}))
