"""Model zoo: the paper's GNNs + the assigned LM architectures."""
