"""Family-agnostic model API: init / forward / prefill / decode by config.

Everything downstream (trainer, server, dry-run, benchmarks) talks to models
exclusively through these five functions, dispatched on ``cfg.family``:

  * token families (dense/moe/ssm/hybrid/encdec/vlm/audio) route to the LM
    stacks; batches carry ``tokens``/``embeds``;
  * ``family="gnn"`` routes to the arch registry in models/gnn/api.py;
    batches carry ``graph`` (CSR Graph) + ``features`` (and optionally a
    pre-compiled ``engine`` from the serving plan cache).

GNN inference is single-shot node classification — there is no KV cache, so
the prefill/decode entry points reject GNN configs with a pointer to
``serve.gnn_engine.GNNServeEngine``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import encdec, transformer

__all__ = [
    "model_init",
    "model_forward",
    "model_prefill",
    "model_init_cache",
    "model_decode_step",
    "loss_fn",
]


def _is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encoder_layers > 0


def _is_gnn(cfg: ModelConfig) -> bool:
    return cfg.family == "gnn"


def _no_token_cache(cfg: ModelConfig, entry: str):
    raise TypeError(
        f"{entry} is undefined for family='gnn' ({cfg.name}): GNN inference "
        "has no token cache; use model_forward with {'graph', 'features'} or "
        "serve.gnn_engine.GNNServeEngine for cached-plan serving"
    )


def model_init(cfg: ModelConfig, key, *, tp: int = 1):
    if _is_gnn(cfg):
        from repro.models.gnn import api as gnn_api

        return gnn_api.gnn_init(cfg, key)
    if _is_encdec(cfg):
        return encdec.init_encdec(cfg, key, tp=tp)
    return transformer.init_lm(cfg, key, tp=tp)


def model_forward(params, cfg: ModelConfig, batch: Dict, *, policy=transformer.NO_POLICY):
    if _is_gnn(cfg):
        from repro.models.gnn import api as gnn_api

        return gnn_api.gnn_forward(params, cfg, batch)
    if _is_encdec(cfg):
        return encdec.forward_encdec(params, cfg, batch, policy=policy)
    return transformer.forward(params, cfg, batch, policy=policy)


def model_prefill(params, cfg: ModelConfig, batch: Dict, max_len: int, *, policy=transformer.NO_POLICY):
    if _is_gnn(cfg):
        _no_token_cache(cfg, "model_prefill")
    if _is_encdec(cfg):
        enc = encdec.encode(params, cfg, batch["src_embeds"], policy=policy)
        cache = encdec.init_decoder_cache(params, cfg, enc, max_len)
        logits, aux = encdec.forward_encdec(params, cfg, batch, policy=policy)
        return logits, cache, jnp.asarray(batch["tgt_tokens"].shape[1], jnp.int32)
    return transformer.prefill(params, cfg, batch, max_len, policy=policy)


def model_init_cache(cfg: ModelConfig, params, batch: Dict, max_len: int, *, tp: int = 1):
    """Empty decode cache (enc-dec needs the encoder pass to build cross-K/V)."""
    if _is_gnn(cfg):
        _no_token_cache(cfg, "model_init_cache")
    if _is_encdec(cfg):
        enc = encdec.encode(params, cfg, batch["src_embeds"])
        return encdec.init_decoder_cache(params, cfg, enc, max_len)
    b = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[0]
    return transformer.init_cache(cfg, b, max_len, tp=tp)


def model_decode_step(params, cfg: ModelConfig, batch: Dict, cache, cache_len, *, policy=transformer.NO_POLICY):
    if _is_gnn(cfg):
        _no_token_cache(cfg, "model_decode_step")
    if _is_encdec(cfg):
        return encdec.decode_step_encdec(
            params, cfg, batch["tokens"], cache, cache_len, policy=policy
        )
    return transformer.decode_step(params, cfg, batch, cache, cache_len, policy=policy)


def loss_fn(
    params,
    cfg: ModelConfig,
    batch: Dict,
    *,
    policy=transformer.NO_POLICY,
    aux_coef: float = 0.01,
) -> Tuple[jnp.ndarray, Dict]:
    """Token cross-entropy (padded-vocab columns masked out) + MoE aux loss.

    batch["labels"] int32[B, S]; positions with label < 0 are ignored.
    """
    logits, aux = model_forward(params, cfg, batch, policy=policy)
    labels = batch["labels"]
    vp = logits.shape[-1]
    if vp > cfg.vocab_size:  # mask the sharding-padded vocab tail.
        # elementwise iota mask — unlike a concat, this PRESERVES the vocab
        # sharding of the logits (the concat boundary would force a reshard).
        vmask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(vmask, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - tgt) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    loss = ce + aux_coef * aux
    return loss, {"ce": ce, "aux": aux, "tokens": denom}
