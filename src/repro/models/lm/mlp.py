"""Feed-forward variants: SwiGLU (llama/qwen), squared-ReLU (nemotron), GELU."""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["mlp_init", "mlp_apply"]


def _he(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(scale_dim)).astype(dtype)


def mlp_init(key, d_model: int, d_ff: int, kind: str, *, bias: bool = False, dtype=jnp.bfloat16) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        p = {
            "w_gate": _he(k1, (d_model, d_ff), d_model, dtype),
            "w_up": _he(k2, (d_model, d_ff), d_model, dtype),
            "w_down": _he(k3, (d_ff, d_model), d_ff, dtype),
        }
    elif kind in ("relu2", "gelu"):
        p = {
            "w_in": _he(k1, (d_model, d_ff), d_model, dtype),
            "w_out": _he(k2, (d_ff, d_model), d_ff, dtype),
        }
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    if bias:
        if kind == "swiglu":
            p["b_gate"] = jnp.zeros((d_ff,), dtype)
            p["b_up"] = jnp.zeros((d_ff,), dtype)
        else:
            p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(params: Dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        gate = x @ params["w_gate"] + params.get("b_gate", 0)
        up = x @ params["w_up"] + params.get("b_up", 0)
        h = jax.nn.silu(gate) * up
        return h @ params["w_down"] + params.get("b_down", 0)
    h = x @ params["w_in"] + params.get("b_in", 0)
    if kind == "relu2":
        h = jnp.square(jax.nn.relu(h))  # nemotron squared-ReLU
    else:
        h = jax.nn.gelu(h)
    return h @ params["w_out"] + params.get("b_down", 0)
