"""Normalization layers (RMSNorm / LayerNorm), computed in fp32."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm", "make_norm"]


def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    orig = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jnp.reciprocal(jnp.sqrt(var + eps)) * params["scale"]
    return y.astype(orig)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    orig = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    y = y * params["scale"] + params["bias"]
    return y.astype(orig)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(f"unknown norm {kind!r}")
