"""Encoder-decoder backbone (Seamless-M4T medium's transformer core).

Encoder: bidirectional attention units. Decoder: causal self-attention +
cross-attention over encoder output + FFN. The speech/text modality frontend
is a STUB per the assignment — ``src_embeds`` arrive precomputed (frame
embeddings); the decoder consumes token ids.

Both stacks scan over stacked unit params like transformer.py. Cross-attention
K/V are projected once from the encoder output and reused across decode steps
(the standard serving split).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm.attention import (
    AttnStatics,
    attn_init,
    attention,
    decode_attention,
    project_kv,
)
from repro.models.lm.mlp import mlp_apply, mlp_init
from repro.models.lm.norm import make_norm
from repro.models.lm.transformer import NO_POLICY, make_statics

__all__ = [
    "init_encdec",
    "forward_encdec",
    "encode",
    "init_decoder_cache",
    "decode_step_encdec",
]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _sin_pos(x: jnp.ndarray, d_model: int) -> jnp.ndarray:
    s = x.shape[1]
    half = d_model // 2
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) / half * 9.21)
    ang = pos * freq[None]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    return x + pe[None].astype(x.dtype)


def _init_unit(cfg: ModelConfig, key, *, cross: bool, tp: int) -> Dict:
    norm_init, _ = make_norm(cfg.norm)
    k1, k2, k3 = jax.random.split(key, 3)
    kw = dict(
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        dtype=_dtype(cfg),
    )
    p = {
        "norm_attn": norm_init(cfg.d_model),
        "attn": attn_init(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, **kw,
        ),
        "norm_ffn": norm_init(cfg.d_model),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, bias=cfg.mlp_bias,
                        dtype=_dtype(cfg)),
    }
    if cross:
        p["norm_cross"] = norm_init(cfg.d_model)
        p["cross"] = attn_init(
            k3, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, **kw,
        )
    return p


def init_encdec(cfg: ModelConfig, key, *, tp: int = 1) -> Dict:
    assert cfg.encoder_layers > 0
    norm_init, _ = make_norm(cfg.norm)
    ke, kd, kv, kh = jax.random.split(key, 4)
    vp = cfg.padded_vocab(tp)
    dt = _dtype(cfg)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": (
            jax.random.normal(kv, (vp, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt),
        "lm_head": (
            jax.random.normal(kh, (cfg.d_model, vp), jnp.float32)
            / cfg.d_model**0.5
        ).astype(dt),
        "encoder": jax.vmap(lambda k: _init_unit(cfg, k, cross=False, tp=tp))(
            enc_keys
        ),
        "decoder": jax.vmap(lambda k: _init_unit(cfg, k, cross=True, tp=tp))(
            dec_keys
        ),
        "enc_norm": norm_init(cfg.d_model),
        "final_norm": norm_init(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, src_embeds: jnp.ndarray, *, policy=NO_POLICY):
    """Bidirectional encoder over precomputed frontend embeddings."""
    _, norm_apply = make_norm(cfg.norm)
    st = make_statics(cfg, causal=False)
    x = policy.res(_sin_pos(src_embeds.astype(_dtype(cfg)), cfg.d_model))

    def unit(x, p):
        h = norm_apply(p["norm_attn"], x, eps=cfg.norm_eps)
        x = policy.res(x + attention(p["attn"], h, st, None, policy=policy))
        h = norm_apply(p["norm_ffn"], x, eps=cfg.norm_eps)
        x = policy.res(x + mlp_apply(p["mlp"], h, cfg.mlp))
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(unit, x, params["encoder"])
    else:
        n = jax.tree_util.tree_leaves(params["encoder"])[0].shape[0]
        for u in range(n):
            x, _ = unit(x, jax.tree.map(lambda a: a[u], params["encoder"]))
    return norm_apply(params["enc_norm"], x, eps=cfg.norm_eps)


def forward_encdec(
    params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *, policy=NO_POLICY
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced training forward. batch: src_embeds, tgt_tokens."""
    _, norm_apply = make_norm(cfg.norm)
    enc = encode(params, cfg, batch["src_embeds"], policy=policy)
    st_self = make_statics(cfg, causal=True)
    st_cross = make_statics(cfg, causal=False)
    x = params["embed"][batch["tgt_tokens"]]
    x = policy.res(_sin_pos(x, cfg.d_model))

    def unit(x, p):
        h = norm_apply(p["norm_attn"], x, eps=cfg.norm_eps)
        x = policy.res(x + attention(p["attn"], h, st_self, None, policy=policy))
        h = norm_apply(p["norm_cross"], x, eps=cfg.norm_eps)
        kvv = project_kv(p["cross"], enc, st_cross)
        x = policy.res(x + attention(p["cross"], h, st_cross, None, kv=kvv, policy=policy))
        h = norm_apply(p["norm_ffn"], x, eps=cfg.norm_eps)
        x = policy.res(x + mlp_apply(p["mlp"], h, cfg.mlp))
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(unit, x, params["decoder"])
    else:
        n = jax.tree_util.tree_leaves(params["decoder"])[0].shape[0]
        for u in range(n):
            x, _ = unit(x, jax.tree.map(lambda a: a[u], params["decoder"]))
    x = norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    logits = policy.logits((x @ params["lm_head"]).astype(jnp.float32))
    aux = jnp.zeros((), jnp.float32)
    return logits, aux


def init_decoder_cache(params, cfg: ModelConfig, enc: jnp.ndarray, max_len: int):
    """Self-attn KV cache + cross K/V precomputed from the encoder output."""
    b = enc.shape[0]
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = _dtype(cfg)
    st_cross = make_statics(cfg, causal=False)
    cross_k, cross_v = jax.vmap(
        lambda p: project_kv(p, enc, st_cross)
    )(params["decoder"]["cross"])
    return {
        "k": jnp.zeros((cfg.num_layers, b, max_len, kv, hd), dt),
        "v": jnp.zeros((cfg.num_layers, b, max_len, kv, hd), dt),
        "cross_k": cross_k,  # [L, B, S_src, kv, hd]
        "cross_v": cross_v,
    }


def decode_step_encdec(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [B, 1]
    cache: Dict,
    cache_len: jnp.ndarray,
    *,
    policy=NO_POLICY,
):
    _, norm_apply = make_norm(cfg.norm)
    st_self = make_statics(cfg, causal=True)
    st_cross = make_statics(cfg, causal=False)
    x = params["embed"][tokens]
    half = cfg.d_model // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) / half * 9.21)
    ang = cache_len.astype(jnp.float32) * freq
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    x = x + pe[None, None].astype(x.dtype)

    def unit(x, scanned):
        p, ck, cv, xk, xv = scanned
        h = norm_apply(p["norm_attn"], x, eps=cfg.norm_eps)
        h, k_new, v_new = decode_attention(p["attn"], h, st_self, ck, cv, cache_len)
        x = x + h
        h = norm_apply(p["norm_cross"], x, eps=cfg.norm_eps)
        x = x + attention(p["cross"], h, st_cross, None, kv=(xk, xv))
        h = norm_apply(p["norm_ffn"], x, eps=cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp)
        return x, (k_new, v_new)

    scanned = (params["decoder"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"])
    if cfg.scan_layers:
        x, (k_new, v_new) = jax.lax.scan(unit, x, scanned)
    else:
        n = cfg.num_layers
        ks, vs = [], []
        for u in range(n):
            x, (k1, v1) = unit(x, jax.tree.map(lambda a: a[u], scanned))
            ks.append(k1)
            vs.append(v1)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    logits = policy.logits((x @ params["lm_head"]).astype(jnp.float32))
    cache = dict(cache, k=k_new, v=v_new)
    return logits[:, 0], cache
