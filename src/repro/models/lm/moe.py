"""Mixture-of-Experts with event-driven capacity dispatch.

Token→expert routing is the same skewed bin-packing problem as AMPLE's
node→nodeslot scheduling (DESIGN.md §2.1): expert loads are non-uniform, and a
fixed per-expert capacity plays the role of the nodeslot pool. Dispatch here
is the sort-based "dropping" formulation:

  1. route: top-k gates per token (softmax router, f32);
  2. schedule: stable-sort (token, k) slots by expert id, rank within expert —
     rank ≥ capacity overflows (drops) exactly like a nodeslot pool saturating;
  3. execute: scatter tokens into the [E, C, D] expert buffer, run all expert
     FFNs as one stacked einsum (MXU-dense, like the FTE), gather back and
     combine with gate weights.

The capacity C = ceil(T·k/E · capacity_factor) is static; the event-driven
insight surfaces as ``load_stats`` (per-expert load / drop fraction) that the
serving layer can feed back into capacity_factor per batch — the host-side
analogue of reprogramming nodeslots.

Sharding: experts (leading axis of stacked FFN weights) go over the "model"
mesh axis when divisible (EP); otherwise the expert FFN hidden dim is sharded
(TP-within-expert; e.g. granite's 40 experts on a 16-way axis). Router and
gates replicate.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.mlp import mlp_apply, mlp_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    num_experts: int,
    kind: str,
    *,
    shared_expert: bool = False,
    dtype=jnp.bfloat16,
) -> Dict:
    kr, ke, ks = jax.random.split(key, 3)
    expert_keys = jax.random.split(ke, num_experts)
    experts = jax.vmap(
        lambda k: mlp_init(k, d_model, d_ff, kind, dtype=dtype)
    )(expert_keys)
    p = {
        "router": (
            jax.random.normal(kr, (d_model, num_experts), jnp.float32)
            / math.sqrt(d_model)
        ),
        "experts": experts,  # stacked [E, ...]
    }
    if shared_expert:
        p["shared"] = mlp_init(ks, d_model, d_ff, kind, dtype=dtype)
    return p


def _expert_ffn(experts: Dict, xin: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Stacked expert FFN: xin [G, E, C, D] -> [G, E, C, D] (G = dispatch
    groups — one per data shard; see moe_apply)."""
    if kind == "swiglu":
        gate = jnp.einsum("gecd,edf->gecf", xin, experts["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", xin, experts["w_up"])
        h = jax.nn.silu(gate) * up
        return jnp.einsum("gecf,efd->gecd", h, experts["w_down"])
    h = jnp.einsum("gecd,edf->gecf", xin, experts["w_in"])
    h = jnp.square(jax.nn.relu(h)) if kind == "relu2" else jax.nn.gelu(h)
    return jnp.einsum("gecf,efd->gecd", h, experts["w_out"])


def moe_apply(
    params: Dict,
    x: jnp.ndarray,  # [B, S, D]
    *,
    num_experts: int,
    top_k: int,
    kind: str,
    capacity_factor: float = 1.25,
    return_stats: bool = False,
    policy=None,
):
    b, s, d = x.shape
    t = b * s
    e = num_experts
    # Explicit shard_map EP path (§Perf cell C): partitioner-proof dispatch.
    if policy is not None and not return_stats:
        from repro.models.lm.moe_sharded import moe_apply_sharded, sharded_applicable

        if sharded_applicable(policy, e, t, 0):
            return moe_apply_sharded(
                params, x, num_experts=e, top_k=top_k, kind=kind,
                capacity_factor=capacity_factor, policy=policy,
            )
    # --- dispatch groups: one local nodeslot pool per data shard -------------
    # The schedule (sort + rank + capacity) runs independently inside each
    # group, so no global shuffle crosses shards; the only cross-shard motion
    # is the [G, E] block transpose into expert shards — an all-to-all. This
    # mirrors the paper exactly: nodeslots are a LOCAL resource pool, and the
    # NoC (here: ICI a2a) moves only scheduled work. A global-sort variant was
    # measured to all-gather token tensors every layer (EXPERIMENTS.md §Perf).
    groups = 1
    if policy is not None and hasattr(policy, "moe_groups"):
        groups = policy.moe_groups(t)
    tg = t // groups
    cap = max(1, int(math.ceil(tg * top_k / e * capacity_factor)))

    xf = x.reshape(groups, tg, d)
    logits = (xf.astype(jnp.float32)) @ params["router"]  # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, top_k)  # [G, Tg, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- event-driven schedule (per group): sort by expert, rank, capacity --
    flat_e = gate_idx.reshape(groups, tg * top_k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)  # sorted expert ids
    token_of = order // top_k
    counts = jax.vmap(lambda se_g: jnp.bincount(se_g, length=e))(se)
    starts = jnp.cumsum(counts, axis=-1) - counts
    rank = jnp.arange(tg * top_k)[None, :] - jnp.take_along_axis(starts, se, -1)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)  # overflow -> sentinel row

    # ---- dispatch / execute / combine ----
    def scatter_group(xf_g, slot_g, token_of_g):
        return jnp.zeros((e * cap + 1, d), x.dtype).at[slot_g].set(
            xf_g[token_of_g]
        )

    buf = jax.vmap(scatter_group)(xf.astype(x.dtype), slot, token_of)
    xin = buf[:, : e * cap].reshape(groups, e, cap, d)
    if policy is not None:
        xin = policy.ebuf(xin)  # EP: [G,E] block transpose == all-to-all
    yexp = _expert_ffn(params["experts"], xin, kind)
    if policy is not None:
        yexp = policy.ebuf_out(yexp)  # a2a back to group-local layout
    yflat = yexp.reshape(groups, e * cap, d)
    wsorted = jnp.take_along_axis(gate_w.reshape(groups, tg * top_k), order, -1)

    def combine_group(yflat_g, slot_g, token_of_g, keep_g, w_g):
        contrib = jnp.where(
            keep_g[:, None], yflat_g[jnp.minimum(slot_g, e * cap - 1)], 0.0
        ) * w_g[:, None].astype(x.dtype)
        return jnp.zeros((tg, d), x.dtype).at[token_of_g].add(contrib)

    out = jax.vmap(combine_group)(yflat, slot, token_of, keep, wsorted)

    if "shared" in params:
        out = out + mlp_apply(params["shared"], xf.reshape(groups, tg, d), kind)
    out = out.reshape(b, s, d)

    # Switch-style load-balancing aux loss: E * Σ_e f_e · P_e
    f_e = counts.sum(0).astype(jnp.float32) / (t * top_k)
    p_e = probs.reshape(t, e).mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)

    if return_stats:
        stats = {
            "expert_load": counts.sum(0),
            "dropped_fraction": 1.0 - keep.mean(),
            "capacity": cap,
            "groups": groups,
        }
        return out, aux, stats
    return out, aux
