"""Decoder-only LM assembly: dense / MoE / hybrid (Jamba) / SSM (Mamba2).

Layers are grouped into repeated **units** (the smallest repeating pattern of
layer roles) and parameters are stacked with a leading unit axis, so the whole
stack lowers as one ``lax.scan`` — compile time and HLO size are that of a single
unit regardless of depth. Unit patterns:

  dense LM            [(attn, dense)]                       U = L
  granite-moe         [(attn, moe)]                         U = L
  llama4 (interleave) [(attn, dense), (attn, moe)]          U = L/2
  jamba (1:7, moe/2)  8 roles: attn at offset 4, moe odd    U = L/8
  mamba2              [(mamba, none)]                       U = L

Activation-checkpointing (``cfg.remat == "block"``) wraps the unit body in
``jax.checkpoint`` so the scan saves only inter-unit residuals.

The ``policy`` argument carries sharding constraints (distributed/sharding.py)
applied to residual-stream activations and logits; ``None`` means single
device (tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, pad_to_multiple
from repro.models.lm.attention import (
    AttnStatics,
    attn_init,
    attention,
    decode_attention,
)
from repro.models.lm.mamba import (
    mamba_apply,
    mamba_decode,
    mamba_init,
    mamba_state_init,
)
from repro.models.lm.mlp import mlp_apply, mlp_init
from repro.models.lm.moe import moe_apply, moe_init
from repro.models.lm.norm import make_norm
from repro.models.lm.rope import mrope_text_positions

__all__ = [
    "block_roles",
    "init_lm",
    "forward",
    "init_cache",
    "decode_step",
    "make_statics",
    "count_params",
]

Role = Tuple[str, str]  # (mixer, ffn)


class _NoPolicy:
    def res(self, x):  # residual-stream activations
        return x

    def logits(self, x):
        return x

    def qkv(self, q, k, v):
        return q, k, v

    def ebuf(self, xin):
        return xin

    def ebuf_out(self, y):
        return y

    def moe_groups(self, t):
        return 1


NO_POLICY = _NoPolicy()


def block_roles(cfg: ModelConfig) -> List[Role]:
    if cfg.is_hybrid:  # jamba: attn every `period`, MoE every `moe_period`
        roles = []
        for i in range(cfg.attn_layer_period):
            mixer = "attn" if i == cfg.attn_layer_offset else "mamba"
            ffn = (
                "moe"
                if cfg.is_moe and (i % cfg.moe_layer_period == cfg.moe_layer_period - 1)
                else "dense"
            )
            roles.append((mixer, ffn))
        return roles
    if cfg.is_ssm_only:
        return [("mamba", "none" if cfg.d_ff == 0 else "dense")]
    if cfg.is_moe and cfg.moe_layer_period > 1:
        return [("attn", "dense")] * (cfg.moe_layer_period - 1) + [("attn", "moe")]
    if cfg.is_moe:
        return [("attn", "moe")]
    return [("attn", "dense")]


def make_statics(cfg: ModelConfig, *, tp: int = 1, causal: bool = True) -> AttnStatics:
    return AttnStatics(
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        mrope=cfg.pos_embed == "mrope",
        mrope_sections=cfg.mrope_sections,
        qk_norm=cfg.qk_norm,
        impl=cfg.attention_impl,
        causal=causal,
        norm_eps=cfg.norm_eps,
        use_rope=cfg.pos_embed in ("rope", "mrope"),
    )


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------- init
def _init_role(cfg: ModelConfig, role: Role, key, tp: int) -> Dict:
    norm_init, _ = make_norm(cfg.norm)
    mixer, ffn = role
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm_mixer": norm_init(cfg.d_model)}
    if mixer == "attn":
        p["attn"] = attn_init(
            k1,
            cfg.d_model,
            cfg.num_heads,
            cfg.num_kv_heads,
            cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias,
            qk_norm=cfg.qk_norm,
            dtype=_dtype(cfg),
        )
    else:
        p["mamba"] = mamba_init(
            k1,
            cfg.d_model,
            d_inner=cfg.d_inner,
            ssm_state=cfg.ssm_state,
            heads=cfg.ssm_heads,
            conv=cfg.ssm_conv,
            dtype=_dtype(cfg),
        )
    if ffn != "none":
        p["norm_ffn"] = norm_init(cfg.d_model)
        if ffn == "moe":
            p["moe"] = moe_init(
                k2,
                cfg.d_model,
                cfg.d_ff,
                cfg.num_experts,
                cfg.mlp,
                shared_expert=cfg.moe_shared_expert,
                dtype=_dtype(cfg),
            )
        else:
            p["mlp"] = mlp_init(
                k2, cfg.d_model, cfg.d_ff, cfg.mlp, bias=cfg.mlp_bias, dtype=_dtype(cfg)
            )
    return p


def init_lm(cfg: ModelConfig, key, *, tp: int = 1) -> Dict:
    roles = block_roles(cfg)
    assert cfg.num_layers % len(roles) == 0, (cfg.num_layers, roles)
    units = cfg.num_layers // len(roles)
    norm_init, _ = make_norm(cfg.norm)
    keys = jax.random.split(key, len(roles) + 2)
    vp = cfg.padded_vocab(tp)
    dt = _dtype(cfg)
    params: Dict[str, Any] = {
        "embed": (
            jax.random.normal(keys[-1], (vp, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt),
        "final_norm": norm_init(cfg.d_model),
        "units": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, vp), jnp.float32)
            / cfg.d_model**0.5
        ).astype(dt)
    for r, role in enumerate(roles):
        role_keys = jax.random.split(keys[r], units)
        params["units"].append(
            jax.vmap(lambda k: _init_role(cfg, role, k, tp))(role_keys)
        )
    return params


# ------------------------------------------------------------------ forward
def _apply_role(cfg, role, st, p, x, positions, policy):
    _, norm_apply = make_norm(cfg.norm)
    mixer, ffn = role
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm_mixer"], x, eps=cfg.norm_eps)
    if mixer == "attn":
        h = attention(p["attn"], h, st, positions, policy=policy)
    else:
        h = mamba_apply(
            p["mamba"],
            h,
            d_inner=cfg.d_inner,
            ssm_state=cfg.ssm_state,
            heads=cfg.ssm_heads,
            headdim=cfg.ssm_headdim,
            chunk=cfg.ssm_chunk,
            norm_eps=cfg.norm_eps,
        )
    x = policy.res(x + h)
    if ffn != "none":
        h = norm_apply(p["norm_ffn"], x, eps=cfg.norm_eps)
        if ffn == "moe":
            h, a = moe_apply(
                p["moe"],
                h,
                num_experts=cfg.num_experts,
                top_k=cfg.experts_per_token,
                kind=cfg.mlp,
                capacity_factor=cfg.capacity_factor,
                policy=policy,
            )
            aux = aux + a
        else:
            h = mlp_apply(p["mlp"], h, cfg.mlp)
        x = policy.res(x + h)
    return x, aux


def _embed_in(cfg, params, batch, policy):
    if "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = params["embed"][batch["tokens"]]
    b, s = x.shape[:2]
    if cfg.pos_embed == "sin":
        half = cfg.d_model // 2
        pos = jnp.arange(s, dtype=jnp.float32)[:, None]
        freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) / half * 9.21)
        ang = pos * freq[None]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        x = x + pe[None].astype(x.dtype)
    if cfg.pos_embed == "rope":
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)),
        )
    elif cfg.pos_embed == "mrope":
        positions = batch.get("positions", mrope_text_positions(b, s))
    else:
        positions = None
    return policy.res(x), positions


def _lm_head(cfg, params, x, policy):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return policy.logits(logits.astype(jnp.float32))


def forward(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    policy=NO_POLICY,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits [B,S,Vp] f32, moe aux loss)."""
    roles = block_roles(cfg)
    st = make_statics(cfg)
    x, positions = _embed_in(cfg, params, batch, policy)

    def unit(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        for role, p in zip(roles, unit_params):
            x, a = _apply_role(cfg, role, st, p, x, positions, policy)
            aux += a
        return x, aux

    if cfg.remat == "block":
        unit = jax.checkpoint(unit)

    if cfg.scan_layers:
        def scan_body(x, unit_params):
            return unit(x, unit_params)

        x, auxs = jax.lax.scan(scan_body, x, tuple(params["units"]))
        aux = auxs.sum()
    else:
        units = jax.tree_util.tree_leaves(params["units"][0])[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        for u in range(units):
            up = jax.tree.map(lambda a: a[u], params["units"])
            x, a = unit(x, tuple(up))
            aux += a

    _, norm_apply = make_norm(cfg.norm)
    x = norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    return _lm_head(cfg, params, x, policy), aux


def prefill(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    max_len: int,
    *,
    policy=NO_POLICY,
) -> Tuple[jnp.ndarray, List[Dict], jnp.ndarray]:
    """Process the prompt once, returning (logits [B,S,Vp], cache, cache_len).

    One forward pass that also writes every layer's K/V (and SSM final state)
    into a decode cache of capacity ``max_len`` — the serving prefill path.
    """
    roles = block_roles(cfg)
    st = make_statics(cfg)
    _, norm_apply = make_norm(cfg.norm)
    x, positions = _embed_in(cfg, params, batch, policy)
    b, s = x.shape[:2]
    dt = _dtype(cfg)

    def unit(x, unit_params):
        cache_out = []
        for role, p in zip(roles, unit_params):
            mixer, ffn = role
            h = norm_apply(p["norm_mixer"], x, eps=cfg.norm_eps)
            if mixer == "attn":
                h, k, v = attention(p["attn"], h, st, positions, return_kv=True,
                                    policy=policy)
                pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
                if cfg.kv_cache_dtype == "int8":
                    from repro.models.lm.attention import quantize_kv

                    kq, ks = quantize_kv(k)
                    vq, vs = quantize_kv(v)
                    spad = ((0, 0), (0, max_len - s), (0, 0))
                    cache_out.append({
                        "k": jnp.pad(kq, pad), "v": jnp.pad(vq, pad),
                        "k_scale": jnp.pad(ks, spad),
                        "v_scale": jnp.pad(vs, spad),
                    })
                else:
                    cache_out.append(
                        {"k": jnp.pad(k.astype(dt), pad),
                         "v": jnp.pad(v.astype(dt), pad)}
                    )
            else:
                h, state = mamba_apply(
                    p["mamba"], h,
                    d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                    heads=cfg.ssm_heads, headdim=cfg.ssm_headdim,
                    chunk=cfg.ssm_chunk, norm_eps=cfg.norm_eps,
                    return_state=True,
                )
                cache_out.append(state)
            x = policy.res(x + h)
            if ffn != "none":
                h = norm_apply(p["norm_ffn"], x, eps=cfg.norm_eps)
                if ffn == "moe":
                    h, _ = moe_apply(
                        p["moe"], h,
                        num_experts=cfg.num_experts, top_k=cfg.experts_per_token,
                        kind=cfg.mlp, capacity_factor=cfg.capacity_factor,
                        policy=policy,
                    )
                else:
                    h = mlp_apply(p["mlp"], h, cfg.mlp)
                x = policy.res(x + h)
        return x, tuple(cache_out)

    if cfg.scan_layers:
        x, cache = jax.lax.scan(lambda c, p: unit(c, p), x, tuple(params["units"]))
        cache = list(cache)
    else:
        units = jax.tree_util.tree_leaves(params["units"][0])[0].shape[0]
        ys = []
        for u in range(units):
            up = jax.tree.map(lambda a: a[u], params["units"])
            x, c = unit(x, tuple(up))
            ys.append(c)
        cache = [jax.tree.map(lambda *xs: jnp.stack(xs), *[y[r] for y in ys])
                 for r in range(len(roles))]

    x = norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    logits = _lm_head(cfg, params, x, policy)
    return logits, cache, jnp.asarray(s, jnp.int32)


# ------------------------------------------------------------------- decode
def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, tp: int = 1, dtype=None
) -> List[Dict]:
    """Per-role stacked cache pytree ([U, ...] leading axis, scan-compatible)."""
    roles = block_roles(cfg)
    units = cfg.num_layers // len(roles)
    dt = dtype or _dtype(cfg)
    cache = []
    int8kv = cfg.kv_cache_dtype == "int8"
    for mixer, _ in roles:
        if mixer == "attn":
            kv = cfg.num_kv_heads
            hd = cfg.resolved_head_dim
            kdt = jnp.int8 if int8kv else dt
            entry = {
                "k": jnp.zeros((units, batch, max_len, kv, hd), kdt),
                "v": jnp.zeros((units, batch, max_len, kv, hd), kdt),
            }
            if int8kv:
                entry["k_scale"] = jnp.zeros((units, batch, max_len, kv), jnp.float32)
                entry["v_scale"] = jnp.zeros((units, batch, max_len, kv), jnp.float32)
            cache.append(entry)
        else:
            st = mamba_state_init(
                batch,
                d_inner=cfg.d_inner,
                ssm_state=cfg.ssm_state,
                heads=cfg.ssm_heads,
                headdim=cfg.ssm_headdim,
                conv=cfg.ssm_conv,
            )
            cache.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (units, *a.shape)), st))
    return cache


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],  # tokens [B,1] or embeds [B,1,D]
    cache: List[Dict],
    cache_len: jnp.ndarray,  # int32[]
    *,
    policy=NO_POLICY,
) -> Tuple[jnp.ndarray, List[Dict]]:
    """One serving step: returns (logits [B, Vp] f32, updated cache)."""
    roles = block_roles(cfg)
    st = make_statics(cfg)
    _, norm_apply = make_norm(cfg.norm)
    if "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = params["embed"][batch["tokens"]]

    def unit(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = []
        for role, p, c in zip(roles, unit_params, unit_cache):
            mixer, ffn = role
            h = norm_apply(p["norm_mixer"], x, eps=cfg.norm_eps)
            if mixer == "attn":
                if "k_scale" in c:  # int8 KV cache
                    h, k_new, v_new, ks, vs = decode_attention(
                        p["attn"], h, st, c["k"], c["v"], cache_len,
                        k_scale=c["k_scale"], v_scale=c["v_scale"],
                    )
                    new_cache.append(
                        {"k": k_new, "v": v_new, "k_scale": ks, "v_scale": vs}
                    )
                else:
                    h, k_new, v_new = decode_attention(
                        p["attn"], h, st, c["k"], c["v"], cache_len
                    )
                    new_cache.append({"k": k_new, "v": v_new})
            else:
                h, c_new = mamba_decode(
                    p["mamba"],
                    h,
                    c,
                    d_inner=cfg.d_inner,
                    ssm_state=cfg.ssm_state,
                    heads=cfg.ssm_heads,
                    headdim=cfg.ssm_headdim,
                    norm_eps=cfg.norm_eps,
                )
                new_cache.append(c_new)
            x = x + h
            if ffn != "none":
                h = norm_apply(p["norm_ffn"], x, eps=cfg.norm_eps)
                if ffn == "moe":
                    h, _ = moe_apply(
                        p["moe"],
                        h,
                        num_experts=cfg.num_experts,
                        top_k=cfg.experts_per_token,
                        kind=cfg.mlp,
                        capacity_factor=cfg.capacity_factor,
                        policy=policy,
                    )
                else:
                    h = mlp_apply(p["mlp"], h, cfg.mlp)
                x = x + h
        return x, tuple(new_cache)

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(unit, x, (tuple(params["units"]), tuple(cache)))
        new_cache = list(new_cache)
    else:
        units = jax.tree_util.tree_leaves(cache[0])[0].shape[0]
        ys = []
        for u in range(units):
            up = jax.tree.map(lambda a: a[u], params["units"])
            uc = jax.tree.map(lambda a: a[u], cache)
            x, nc = unit(x, (tuple(up), tuple(uc)))
            ys.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ys)
        new_cache = list(new_cache)

    x = norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    logits = _lm_head(cfg, params, x, policy)
    return logits[:, 0], new_cache


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
