"""Mamba2 — SSD (state-space duality) blocks, chunked form + decode recurrence.

Implements the Mamba2 mixer (arXiv:2405.21060): gated x/z projection, causal
depthwise conv on (x, B, C), softplus-dt input-dependent discretization with a
scalar decay per head (A), and the SSD chunked algorithm:

  * intra-chunk: quadratic "attention-like" term (C_i·B_j masked by the decay
    kernel L[i,j] = exp(Σ_{j<k≤i} a_k)) — MXU-dense;
  * inter-chunk: linear recurrence over per-chunk states via ``lax.scan``.

Decode is the pure recurrence: state ← decay·state + B·(dt·x), y = C·state.
Single B/C group (G=1), shared across heads, as in the 370m config.

Unlike the reference CUDA implementation's packed ``in_proj``, the five
projections (x, z, B, C, dt) are stored as separate weights: the packed layout
cuts across tensor-parallel shard boundaries, while separate weights shard
cleanly (x/z on d_inner over "model"; B/C/dt are small and replicate).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.norm import rmsnorm

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "mamba_state_init"]


def _he(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(scale_dim)).astype(dtype)


def mamba_init(
    key,
    d_model: int,
    *,
    d_inner: int,
    ssm_state: int,
    heads: int,
    conv: int = 4,
    dtype=jnp.bfloat16,
) -> Dict:
    keys = jax.random.split(key, 8)
    n, h = ssm_state, heads
    # dt bias: inverse-softplus of dt in [1e-3, 1e-1] (mamba2 default init)
    u = jax.random.uniform(keys[7], (h,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
    return {
        "wx": _he(keys[0], (d_model, d_inner), d_model, dtype),
        "wz": _he(keys[1], (d_model, d_inner), d_model, dtype),
        "wb": _he(keys[2], (d_model, n), d_model, dtype),
        "wc": _he(keys[3], (d_model, n), d_model, dtype),
        "wdt": _he(keys[4], (d_model, h), d_model, dtype),
        "conv_x": {"w": _he(keys[5], (conv, d_inner), conv, jnp.float32),
                   "b": jnp.zeros((d_inner,), jnp.float32)},
        "conv_b": {"w": _he(keys[5], (conv, n), conv, jnp.float32),
                   "b": jnp.zeros((n,), jnp.float32)},
        "conv_c": {"w": _he(keys[6], (conv, n), conv, jnp.float32),
                   "b": jnp.zeros((n,), jnp.float32)},
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt0 + jnp.log(-jnp.expm1(-dt0)),  # softplus^-1(dt0)
        "norm_scale": {"scale": jnp.ones((d_inner,), jnp.float32)},
        "out_proj": _he(keys[6], (d_inner, d_model), d_inner, dtype),
    }


def _causal_conv(u: jnp.ndarray, conv: Dict) -> jnp.ndarray:
    """Depthwise causal conv1d: u [B, L, C], w [K, C] -> silu(conv) [B, L, C]."""
    w, b = conv["w"], conv["b"]
    k = w.shape[0]
    u32 = u.astype(jnp.float32)
    pad = jnp.pad(u32, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u32)
    for i in range(k):  # K is tiny (4): unrolled taps beat a conv op in HLO
        out = out + pad[:, i : i + u.shape[1], :] * w[i]
    return jax.nn.silu(out + b).astype(u.dtype)


def mamba_apply(
    params: Dict,
    x: jnp.ndarray,  # [B, L, D]
    *,
    d_inner: int,
    ssm_state: int,
    heads: int,
    headdim: int,
    chunk: int = 256,
    norm_eps: float = 1e-6,
    return_state: bool = False,
):
    b, l, _ = x.shape
    n, h, p = ssm_state, heads, headdim
    z = x @ params["wz"]
    xc = _causal_conv(x @ params["wx"], params["conv_x"])
    bb = _causal_conv(x @ params["wb"], params["conv_b"]).astype(jnp.float32)
    cc = _causal_conv(x @ params["wc"], params["conv_c"]).astype(jnp.float32)
    dt = x @ params["wdt"]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,L,H]
    a = -jnp.exp(params["A_log"])  # [H]
    adt = dt * a  # log-decay per step [B, L, H]

    # ---- chunking ----
    q = min(chunk, l)
    nc = -(-l // q)
    lp = nc * q
    if lp != l:
        pad = ((0, 0), (0, lp - l), (0, 0))
        xc, z = jnp.pad(xc, pad), jnp.pad(z, pad)
        bb, cc = jnp.pad(bb, pad), jnp.pad(cc, pad)
        adt = jnp.pad(adt, pad)
        dt = jnp.pad(dt, pad)
    xh = xc.reshape(b, nc, q, h, p).astype(jnp.float32)
    xdt = xh * dt.reshape(b, nc, q, h)[..., None]  # fold dt into B·x
    bc = bb.reshape(b, nc, q, n)
    cch = cc.reshape(b, nc, q, n)
    adt_c = adt.reshape(b, nc, q, h)
    acum = jnp.cumsum(adt_c, axis=2)  # [B,nc,Q,H]

    # intra-chunk (diagonal block): L[i,j] = exp(acum_i - acum_j) for i>=j
    li = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    iota = jnp.arange(q)
    causal = iota[:, None] >= iota[None, :]
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cch, bc)  # [B,nc,Q,Q] (G=1 shared)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, lmat, xdt)

    # chunk-final states: S_c = Σ_j exp(acum_last - acum_j) B_j ⊗ xdt_j
    decay_states = jnp.exp(acum[:, :, -1:, :] - acum)  # [B,nc,Q,H]
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, decay_states, xdt)

    # inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(acum[:, :, -1, :])  # [B,nc,H]

    def scan_fn(s_prev, inp):
        s_c, cd = inp  # [B,H,P,N], [B,H]
        s_new = s_c + s_prev * cd[..., None, None]
        return s_new, s_prev

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    s_last, s_prevs = jax.lax.scan(
        scan_fn,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N] state entering chunk

    # off-diagonal: contribution of carried state to every position
    state_decay = jnp.exp(acum)  # [B,nc,Q,H]
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", cch, s_prevs, state_decay)

    y = (y_diag + y_off).reshape(b, lp, h, p) + params["D"][None, None, :, None] * xh.reshape(b, lp, h, p)
    y = y.reshape(b, lp, d_inner)[:, :l]
    z = z[:, :l]
    y = rmsnorm(params["norm_scale"], y * jax.nn.silu(z.astype(jnp.float32)), eps=norm_eps)
    out = (y.astype(x.dtype)) @ params["out_proj"]
    if not return_state:
        return out
    # decode-continuation state: final SSM state (padding lanes are inert —
    # padded dt is 0, so decay=1 and contribution=0) + last K-1 raw conv inputs
    kc = params["conv_x"]["w"].shape[0]

    def tail(u):  # [B, L, C] -> [B, K-1, C]
        need = kc - 1
        u = jnp.pad(u, ((0, 0), (max(0, need - u.shape[1]), 0), (0, 0)))
        return u[:, -need:].astype(jnp.float32)

    state = {
        "conv_x": tail(x @ params["wx"]),
        "conv_b": tail(x @ params["wb"]),
        "conv_c": tail(x @ params["wc"]),
        "ssm": s_last,
    }
    return out, state


def mamba_state_init(batch: int, *, d_inner: int, ssm_state: int, heads: int,
                     headdim: int, conv: int = 4, dtype=jnp.float32):
    """Decode state: conv windows for (x, B, C) + the SSM state tensor."""
    n = ssm_state
    return {
        "conv_x": jnp.zeros((batch, conv - 1, d_inner), dtype),
        "conv_b": jnp.zeros((batch, conv - 1, n), dtype),
        "conv_c": jnp.zeros((batch, conv - 1, n), dtype),
        "ssm": jnp.zeros((batch, heads, headdim, n), dtype),
    }


def _conv_step(u_t: jnp.ndarray, conv_state: jnp.ndarray, conv: Dict):
    """One causal-conv step: u_t [B, C]; returns (silu(out) [B, C], new_state)."""
    window = jnp.concatenate(
        [conv_state, u_t[:, None, :].astype(conv_state.dtype)], axis=1
    )  # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), conv["w"])
    return jax.nn.silu(out + conv["b"]), window[:, 1:]


def mamba_decode(
    params: Dict,
    x: jnp.ndarray,  # [B, 1, D]
    state: Dict,
    *,
    d_inner: int,
    ssm_state: int,
    heads: int,
    headdim: int,
    norm_eps: float = 1e-6,
):
    """One-token recurrence. Returns (y [B,1,D], new_state)."""
    b = x.shape[0]
    n, h, p = ssm_state, heads, headdim
    xt = x[:, 0]
    z = xt @ params["wz"]
    xc, ncx = _conv_step(xt @ params["wx"], state["conv_x"], params["conv_x"])
    bb, ncb = _conv_step(xt @ params["wb"], state["conv_b"], params["conv_b"])
    cc, ncc = _conv_step(xt @ params["wc"], state["conv_c"], params["conv_c"])
    dt = jax.nn.softplus(
        (xt @ params["wdt"]).astype(jnp.float32) + params["dt_bias"]
    )  # [B,H]
    decay = jnp.exp(dt * (-jnp.exp(params["A_log"])))  # [B,H]
    xh = xc.reshape(b, h, p).astype(jnp.float32)
    xdt = xh * dt[..., None]
    s_new = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", bb, xdt
    )
    y = jnp.einsum("bn,bhpn->bhp", cc, s_new) + params["D"][None, :, None] * xh
    y = y.reshape(b, d_inner)
    y = rmsnorm(params["norm_scale"], y * jax.nn.silu(z.astype(jnp.float32)), eps=norm_eps)
    out = (y.astype(x.dtype) @ params["out_proj"])[:, None, :]
    return out, {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "ssm": s_new}
