"""Rotary position embeddings: standard RoPE and M-RoPE (Qwen2-VL).

M-RoPE splits each head's rotary dimensions into (temporal, height, width)
sections and rotates each section by its own position stream; plain text uses
identical t/h/w positions, images advance h/w per patch. The backbone here
receives the 3×positions stream from the (stubbed) modality frontend.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["rope_frequencies", "apply_rope", "apply_mrope", "mrope_text_positions"]


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """f32[head_dim/2] inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x[..., :half], x[..., half:]) by ``angles`` [..., half]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jnp.ndarray,  # [B, S, H, hd]
    positions: jnp.ndarray,  # int32[B, S]
    theta: float,
) -> jnp.ndarray:
    inv = rope_frequencies(x.shape[-1], theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * inv  # [B, S, hd/2]
    return _rotate(x, angles[:, :, None, :])


def apply_mrope(
    x: jnp.ndarray,  # [B, S, H, hd]
    positions: jnp.ndarray,  # int32[3, B, S]  (t, h, w streams)
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_frequencies(hd, theta)  # [half]
    # build a per-frequency position stream: first `sections[0]` freqs follow
    # the temporal stream, next follow height, last follow width.
    angle_parts = []
    off = 0
    for sec, pos in zip(sections, positions):
        angle_parts.append(
            pos[..., None].astype(jnp.float32) * inv[off : off + sec]
        )  # [B, S, sec]
        off += sec
    angles = jnp.concatenate(angle_parts, axis=-1)  # [B, S, half]
    return _rotate(x, angles[:, :, None, :])


def mrope_text_positions(batch: int, seq: int) -> jnp.ndarray:
    """Pure-text M-RoPE degenerates to three identical streams."""
    p = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    return jnp.broadcast_to(p[None], (3, batch, seq))
