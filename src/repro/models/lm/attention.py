"""Multi-head attention: GQA/MQA, qk-norm, QKV bias, RoPE/M-RoPE, three impls.

Implementations (cfg.attention_impl):
* ``chunked`` — online-softmax over KV chunks via ``lax.scan`` (flash-attention
  algorithm expressed in XLA). Default: O(S·C) activation memory instead of
  O(S²), honest HLO for the dry-run roofline, and the same math as the Pallas
  kernel.
* ``xla``     — single einsum + softmax (small sequences / tests).
* ``flash``   — the Pallas TPU kernel (kernels/flash_attention); deployment
  fast path, validated in interpret mode against ``xla``.

Sharding note: projections are sharded on their FLAT output axis (H·hd),
which is 128-divisible for every assigned arch even when the head count is
not (smollm's 15 heads, qwen2-vl's 28) — attention-internal layout is then
chosen by the policy (context-parallel queries), not by head divisibility.

GQA grouping is computed by reshaping q to [B, S, KV, group, hd] — kv tensors
are never materially repeated.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.norm import rmsnorm, rmsnorm_init
from repro.models.lm.rope import apply_mrope, apply_rope

__all__ = ["attn_init", "attention", "decode_attention", "AttnStatics"]

NEG_INF = -1e30


def _he(key, shape, scale_dim):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(scale_dim)).astype(
        jnp.float32
    )


def attn_init(
    key,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    *,
    padded_heads: Optional[int] = None,
    qkv_bias: bool = False,
    qk_norm: bool = False,
    dtype=jnp.bfloat16,
) -> Dict:
    hp = padded_heads or num_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wq = _he(k1, (d_model, hp * head_dim), d_model)
    wo = _he(k4, (hp * head_dim, d_model), hp * head_dim)
    if hp > num_heads:  # zero the inert padded heads (exactness, see module doc)
        wq = wq.at[:, num_heads * head_dim :].set(0.0)
        wo = wo.at[num_heads * head_dim :, :].set(0.0)
    p = {
        "wq": wq.astype(dtype),
        "wk": _he(k2, (d_model, num_kv_heads * head_dim), d_model).astype(dtype),
        "wv": _he(k3, (d_model, num_kv_heads * head_dim), d_model).astype(dtype),
        "wo": wo.astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((hp * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim)
        p["k_norm"] = rmsnorm_init(head_dim)
    return p


class AttnStatics:
    """Static knobs threaded through the transformer (not traced)."""

    def __init__(
        self,
        num_heads: int,
        num_kv_heads: int,
        head_dim: int,
        *,
        padded_heads: Optional[int] = None,
        rope_theta: float = 1e4,
        mrope: bool = False,
        mrope_sections: Tuple[int, int, int] = (16, 24, 24),
        qk_norm: bool = False,
        impl: str = "chunked",
        chunk: int = 512,
        causal: bool = True,
        norm_eps: float = 1e-6,
        use_rope: bool = True,
    ):
        self.use_rope = use_rope
        self.num_heads = padded_heads or num_heads
        self.num_kv_heads = num_kv_heads
        self.head_dim = head_dim
        self.rope_theta = rope_theta
        self.mrope = mrope
        self.mrope_sections = mrope_sections
        self.qk_norm = qk_norm
        self.impl = impl
        self.chunk = chunk
        self.causal = causal
        self.norm_eps = norm_eps


def _project_qkv(params, x, st: AttnStatics, positions):
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, st.num_heads, st.head_dim)
    k = k.reshape(b, s, st.num_kv_heads, st.head_dim)
    v = v.reshape(b, s, st.num_kv_heads, st.head_dim)
    if st.qk_norm:
        q = rmsnorm(params["q_norm"], q, eps=st.norm_eps)
        k = rmsnorm(params["k_norm"], k, eps=st.norm_eps)
    if positions is not None:
        if st.mrope:
            q = apply_mrope(q, positions, st.rope_theta, st.mrope_sections)
            k = apply_mrope(k, positions, st.rope_theta, st.mrope_sections)
        else:
            q = apply_rope(q, positions, st.rope_theta)
            k = apply_rope(k, positions, st.rope_theta)
    return q, k, v


def _sdpa_xla(q, k, v, *, causal: bool, scale: float):
    """[B,S,KV,G,hd] x [B,T,KV,hd] full-materialization attention."""
    b, s, kv, g, hd = q.shape
    t = k.shape[1]
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        scores = jnp.where((kpos - (t - s)) > qpos, NEG_INF, scores)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", probs, v)


def _sdpa_chunked(q, k, v, *, causal: bool, scale: float, chunk: int):
    """Q-block-chunked attention: ``scan`` over query blocks, exact softmax
    per block over the full K/V (flash-attention memory shape in XLA).

    Scanning over Q (not KV) means the scan has NO carry — each block is
    independent — so autodiff saves only the per-block outputs, not an
    O(B·S·H·hd) accumulator per step. The per-block score tensor is transient
    and rematerialized in backward (``jax.checkpoint`` on the block body).
    Peak activation: O(B·BQ·S) scores + O(B·S·H·hd) outputs, vs O(B·S²) for
    the naive path.
    """
    b, s, kv, g, hd = q.shape
    t = k.shape[1]
    c = min(chunk, s)
    nc = -(-s // c)
    sp = nc * c
    if sp != s:
        q = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0), (0, 0)))
    qc = q.reshape(b, nc, c, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kpos = jnp.arange(t)[None, :]

    @jax.checkpoint
    def block(ci, qb):
        # qb: [B, c, kv, g, hd]
        scores = jnp.einsum("bskgh,btkh->bkgst", qb, k).astype(jnp.float32) * scale
        qpos = ci * c + jnp.arange(c)[:, None] + (t - s)
        mask = qpos >= t + (t - s)  # q padding rows (never selected anyway)
        m = kpos > qpos if causal else jnp.zeros((c, t), bool)
        scores = jnp.where(m[None, None, None, :, :], NEG_INF, scores)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgst,btkh->bskgh", probs, v)

    def body(_, inputs):
        ci, qb = inputs
        return None, block(ci, qb)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nc), qc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sp, kv, g, hd)
    return out[:, :s]


def attention(
    params: Dict,
    x: jnp.ndarray,  # [B, S, D]
    st: AttnStatics,
    positions: Optional[jnp.ndarray] = None,
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn K/V source
    return_kv: bool = False,
    policy=None,
):
    """Full-sequence attention (train / prefill / encoder / cross).

    ``return_kv=True`` additionally returns this layer's (k, v) [B,S,KV,hd]
    so prefill can populate the decode cache in one pass. ``policy`` applies
    the attention-internal sharding layout (context-parallel queries)."""
    b, s, d = x.shape
    g = st.num_heads // st.num_kv_heads
    scale = 1.0 / math.sqrt(st.head_dim)
    if kv is None:
        q, k, v = _project_qkv(params, x, st, positions)
    else:  # cross-attention: q from x, k/v precomputed from the encoder
        q = (x @ params["wq"]).reshape(b, s, st.num_heads, st.head_dim)
        if st.qk_norm:
            q = rmsnorm(params["q_norm"], q, eps=st.norm_eps)
        k, v = kv
    if policy is not None:
        q, k, v = policy.qkv(q, k, v)
    qg = q.reshape(b, s, st.num_kv_heads, g, st.head_dim)
    if st.impl == "flash" and kv is None and st.causal:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=True)
        out = out.reshape(b, s, st.num_kv_heads, g, st.head_dim)
    elif st.impl == "chunked":
        out = _sdpa_chunked(qg, k, v, causal=st.causal and kv is None, scale=scale, chunk=st.chunk)
    else:
        out = _sdpa_xla(qg, k, v, causal=st.causal and kv is None, scale=scale)
    out = out.reshape(b, s, st.num_heads * st.head_dim)
    out = out @ params["wo"]
    if return_kv:
        return out, k, v
    return out


def project_kv(params: Dict, x: jnp.ndarray, st: AttnStatics):
    """K/V projection alone (cross-attention source, computed once)."""
    b, s, _ = x.shape
    k = (x @ params["wk"]).reshape(b, s, st.num_kv_heads, st.head_dim)
    v = (x @ params["wv"]).reshape(b, s, st.num_kv_heads, st.head_dim)
    if "bk" in params:
        k = k + params["bk"].reshape(st.num_kv_heads, st.head_dim)
        v = v + params["bv"].reshape(st.num_kv_heads, st.head_dim)
    if st.qk_norm:
        k = rmsnorm(params["k_norm"], k, eps=st.norm_eps)
    return k, v


def quantize_kv(k: jnp.ndarray):
    """Per-(batch, position, kv-head) symmetric int8: k [B,S,KV,hd] ->
    (int8 same shape, f32 scale [B,S,KV]). The 4× lighter cache stream is the
    decode-roofline lever (EXPERIMENTS.md §Perf, decode cells)."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(amax / 127.0, 1e-8)
    kq = jnp.clip(jnp.round(k.astype(jnp.float32) / s[..., None]), -127, 127)
    return kq.astype(jnp.int8), s


def decode_attention(
    params: Dict,
    x: jnp.ndarray,  # [B, 1, D] current token
    st: AttnStatics,
    k_cache: jnp.ndarray,  # [B, L, KV, hd] (bf16/f32 or int8)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # int32[] tokens already in cache
    k_scale: Optional[jnp.ndarray] = None,  # f32[B, L, KV] when int8
    v_scale: Optional[jnp.ndarray] = None,
):
    """One decode step: append this token's K/V at ``cache_len``, attend over
    the valid prefix. Returns (out, k_cache, v_cache[, k_scale, v_scale]).

    With an int8 cache, dequantization folds into the einsums: scores pick up
    the per-position K scale; the V scale multiplies the (already f32) probs —
    the MXU stream stays int8 end-to-end."""
    b, _, d = x.shape
    l = k_cache.shape[1]
    g = st.num_heads // st.num_kv_heads
    scale = 1.0 / math.sqrt(st.head_dim)
    if not st.use_rope:
        pos = None
    elif st.mrope:
        pos = jnp.broadcast_to(cache_len, (3, b, 1)).astype(jnp.int32)
    else:
        pos = jnp.broadcast_to(cache_len, (b, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(params, x, st, pos)
    int8_cache = k_cache.dtype == jnp.int8
    if int8_cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, cache_len, axis=1)
        v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, cache_len, axis=1)
        k, v = kq, vq
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
    qg = q.reshape(b, st.num_kv_heads, g, st.head_dim)
    if int8_cache:
        scores = jnp.einsum(
            "bkgh,btkh->bkgt", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
        ) * scale
        scores = scores * k_scale.transpose(0, 2, 1)[:, :, None, :]
    else:
        scores = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(l)[None, :] <= cache_len  # includes the new token
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if int8_cache:
        pv = probs * v_scale.transpose(0, 2, 1)[:, :, None, :]  # fold V scale
        out = jnp.einsum("bkgt,btkh->bkgh", pv, v_cache.astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        out = jnp.einsum("bkgt,btkh->bkgh", probs.astype(v_cache.dtype), v_cache)
    out = out.reshape(b, 1, st.num_heads * st.head_dim) @ params["wo"]
    if int8_cache:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache
