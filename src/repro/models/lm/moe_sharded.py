"""shard_map MoE: explicit event-driven EP dispatch (§Perf cell C iteration 2).

GSPMD resolves the batched scatter of the capacity dispatch by materializing
full [T, D] buffers and all-reducing them — measured 480 GB of f32 AR per
llama4 train step. This module replaces partitioner guesswork with the
explicit schedule, which is also the faithful NoC analogue: every data shard
runs its own nodeslot pool (local sort/rank/capacity — zero cross-shard
traffic), each model shard executes only its expert slice against the
*already model-replicated* token activations, and a single psum over "model"
assembles the combine — the only activation collective in the whole layer.

Communication per layer (per device):
  * expert-weight FSDP all-gather over "data"   (O(weights/TP), unavoidable)
  * one psum of [T_loc, D] over "model"          (the combine)
vs the GSPMD path's multiple full-[T, D] f32 all-reduces.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.models.lm.moe import moe_init  # noqa: F401 (same param layout)

__all__ = ["moe_apply_sharded", "sharded_applicable"]


def sharded_applicable(policy, num_experts: int, t: int, d_ff: int, tp_needed=None) -> bool:
    """shard_map path needs: a real mesh policy in TP mode and divisible
    tokens. Two variants: EP (experts % model axis == 0) or replicated-expert
    token-parallel (any expert count, tokens divisible by the whole mesh)."""
    mesh = getattr(policy, "mesh", None)
    if mesh is None or getattr(policy, "mode", "tp") != "tp":
        return False
    tp = mesh.shape["model"]
    dp = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            dp *= mesh.shape[a]
    if num_experts % tp == 0 and t % dp == 0:
        return True
    return t % (dp * tp) == 0  # replicated-expert variant (e.g. granite)


def _ag_fsdp(w: jnp.ndarray, axis_name: str, dim: int, full: int) -> jnp.ndarray:
    """Explicit FSDP gather: restore dimension ``dim`` to ``full`` size."""
    if w.shape[dim] == full:
        return w
    return jax.lax.all_gather(w, axis_name, axis=dim, tiled=True)


def moe_apply_sharded(
    params: Dict,
    x: jnp.ndarray,  # [B, S, D]
    *,
    num_experts: int,
    top_k: int,
    kind: str,
    capacity_factor: float,
    policy,
):
    mesh = policy.mesh
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    tp = mesh.shape["model"]
    b, s, d = x.shape
    t = b * s
    e = num_experts
    ep = e % tp == 0
    e_loc = e // tp if ep else e
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    # Replicated-expert variant (non-EP, e.g. granite's 40 experts): tokens
    # shard over BOTH axes and every device runs its own complete nodeslot
    # pool against the full (data-FSDP-gathered) expert set — the MoE layer
    # then needs NO activation collective at all.
    token_axes = dp_axes if ep else dp_axes + ("model",)
    t_loc = t // (dp if ep else dp * tp)
    cap = max(1, int(math.ceil(t_loc * top_k / e * capacity_factor)))
    up_name = "w_gate" if "w_gate" in params["experts"] else "w_in"
    d_ff = params["experts"][up_name].shape[-1]
    has_shared = "shared" in params
    if not ep and has_shared:
        raise NotImplementedError("replicated-expert path w/ shared expert")

    def local(xf, router, experts, shared):
        # xf: [t_loc, d] — this data shard's tokens, replicated over "model".
        m_idx = jax.lax.axis_index("model")
        logits = xf.astype(jnp.float32) @ router  # [t_loc, E] (full router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_idx = jax.lax.top_k(probs, top_k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        # local nodeslot schedule (identical on every model shard — cheap,
        # and keeping it redundant avoids broadcasting the schedule)
        flat_e = gate_idx.reshape(t_loc * top_k)
        order = jnp.argsort(flat_e, stable=True)
        se = flat_e[order]
        token_of = order // top_k
        counts = jnp.bincount(se, length=e)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(t_loc * top_k) - starts[se]
        keep = rank < cap

        # my expert slice only (EP); replicated variant owns all experts
        if ep:
            mine = keep & (se >= m_idx * e_loc) & (se < (m_idx + 1) * e_loc)
            slot = jnp.where(mine, (se - m_idx * e_loc) * cap + rank, e_loc * cap)
        else:
            mine = keep
            slot = jnp.where(mine, se * cap + rank, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, d), x.dtype).at[slot].set(xf[token_of])
        xin = buf[: e_loc * cap].reshape(1, e_loc, cap, d)

        # FSDP gather of this shard's expert weights over the data axis
        if ep:
            eff = {
                k_: _ag_fsdp(w, "data", 1,
                             d if k_ in ("w_gate", "w_up", "w_in") else d_ff)
                for k_, w in experts.items()
            }
        else:  # non-EP rules FSDP w_in on D(dim1) and w_out on D(dim2)
            eff = {
                k_: _ag_fsdp(w, "data", 1 if k_ in ("w_gate", "w_up", "w_in") else 2, d)
                for k_, w in experts.items()
            }
        from repro.models.lm.moe import _expert_ffn

        yflat = _expert_ffn(eff, xin, kind)[0].reshape(e_loc * cap, d)
        wsorted = gate_w.reshape(t_loc * top_k)[order]
        contrib = jnp.where(
            mine[:, None], yflat[jnp.minimum(slot, e_loc * cap - 1)], 0.0
        ) * wsorted[:, None].astype(x.dtype)
        out = jnp.zeros((t_loc, d), x.dtype).at[token_of].add(contrib)

        if not ep:  # replicated-expert variant: combine is complete locally
            return out, _aux(counts, probs)
        if shared is not None:  # TP'd shared expert folded into the same psum
            sg = {k_: _ag_fsdp(w, "data", 0 if k_ in ("w_gate", "w_up", "w_in") else 1,
                               d) for k_, w in shared.items()}
            if kind == "swiglu":
                h = jax.nn.silu(xf @ sg["w_gate"]) * (xf @ sg["w_up"])
                out = out + (h @ sg["w_down"]).astype(x.dtype) / 1  # partial over f
            else:
                h = xf @ sg["w_in"]
                h = jnp.square(jax.nn.relu(h)) if kind == "relu2" else jax.nn.gelu(h)
                out = out + (h @ sg["w_out"]).astype(x.dtype)
        out = jax.lax.psum(out, "model")
        return out, _aux(counts, probs)

    def _aux(counts, probs):
        # load-balance aux: mean over every token shard
        f_e = counts.astype(jnp.float32) / (t_loc * top_k)
        p_e = probs.mean(axis=0)
        aux = e * jnp.sum(f_e * p_e)
        for a in token_axes:
            aux = jax.lax.pmean(aux, a)
        return aux

    if ep:
        expert_specs = {k_: P("model", "data", None) for k_ in params["experts"]}
    else:  # replicated over model, FSDP over data (matches the param rules)
        expert_specs = {
            k_: (P(None, "data", None) if k_ in ("w_gate", "w_up", "w_in")
                 else P(None, None, "data"))
            for k_ in params["experts"]
        }
    shared_specs = None
    shared_arg = None
    if has_shared:
        shared_specs = {
            k_: (P("data", "model") if k_ in ("w_gate", "w_up", "w_in") else P("model", "data"))
            for k_ in params["shared"]
        }
        shared_arg = params["shared"]

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(token_axes, None), P(None, None), expert_specs, shared_specs),
        out_specs=(P(token_axes, None), P()),
    )
    out, aux = fn(x.reshape(t, d), params["router"], params["experts"], shared_arg)
    return out.reshape(b, s, d), aux
