"""The paper's three benchmark GNNs (Table 3) on the AMPLE engine."""
from repro.models.gnn import gcn, gin, sage

MODELS = {"gcn": gcn, "gin": gin, "sage": sage}
