"""The paper's three benchmark GNNs (Table 3), behind the arch registry.

Use the uniform surface in :mod:`repro.models.gnn.api` (``gnn_init`` /
``gnn_apply`` / ``gnn_reference``) or go through the family-agnostic
``repro.models.api`` with a ``family="gnn"`` ModelConfig.
"""
from repro.models.gnn import gcn, gin, sage  # registers the archs
from repro.models.gnn.api import (
    ArchSpec,
    get_arch,
    gnn_apply,
    gnn_forward,
    gnn_init,
    gnn_reference,
    list_archs,
    register_arch,
)

MODELS = {"gcn": gcn, "gin": gin, "sage": sage}
