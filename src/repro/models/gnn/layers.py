"""Shared initialisers and reference ops for the GNN model zoo."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["glorot", "linear_init", "mlp_init", "mlp_apply", "relu"]


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    limit = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def linear_init(key, in_dim: int, out_dim: int, *, bias: bool = True) -> Dict:
    kw, _ = jax.random.split(key)
    p = {"w": glorot(kw, (in_dim, out_dim))}
    if bias:
        p["b"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def mlp_init(key, dims: List[int], *, bias: bool = True) -> Dict:
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "layers": [
            linear_init(k, dims[i], dims[i + 1], bias=bias)
            for i, k in enumerate(keys)
        ]
    }


def mlp_apply(params: Dict, x: jnp.ndarray, *, final_activation=None) -> jnp.ndarray:
    n = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        x = x @ lyr["w"]
        if "b" in lyr:
            x = x + lyr["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


relu = jax.nn.relu
