"""GNN model registry — the family="gnn" half of the unified model API.

Every arch (gcn / gin / sage / gat) registers an ``ArchSpec`` with three
uniform, config-driven entry points:

    init(cfg, key)                     -> params
    apply(cfg, params, engine, x)      -> node outputs (through AmpleEngine)
    reference(cfg, params, g, x)       -> dense float oracle (test-scale)

replacing the historical per-module ``init(key, dims)`` signatures. Layer
dims, aggregation mode and precision policy all come from ``ModelConfig``
(``gnn_layer_dims``, ``gnn_agg``, ``gnn_precision``), so ``models/api.py``
can dispatch LM and GNN configs through the same five-function surface —
the software analogue of AMPLE's single NID host interface across models.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.message_passing import AmpleEngine, EngineConfig, compile_sharded_plans
from repro.graphs.csr import Graph, add_self_loops

__all__ = [
    "ArchSpec",
    "register_arch",
    "get_arch",
    "list_archs",
    "agg_mode",
    "engine_config",
    "prepare_graph",
    "make_engine",
    "gnn_init",
    "gnn_apply",
    "gnn_reference",
    "gnn_forward",
]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """A registered GNN architecture: uniform entry points + plan needs."""

    name: str
    init: Callable[[ModelConfig, object], Dict]
    apply: Callable[[ModelConfig, Dict, AmpleEngine, jnp.ndarray], jnp.ndarray]
    reference: Callable[[ModelConfig, Dict, Graph, jnp.ndarray], jnp.ndarray]
    default_agg: str  # aggregation coefficient mode when cfg.gnn_agg == ""
    needs_self_loops: bool = False  # GCN's ∪{i} term is an explicit edge


_ARCHS: Dict[str, ArchSpec] = {}

_ARCH_MODULES = ["gcn", "gin", "sage", "gat"]


def register_arch(
    name: str,
    *,
    init,
    apply,
    reference,
    default_agg: str,
    needs_self_loops: bool = False,
) -> ArchSpec:
    spec = ArchSpec(
        name=name,
        init=init,
        apply=apply,
        reference=reference,
        default_agg=default_agg,
        needs_self_loops=needs_self_loops,
    )
    _ARCHS[name] = spec
    return spec


def _ensure_loaded() -> None:
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.models.gnn.{m}")


def get_arch(name: str) -> ArchSpec:
    _ensure_loaded()
    if name not in _ARCHS:
        raise KeyError(f"unknown GNN arch {name!r}; have {sorted(_ARCHS)}")
    return _ARCHS[name]


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_ARCHS))


# ------------------------------------------------------------- config glue
def agg_mode(cfg: ModelConfig) -> str:
    """The aggregation coefficient mode this config's plans are built with."""
    return cfg.gnn_agg or get_arch(cfg.gnn_arch).default_agg


def engine_config(cfg: ModelConfig) -> EngineConfig:
    """Map the ModelConfig precision/tiling policy onto an EngineConfig."""
    if cfg.gnn_precision not in ("mixed", "float"):
        raise ValueError(f"unknown gnn_precision {cfg.gnn_precision!r}")
    return EngineConfig(
        edges_per_tile=cfg.gnn_edges_per_tile,
        mixed_precision=cfg.gnn_precision == "mixed",
        use_kernel=cfg.gnn_use_kernel,
    )


def prepare_graph(cfg: ModelConfig, g: Graph) -> Graph:
    """Arch-specific structural preprocessing (idempotent)."""
    if get_arch(cfg.gnn_arch).needs_self_loops:
        return add_self_loops(g)
    return g


def make_engine(
    cfg: ModelConfig,
    prepared: Graph,
    *,
    num_shards: Optional[int] = None,
    partition=None,
    partitioner: Optional[str] = None,
    mesh=None,
    halo_overlap: Optional[bool] = None,
) -> AmpleEngine:
    """Build the execution engine ``cfg`` calls for over a *prepared* graph.

    ``gnn_num_shards`` (or the explicit ``num_shards``/``partition``
    overrides) selects between the single-plan ``AmpleEngine`` and the
    partition-aware ``ShardedAmpleEngine`` — the arch apply functions are
    agnostic, so gcn/gin/sage thread through either unchanged.
    ``gnn_partitioner`` picks the splitting algorithm ("edges" contiguous /
    "mincut" halo-minimizing) and ``gnn_halo_overlap`` the overlapped halo
    exchange; the keyword arguments override the config fields.
    """
    shards = cfg.gnn_num_shards if num_shards is None else num_shards
    if partition is None and shards <= 1:
        return AmpleEngine(prepared, engine_config(cfg))
    from repro.distributed.graph_shard import ShardedAmpleEngine

    splan = compile_sharded_plans(
        prepared,
        engine_config(cfg),
        num_shards=None if partition is not None else shards,
        partition=partition,
        partitioner=(
            cfg.gnn_partitioner if partitioner is None else partitioner
        ) or "edges",
        modes=(agg_mode(cfg),),
    )
    return ShardedAmpleEngine(
        prepared,
        splan,
        mesh=mesh,
        halo_overlap=(
            cfg.gnn_halo_overlap if halo_overlap is None else halo_overlap
        ),
    )


# --------------------------------------------------- uniform entry points
def gnn_init(cfg: ModelConfig, key) -> Dict:
    return get_arch(cfg.gnn_arch).init(cfg, key)


def gnn_apply(cfg: ModelConfig, params: Dict, engine: AmpleEngine, x) -> jnp.ndarray:
    from repro.memory.prefetcher import StreamedFeatures

    if not isinstance(x, StreamedFeatures):  # streamed handles pass through
        x = jnp.asarray(x)
    return get_arch(cfg.gnn_arch).apply(cfg, params, engine, x)


def gnn_reference(cfg: ModelConfig, params: Dict, g: Graph, x) -> jnp.ndarray:
    """Dense-adjacency float oracle on the *prepared* graph (test-scale)."""
    return get_arch(cfg.gnn_arch).reference(
        cfg, params, prepare_graph(cfg, g), jnp.asarray(x)
    )


def gnn_forward(params: Dict, cfg: ModelConfig, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """model_forward body for family="gnn".

    ``batch`` carries ``graph`` (a CSR Graph) and ``features`` f32[N, D];
    callers holding a compiled engine (the serving path) pass it as
    ``batch["engine"]`` to skip plan compilation. ``features`` may also be a
    ``memory.StreamedFeatures`` handle — the out-of-core path: the feature
    matrix stays host-resident and the engine streams it chunk-wise under
    the handle's budget. Returns ``(logits, aux)`` with logits
    f32[N, num_classes], matching the LM tuple contract so ``loss_fn``
    works unchanged for node classification.
    """
    from repro.memory.prefetcher import StreamedFeatures

    feats = batch["features"]
    x = feats if isinstance(feats, StreamedFeatures) else jnp.asarray(feats)
    engine = batch.get("engine")
    n = engine.graph.num_nodes if engine is not None else batch["graph"].num_nodes
    want = cfg.gnn_layer_dims[0]
    if x.ndim != 2 or tuple(x.shape) != (n, want):
        raise ValueError(
            f"features must be [{n}, {want}] for {cfg.name} on this graph "
            f"(num_nodes={n}, cfg.d_model={want}), got {tuple(x.shape)}"
        )
    if engine is None:
        g = prepare_graph(cfg, batch["graph"])
        engine = make_engine(cfg, g)
    engine.begin_forward()
    y = gnn_apply(cfg, params, engine, x)
    return y, jnp.asarray(0.0, jnp.float32)
