"""GraphSAGE (Hamilton et al.) on the AMPLE engine — Eq. 4 of the paper.

    x_i' = W1 x_i + W2 · mean_{j ∈ N(i)} σ(W3 x_j + b)

φ is a dense projection applied to *all* nodes once (every node is someone's
neighbour), the mean runs through the event-driven AGE with 1/deg
coefficients, and γ adds the W1 transformation-side residual (Table 3).

Entry points are uniform and config-driven (see models/gnn/api.py).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.message_passing import AmpleEngine
from repro.graphs.csr import Graph
from repro.models.gnn import api
from repro.models.gnn.layers import linear_init

__all__ = ["init", "apply", "reference"]


def init(cfg: ModelConfig, key) -> Dict:
    dims = cfg.gnn_layer_dims
    layers = []
    for i in range(len(dims) - 1):
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append(
            {
                "w1": linear_init(k1, dims[i], dims[i + 1], bias=False),
                "w2": linear_init(k2, dims[i], dims[i + 1], bias=False),
                "w3": linear_init(k3, dims[i], dims[i], bias=True),
            }
        )
    return {"layers": layers}


def apply(cfg: ModelConfig, params: Dict, engine: AmpleEngine, x: jnp.ndarray) -> jnp.ndarray:
    mode = api.agg_mode(cfg)
    n = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        msgs = engine.transform(x, lyr["w3"]["w"], lyr["w3"]["b"], jax.nn.relu)  # φ
        m = engine.aggregate(msgs, mode=mode)  # A
        x = engine.transform(x, lyr["w1"]["w"]) + engine.transform(m, lyr["w2"]["w"])
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def reference(cfg: ModelConfig, params: Dict, g: Graph, x: jnp.ndarray) -> jnp.ndarray:
    import numpy as np

    a = g.dense_adjacency()
    deg = np.maximum(a.sum(axis=1, keepdims=True), 1.0)
    a_mean = jnp.asarray(a / deg)
    n = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        msgs = jax.nn.relu(x @ lyr["w3"]["w"] + lyr["w3"]["b"])
        m = a_mean @ msgs
        x = x @ lyr["w1"]["w"] + m @ lyr["w2"]["w"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


api.register_arch(
    "sage",
    init=init,
    apply=apply,
    reference=reference,
    default_agg="mean",
)
