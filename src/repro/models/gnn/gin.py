"""GIN (Xu et al.) on the AMPLE engine — Eq. 3 of the paper.

    x_i' = MLP( (1 + ε) · x_i  +  Σ_{j ∈ N(i)} x_j )

Aggregation: plain sum, no normalisation; residual on the aggregation side
(Table 3) — the (1+ε)x_i term. The MLP (2 layers, ReLU) is the γ transform and
runs through the engine's mixed-precision FTE one linear at a time.

Entry points are uniform and config-driven (see models/gnn/api.py).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.message_passing import AmpleEngine
from repro.graphs.csr import Graph
from repro.memory.prefetcher import StreamedFeatures, scale_add_streamed
from repro.models.gnn import api
from repro.models.gnn.layers import mlp_init

__all__ = ["init", "apply", "reference"]


def init(cfg: ModelConfig, key, *, hidden_mult: int = 1, eps: float = 0.0) -> Dict:
    """One 2-layer MLP per GNN layer: [d_in -> d_out*mult -> d_out]."""
    dims = cfg.gnn_layer_dims
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "eps": jnp.asarray(eps, jnp.float32),
        "layers": [
            mlp_init(k, [dims[i], dims[i + 1] * hidden_mult, dims[i + 1]])
            for i, k in enumerate(keys)
        ],
    }


def _mlp_through_engine(engine: AmpleEngine, mlp: Dict, h: jnp.ndarray) -> jnp.ndarray:
    n = len(mlp["layers"])
    for i, lyr in enumerate(mlp["layers"]):
        h = engine.transform(
            h,
            lyr["w"],
            lyr.get("b"),
            activation=jax.nn.relu if i < n - 1 else None,
        )
    return h


def apply(cfg: ModelConfig, params: Dict, engine: AmpleEngine, x: jnp.ndarray) -> jnp.ndarray:
    mode = api.agg_mode(cfg)
    n = len(params["layers"])
    for i, mlp in enumerate(params["layers"]):
        m = engine.aggregate(x, mode=mode)
        if isinstance(x, StreamedFeatures):  # out-of-core first layer
            h = scale_add_streamed(x, 1.0 + params["eps"], m)
        else:
            h = (1.0 + params["eps"]) * x + m  # aggregation-side residual
        x = _mlp_through_engine(engine, mlp, h)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def reference(cfg: ModelConfig, params: Dict, g: Graph, x: jnp.ndarray) -> jnp.ndarray:
    a = jnp.asarray(g.dense_adjacency())
    n = len(params["layers"])
    for i, mlp in enumerate(params["layers"]):
        h = (1.0 + params["eps"]) * x + a @ x
        for k, lyr in enumerate(mlp["layers"]):
            h = h @ lyr["w"] + lyr.get("b", 0.0)
            if k < len(mlp["layers"]) - 1:
                h = jax.nn.relu(h)
        x = jax.nn.relu(h) if i < n - 1 else h
    return x


api.register_arch(
    "gin",
    init=init,
    apply=apply,
    reference=reference,
    default_agg="sum",
)
