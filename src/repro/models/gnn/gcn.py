"""GCN (Kipf & Welling) on the AMPLE engine — Eq. 2 of the paper.

    x_i' = W ( Σ_{j ∈ N(i) ∪ {i}}  e_ji / √(d̂_j d̂_i) · x_j )

Aggregation: sum with GCN normalisation coefficients (folded into the plan);
no residual; normalisation on the aggregation side (Table 3). The graph must
carry explicit self-loops so the ∪{i} term is an edge — the registry's
``needs_self_loops`` flag makes ``prepare_graph`` add them.

Entry points are uniform and config-driven (see models/gnn/api.py): layer
dims come from ``cfg.gnn_layer_dims``, the coefficient mode from
``api.agg_mode(cfg)``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.message_passing import AmpleEngine
from repro.graphs.csr import Graph, gcn_norm_coeffs
from repro.models.gnn import api
from repro.models.gnn.layers import glorot

__all__ = ["init", "apply", "reference"]


def init(cfg: ModelConfig, key) -> Dict:
    """One weight per layer (Eq. 2 has no bias)."""
    dims = cfg.gnn_layer_dims
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "layers": [
            {"w": glorot(k, (dims[i], dims[i + 1]))} for i, k in enumerate(keys)
        ]
    }


def apply(cfg: ModelConfig, params: Dict, engine: AmpleEngine, x: jnp.ndarray) -> jnp.ndarray:
    mode = api.agg_mode(cfg)
    n = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        m = engine.aggregate(x, mode=mode)
        x = engine.transform(
            m, lyr["w"], activation=jax.nn.relu if i < n - 1 else None
        )
    return x


def reference(cfg: ModelConfig, params: Dict, g: Graph, x: jnp.ndarray) -> jnp.ndarray:
    """Dense-adjacency float oracle (test-scale only)."""
    import numpy as np

    a = g.dense_adjacency()
    coeff = gcn_norm_coeffs(g)
    rows = np.repeat(np.arange(g.num_nodes), g.degrees)
    a_norm = np.zeros_like(a)
    a_norm[rows, g.indices] = coeff
    a_norm = jnp.asarray(a_norm)
    n = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        x = (a_norm @ x) @ lyr["w"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


api.register_arch(
    "gcn",
    init=init,
    apply=apply,
    reference=reference,
    default_agg="gcn",
    needs_self_loops=True,
)
