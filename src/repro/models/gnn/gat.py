"""GAT (Veličković et al.) on the AMPLE engine — runtime edge coefficients.

    e_ij   = LeakyReLU( a_src · (W x_j)  +  a_dst · (W x_i) )
    α_ij   = softmax_{j ∈ N(i) ∪ {i}} e_ij          (per destination segment)
    x_i'   = ‖_h  Σ_{j}  α_ij · W_h x_j             (concat heads; mean on the
                                                     output layer)

Unlike the Table-3 family, the aggregation coefficient is not a structural
constant: α depends on the node features, per layer, per request. The engine
therefore compiles plans in ``"runtime"`` mode (static coeff 1 as a pure lane
mask) and the attention vector is scattered through the plan's ``edge_ids``
indirection at request time — plans, size classes and shard caches all stay
structure-keyed, exactly as for GCN/GIN/SAGE.

The destination-segment softmax runs over the *same* event-driven tiles as
aggregation: a segment-max pass (numerically stable shift) and a segment-sum
denominator pass, both via the partial-response scatter mechanism
(``AmpleEngine.edge_softmax``). The dense projection W reuses the engine's
mixed-precision FTE, so Degree-Quant tags carry over unchanged; attention
scores and coefficients are always f32 (they are control values, not
bandwidth-bound embeddings).

Self-loops are explicit edges (∪{i} above), added by ``prepare_graph`` via the
registry's ``needs_self_loops`` flag — same mechanism as GCN.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.message_passing import AmpleEngine
from repro.graphs.csr import Graph
from repro.models.gnn import api
from repro.models.gnn.layers import glorot

__all__ = ["init", "apply", "reference", "LEAKY_SLOPE"]

LEAKY_SLOPE = 0.2  # the paper's LeakyReLU negative slope


def _heads(cfg: ModelConfig) -> int:
    """Every layer runs cfg.gnn_heads heads: hidden layers concatenate the
    head outputs, the output layer averages them (standard GAT practice)."""
    return max(int(cfg.gnn_heads), 1)


def _head_dim(cfg: ModelConfig, layer: int) -> int:
    dims = cfg.gnn_layer_dims
    d_out = dims[layer + 1]
    h = _heads(cfg)
    concat = layer < len(dims) - 2
    if concat:
        if d_out % h != 0:
            raise ValueError(
                f"layer {layer} output width {d_out} is not divisible by "
                f"gnn_heads={h} (hidden layers concatenate head outputs)"
            )
        return d_out // h
    return d_out  # output layer: every head spans the full width, then mean


def init(cfg: ModelConfig, key) -> Dict:
    """Per layer: one projection per head (packed [d_in, H·dh]) plus the
    split attention vectors a_src/a_dst [H, dh] (no bias, like GCN)."""
    dims = cfg.gnn_layer_dims
    layers = []
    for i in range(len(dims) - 1):
        kw, ks, kd, key = jax.random.split(key, 4)
        h = _heads(cfg)
        dh = _head_dim(cfg, i)
        layers.append(
            {
                "w": glorot(kw, (dims[i], h * dh)),
                "a_src": glorot(ks, (h, dh)),
                "a_dst": glorot(kd, (h, dh)),
            }
        )
    return {"layers": layers}


def apply(cfg: ModelConfig, params: Dict, engine: AmpleEngine, x: jnp.ndarray) -> jnp.ndarray:
    mode = api.agg_mode(cfg)
    src, dst = engine.edge_endpoints()
    n_layers = len(params["layers"])
    num_nodes = engine.graph.num_nodes
    for i, lyr in enumerate(params["layers"]):
        h = _heads(cfg)
        dh = _head_dim(cfg, i)
        concat = i < n_layers - 1
        # φ: one mixed-precision FTE over all heads at once (x may be a
        # StreamedFeatures handle on the out-of-core first layer; the
        # projection output is dense either way).
        z = engine.transform(x, lyr["w"])  # [N, H*dh]
        zh = z.reshape(num_nodes, h, dh)
        src_sc = jnp.einsum("nhd,hd->nh", zh, lyr["a_src"])  # [N, H]
        dst_sc = jnp.einsum("nhd,hd->nh", zh, lyr["a_dst"])  # [N, H]
        # RAW scores [E, H] — one edge-endpoint gather per layer; LeakyReLU,
        # softmax and the weighted aggregate all run head-vectorized inside
        # the engine (one fused Pallas launch per layer under use_kernel).
        scores = src_sc[src] + dst_sc[dst]
        out = engine.attention_aggregate(
            scores, zh, mode=mode, leaky_slope=LEAKY_SLOPE
        )  # [N, H, dh]
        x = (
            out.reshape(num_nodes, h * dh)
            if concat
            else out.sum(axis=1) / float(h)
        )
        if i < n_layers - 1:
            x = jax.nn.elu(x)
    return x


def reference(cfg: ModelConfig, params: Dict, g: Graph, x: jnp.ndarray) -> jnp.ndarray:
    """Dense-adjacency float oracle: masked softmax attention (test-scale)."""
    mask = jnp.asarray(g.dense_adjacency() > 0)  # [N, N]; row i = in-nbrs of i
    n_layers = len(params["layers"])
    num_nodes = g.num_nodes
    for i, lyr in enumerate(params["layers"]):
        h = _heads(cfg)
        dh = _head_dim(cfg, i)
        concat = i < n_layers - 1
        zh = (x @ lyr["w"]).reshape(num_nodes, h, dh)
        src_sc = jnp.einsum("nhd,hd->nh", zh, lyr["a_src"])
        dst_sc = jnp.einsum("nhd,hd->nh", zh, lyr["a_dst"])
        outs = []
        for head in range(h):
            # e[i, j] = leaky(a_src·z_j + a_dst·z_i) over edges j -> i
            e = jax.nn.leaky_relu(
                src_sc[None, :, head] + dst_sc[:, None, head], LEAKY_SLOPE
            )
            e = jnp.where(mask, e, -jnp.inf)
            m = jnp.max(e, axis=1, keepdims=True)
            m = jnp.where(jnp.isfinite(m), m, 0.0)
            ex = jnp.where(mask, jnp.exp(e - m), 0.0)
            denom = ex.sum(axis=1, keepdims=True)
            alpha = ex / jnp.where(denom > 0, denom, 1.0)
            outs.append(alpha @ zh[:, head, :])
        x = (
            jnp.concatenate(outs, axis=-1)
            if concat
            else sum(outs) / float(h)
        )
        if i < n_layers - 1:
            x = jax.nn.elu(x)
    return x


api.register_arch(
    "gat",
    init=init,
    apply=apply,
    reference=reference,
    default_agg="runtime",
    needs_self_loops=True,
)
