"""Config system: every runnable model is a ``ModelConfig`` in a registry.

``--arch <id>`` anywhere in the launcher resolves through ``get_config``.
Each assigned architecture file registers a FULL config (dry-run only — the
production mesh instantiates it as ShapeDtypeStructs) and a REDUCED config
(same family/topology, tiny dims) that smoke tests run on CPU.

Sharding-driven padding: the vocab is padded up to mesh divisibility at
parameter-init time (padded rows are never targeted; the loss masks padded
logits). Head/expert counts are NOT padded — projections shard on flat
(H·hd) axes and non-divisible expert counts fall back per the sharding
rules. FLOP accounting always uses the raw (unpadded) dimensions.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "register",
    "get_config",
    "list_configs",
    "pad_to_multiple",
]


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_layer_period: int = 1  # every k-th layer is MoE (llama4/jamba interleave)
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25

    # --- attention flavour ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    pos_embed: str = "rope"  # rope | mrope (qwen2-vl 3D) | sin (enc-dec) | none (jamba/mamba)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of hd/2
    attention_impl: str = "chunked"  # chunked | xla | flash(Pallas, TPU)

    # --- MLP flavour ---
    mlp: str = "swiglu"  # swiglu | relu2 | gelu
    mlp_bias: bool = False

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_layer_period: int = 0  # hybrid: 1 attention layer every k (jamba k=8)
    attn_layer_offset: int = 4

    # --- enc-dec ---
    encoder_layers: int = 0  # >0 => encoder-decoder (seamless)

    # --- GNN (family="gnn"): drives models/gnn/api.py ---
    gnn_arch: str = "gcn"  # gcn | gin | sage | gat (registry key)
    gnn_hidden: Tuple[int, ...] = ()  # explicit hidden widths; () -> (d_ff,)*(L-1)
    gnn_agg: str = ""  # aggregation coefficient mode override ("" = arch default)
    gnn_precision: str = "mixed"  # mixed (Degree-Quant int8/float) | float
    gnn_edges_per_tile: int = 256  # event-driven tile width (AGE lanes)
    gnn_heads: int = 1  # attention heads (gat); hidden dims must divide by it
    gnn_use_kernel: bool = False  # route AGE/FTE through the Pallas kernels
    gnn_num_shards: int = 1  # >1: partition-aware execution (edge-balanced shards)
    # Partitioner for sharded execution: "edges" = contiguous edge-balanced
    # ranges; "mincut" = halo-minimizing multilevel (METIS-style) partition.
    # Extra params ride inline, e.g. "mincut(seed=1,balance=1.1)".
    gnn_partitioner: str = "edges"
    # Overlap each shard's halo exchange with its interior-tile aggregation
    # (scheduler.split_plan_by_halo); outputs stay bitwise-identical.
    # Incompatible with gnn_use_kernel (no continuation hook in the kernel).
    gnn_halo_overlap: bool = False
    # Continuous-batching serve knobs (serve/async_gnn.py + GNNServeEngine):
    gnn_batch_window: int = 8  # max requests admitted per micro-batch union
    gnn_union_node_bucket: int = 0  # pad union batches to node size classes (0=exact)
    gnn_union_edge_bucket: int = 0  # pad union tile stacks to edge size classes
    # Latency-aware window close: a partially filled admission window is held
    # open until the oldest queued request has waited this long, then admits
    # whatever arrived (0 = historical behaviour: admit immediately).
    gnn_window_timeout_ms: float = 0.0
    # Bounded requeue-on-failure: a micro-batch window may fail execution
    # this many times before its tickets are completed exceptionally (error
    # attached) instead of being requeued at the head again.
    gnn_window_retries: int = 3
    # Out-of-core serving (memory/feature_store.py + memory/prefetcher.py):
    # requests whose feature matrix exceeds the budget keep features host-
    # resident and stream them chunk-wise (bitwise-identical outputs);
    # 0 disables streaming (everything uploads, the historical path).
    gnn_feature_budget_bytes: int = 0  # device bytes granted to feature chunks
    gnn_feature_chunk_rows: int = 0  # rows per chunk (0 = derive from budget)
    # Locality controls for the streamed path (A/B-able from serving):
    # packing rebuilds tile membership around source chunks
    # (scheduler.pack_tiles_by_chunk); reorder=False keeps plan order as the
    # control arm for the run-reordering pass.
    gnn_stream_packing: bool = False  # pack tiles by source chunk
    gnn_stream_reorder: bool = True  # locality-reorder tile runs

    # --- frontend stubs (vlm/audio): inputs arrive as embeddings ---
    embeds_input: bool = False

    # --- numerics / training ---
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "model"  # model (= dtype) | int8 (decode-memory lever)
    remat: str = "none"  # none | block  (activation checkpointing policy)
    scan_layers: bool = True

    # reduced smoke-config marker
    reduced: bool = False

    # ------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def gnn_layer_dims(self) -> Tuple[int, ...]:
        """[feature_dim, hidden..., num_classes] for the GNN family.

        d_model carries the input feature width and vocab_size the class
        count (matching the dry-run's reuse of the LM fields); hidden widths
        default to d_ff repeated across the interior layers.
        """
        hidden = self.gnn_hidden or (self.d_ff,) * max(self.num_layers - 1, 0)
        return (self.d_model, *hidden, self.vocab_size)

    @property
    def is_hybrid(self) -> bool:
        return self.attn_layer_period > 0 and self.ssm_state > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.ssm_state > 0 and self.attn_layer_period == 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def padded_heads(self, tp: int) -> int:
        return pad_to_multiple(self.num_heads, tp)

    def padded_vocab(self, tp: int) -> int:
        return pad_to_multiple(self.vocab_size, tp)

    def param_count(self) -> int:
        """Approximate raw (unpadded) parameter count; used for 6ND roofline."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (
            self.num_heads * hd
        ) * d
        if self.mlp == "swiglu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        per_expert = mlp_dense
        n_moe = (
            self.num_layers // self.moe_layer_period if self.is_moe else 0
        )
        n_dense_mlp = self.num_layers - n_moe
        n_attn = self.num_layers
        ssm = 0
        if self.ssm_state > 0:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_ssm = (
                d * (2 * di + 2 * ns + nh)  # in_proj (x, z, B, C, dt)
                + di * d  # out_proj
                + self.ssm_conv * (di + 2 * ns)
                + 3 * nh
            )
            if self.is_ssm_only:
                n_ssm = self.num_layers
                n_attn = 0
                n_dense_mlp = 0 if not self.is_moe else n_dense_mlp
                if self.d_ff == 0:
                    n_dense_mlp = 0
            else:
                n_attn = self.num_layers // self.attn_layer_period
                n_ssm = self.num_layers - n_attn
            ssm = n_ssm * per_ssm
        total = (
            n_attn * attn
            + n_dense_mlp * mlp_dense
            + n_moe * (self.num_experts * per_expert + d * self.num_experts)
            + (per_expert if (self.is_moe and self.moe_shared_expert) else 0)
            * (self.num_layers // self.moe_layer_period if self.is_moe else 0)
            + ssm
            + self.vocab_size * d * (1 if self.tie_embeddings else 2)
        )
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp_dense)  # encoder stack
            total += self.num_layers * attn  # decoder cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed experts only) for 6·N_active·D."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        per_expert = (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
        n_moe = self.num_layers // self.moe_layer_period
        inactive = n_moe * (
            (self.num_experts - self.experts_per_token) * per_expert
        )
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """An assigned input-shape cell: what gets lowered for the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_REDUCED: Dict[str, Callable[[], ModelConfig]] = {}

_ARCH_MODULES = [
    "llama4_maverick_400b_a17b",
    "granite_moe_3b_a800m",
    "qwen3_8b",
    "qwen2_1_5b",
    "smollm_360m",
    "nemotron_4_15b",
    "jamba_v0_1_52b",
    "seamless_m4t_medium",
    "qwen2_vl_7b",
    "mamba2_370m",
    "ample_gnn",
]


def register(name: str, full: Callable[[], ModelConfig], reduced: Callable[[], ModelConfig]):
    _REGISTRY[name] = full
    _REDUCED[name] = reduced


def _ensure_loaded():
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    name = name.replace("_", "-")
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))
