"""Mamba2-370M: attention-free SSD stack, 48 layers, state 128, no FFN.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_headdim=64,
        pos_embed="none", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm", reduced=True,
        num_layers=4, d_model=64, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_expand=2, ssm_headdim=16,
        pos_embed="none", tie_embeddings=True, dtype="float32",
    )


register("mamba2-370m", full, reduced)
