"""Qwen3-8B: dense, GQA kv=8, qk-norm (per-head RMSNorm on q/k), SwiGLU.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=12288, vocab_size=151936, qk_norm=True, mlp="swiglu",
        rope_theta=1e6, remat="block",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense", reduced=True,
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, qk_norm=True, mlp="swiglu", dtype="float32",
    )


register("qwen3-8b", full, reduced)
