"""Llama-4 Maverick 400B-A17B: interleaved MoE (128 experts, top-1) + shared
expert, GQA kv=8, early-fusion multimodal (frontend stubbed — text path only).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048,
        num_experts=128, experts_per_token=1, moe_layer_period=2,
        moe_shared_expert=True, mlp="swiglu", rope_theta=5e5, remat="block",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe", reduced=True,
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        num_experts=8, experts_per_token=1, moe_layer_period=2,
        moe_shared_expert=True, mlp="swiglu", dtype="float32",
    )


register("llama4-maverick-400b-a17b", full, reduced)
