"""SeamlessM4T-medium: encoder-decoder transformer backbone (12+12),
LayerNorm/GELU/sinusoidal positions. The speech frontend is a STUB — encoder
consumes precomputed frame embeddings via input_specs().
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        num_layers=12, encoder_layers=12, d_model=1024, num_heads=16,
        num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=256206,
        norm="layernorm", mlp="gelu", pos_embed="sin", embeds_input=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio", reduced=True,
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        norm="layernorm", mlp="gelu", pos_embed="sin", embeds_input=True,
        dtype="float32",
    )


register("seamless-m4t-medium", full, reduced)
