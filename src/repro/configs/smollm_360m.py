"""SmolLM-360M: llama-architecture small model, GQA kv=5, tied embeddings.
15 heads / 5 kv heads are not 16-divisible; projections shard on the
flat H*hd axes (960 / 320).
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, head_dim=64,
        d_ff=2560, vocab_size=49152, mlp="swiglu", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense", reduced=True,
        num_layers=3, d_model=60, num_heads=3, num_kv_heads=1, head_dim=20,
        d_ff=96, vocab_size=512, mlp="swiglu", tie_embeddings=True,
        dtype="float32",
    )


register("smollm-360m", full, reduced)
