"""Granite-3.0 3B-A800M MoE: 40 experts top-8, tiny expert FFN (512), GQA kv=8.
Expert count (40) is not divisible by the 16-way model axis, so experts
replicate over "model" with FSDP over "data" (EXPERIMENTS.md §Perf cell A).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49155,
        num_experts=40, experts_per_token=8, mlp="swiglu", rope_theta=1e4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", reduced=True,
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=512,
        num_experts=10, experts_per_token=4, mlp="swiglu", dtype="float32",
    )


register("granite-moe-3b-a800m", full, reduced)
