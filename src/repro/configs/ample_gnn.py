"""The paper's own workload: GCN/GIN/GraphSAGE inference over Table-4 graphs.

Registered so ``--arch ample-gcn`` works in the launcher and the distributed
dry-run exercises the event-driven engine at Yelp scale (717k nodes) on the
production mesh. d_model carries the feature width, d_ff the hidden width and
vocab_size the class count (see launch/dryrun.py for the GNN input specs).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="ample-gcn", family="gnn",
        num_layers=2, d_model=300, num_heads=1, num_kv_heads=1,
        d_ff=256, vocab_size=100,  # yelp: 300 features, 100 classes
        dtype="float32",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="ample-gcn", family="gnn", reduced=True,
        num_layers=2, d_model=32, num_heads=1, num_kv_heads=1,
        d_ff=16, vocab_size=7, dtype="float32",
    )


register("ample-gcn", full, reduced)
