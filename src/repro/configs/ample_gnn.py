"""The paper's workload plus the attention extension: GCN/GIN/GraphSAGE/GAT
inference over Table-4 graphs.

One registered config per Table-3 model — plus ``ample-gat``, the runtime-
coefficient arch the event-driven pipeline unlocks beyond the paper — all
family="gnn" and dispatched through the unified model API (models/api.py ->
models/gnn/api.py). d_model carries the feature width, d_ff the hidden width
and vocab_size the class count (see launch/dryrun.py for the GNN input
specs); ``gnn_arch`` selects the registry entry, ``gnn_precision`` the
Degree-Quant policy, ``gnn_heads`` the GAT attention heads (hidden widths
must divide by it). The FULL configs are Yelp-scale (717k nodes, 300
features, 100 classes); the REDUCED ones smoke-test on CPU.
"""
import functools

from repro.configs.base import ModelConfig, register

# GAT concatenates head outputs on hidden layers, so d_ff % heads == 0.
_HEADS = {"gat": 4}
_HEADS_REDUCED = {"gat": 2}


def _full(arch: str) -> ModelConfig:
    return ModelConfig(
        name=f"ample-{arch}", family="gnn", gnn_arch=arch,
        num_layers=2, d_model=300, num_heads=1, num_kv_heads=1,
        d_ff=256, vocab_size=100,  # yelp: 300 features, 100 classes
        dtype="float32",
        gnn_heads=_HEADS.get(arch, 1),
        # Continuous batching at production scale: admit up to 8 graphs per
        # micro-batch and pad the union to coarse size classes so the plan
        # and jit caches stay warm under varying request mixes.
        gnn_batch_window=8,
        gnn_union_node_bucket=1024,
        gnn_union_edge_bucket=8192,
    )


def _reduced(arch: str) -> ModelConfig:
    return ModelConfig(
        name=f"ample-{arch}", family="gnn", gnn_arch=arch, reduced=True,
        num_layers=2, d_model=32, num_heads=1, num_kv_heads=1,
        d_ff=16, vocab_size=7, dtype="float32",
        gnn_heads=_HEADS_REDUCED.get(arch, 1),
        gnn_edges_per_tile=64,
        gnn_batch_window=4,
        # buckets stay 0 here: smoke tests opt into padded size classes
        # explicitly (GNNServeEngine union_node_bucket/union_edge_bucket)
    )


for _arch in ("gcn", "gin", "sage", "gat"):
    register(
        f"ample-{_arch}",
        functools.partial(_full, _arch),
        functools.partial(_reduced, _arch),
    )
