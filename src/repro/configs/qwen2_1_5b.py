"""Qwen2-1.5B: dense, GQA kv=2, QKV bias. 12 heads are not 16-divisible;
projections shard on the flat H*hd axis (1536). [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936, qkv_bias=True, mlp="swiglu",
        rope_theta=1e6, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense", reduced=True,
        num_layers=3, d_model=60, num_heads=3, num_kv_heads=1, head_dim=20,
        d_ff=128, vocab_size=512, qkv_bias=True, mlp="swiglu",
        tie_embeddings=True, dtype="float32",
    )


register("qwen2-1.5b", full, reduced)
