"""Nemotron-4 15B: dense, GQA kv=8, squared-ReLU MLP, LayerNorm.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
        d_ff=24576, vocab_size=256000, mlp="relu2", norm="layernorm",
        remat="block",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense", reduced=True,
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512, mlp="relu2", norm="layernorm", dtype="float32",
    )


register("nemotron-4-15b", full, reduced)
