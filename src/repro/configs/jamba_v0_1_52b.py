"""Jamba v0.1 52B: hybrid Mamba+attention (1 attn per 8 layers, offset 4), MoE
every 2nd layer (16 experts top-2), no positional embedding. The Mamba mixer
here is the SSD (Mamba2) form with Jamba's state size — DESIGN.md records this
substitution. [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536,
        num_experts=16, experts_per_token=2, moe_layer_period=2,
        ssm_state=16, ssm_expand=2, ssm_headdim=64,
        attn_layer_period=8, attn_layer_offset=4,
        pos_embed="none", mlp="swiglu", remat="block",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", reduced=True,
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        num_experts=4, experts_per_token=2, moe_layer_period=2,
        ssm_state=8, ssm_expand=2, ssm_headdim=16,
        attn_layer_period=8, attn_layer_offset=4,
        pos_embed="none", mlp="swiglu", dtype="float32",
    )


register("jamba-v0.1-52b", full, reduced)
