"""Qwen2-VL-7B backbone: M-RoPE (t/h/w rotary sections), GQA kv=4, QKV bias.
The vision frontend (dynamic-resolution ViT) is a STUB — patch embeddings and
3D positions arrive via input_specs(). 28 heads shard on the flat axis (3584).
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
        d_ff=18944, vocab_size=152064, qkv_bias=True, mlp="swiglu",
        pos_embed="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
        embeds_input=True, remat="block",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm", reduced=True,
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, qkv_bias=True, mlp="swiglu",
        pos_embed="mrope", mrope_sections=(4, 2, 2), embeds_input=True,
        dtype="float32",
    )


register("qwen2-vl-7b", full, reduced)
