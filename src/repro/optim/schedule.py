"""LR schedules: linear warmup + cosine decay (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    """lr(step): linear 0→peak over `warmup`, cosine peak→floor·peak by `total`."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
