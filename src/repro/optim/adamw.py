"""AdamW from scratch (no optax): pytree-native, f32 moments, global-norm clip.

Moments are kept in float32 regardless of parameter dtype (bf16 training needs
f32 statistics); the update is cast back to the parameter dtype. This is the
state the checkpointing layer persists and the dry-run train_step lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32[]
    m: Any  # f32 pytree like params
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    cfg: AdamWConfig,
    lr: Optional[jnp.ndarray] = None,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics). ``lr`` overrides cfg.lr
    (schedule value); weight decay is decoupled (AdamW)."""
    lr = cfg.lr if lr is None else lr
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm,
        "lr": jnp.asarray(lr, jnp.float32),
    }
