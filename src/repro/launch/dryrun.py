"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and record memory/cost/collective analysis.

MUST set the device-count flag before jax initializes — these two lines stay
first (``setdefault`` so an outer harness can test with fewer fake devices).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, get_config, list_configs
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    make_policy,
    param_shardings,
    replicated,
    state_shardings,
)
from repro.launch.hlo_analysis import analyze_collectives
from repro.launch.input_specs import (
    decode_token_specs,
    gnn_input_specs,
    prefill_input_specs,
    train_input_specs,
)
from repro.launch.mesh import make_production_mesh, mesh_tp
from repro.models.api import model_init, model_init_cache, model_prefill
from repro.train.train_step import init_train_state, make_serve_step, make_train_step

# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return (
            "long_500k requires sub-quadratic attention; this arch is pure "
            "full-attention (see DESIGN.md §Arch-applicability)"
        )
    return None


def _jsonable(d):
    out = {}
    for k, v in (d or {}).items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            out[k] = str(v)
    return out


def _mem_report(compiled):
    m = compiled.memory_analysis()
    if m is None:
        return {}
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ]
    rep = {}
    for k in keys:
        if hasattr(m, k):
            rep[k] = int(getattr(m, k))
    if rep:
        rep["peak_bytes_per_device"] = (
            rep.get("argument_size_in_bytes", 0)
            + rep.get("output_size_in_bytes", 0)
            + rep.get("temp_size_in_bytes", 0)
            - rep.get("alias_size_in_bytes", 0)
        )
    return rep


def _analyze(lowered, compiled, cfg: ModelConfig, shape_name: str, mesh) -> Dict:
    from repro.launch.analytic import analytic_report

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x returns [dict]; newer a dict
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = analyze_collectives(hlo, ring_size=mesh_tp(mesh))
    chips = int(len(mesh.devices.flat))
    flops_hlo = float(cost.get("flops", 0.0))
    bytes_hlo = float(cost.get("bytes accessed", 0.0))
    rec: Dict = {
        "chips": chips,
        # raw cost_analysis — NOTE: XLA counts while(scan) bodies ONCE, so
        # these under-report for scanned-layer programs; the analytic numbers
        # below follow the exact einsum structure and are loop-exact
        # (cross-checked against unrolled HLO for the hillclimb cells).
        "hlo_flops_per_device": flops_hlo,
        "hlo_bytes_per_device": bytes_hlo,
        "collective_bytes_by_kind": coll.bytes_by_kind,
        "collective_counts": coll.count_by_kind,
        "collective_wire_bytes": coll.wire_bytes,
        "memory": _mem_report(compiled),
        "cost_analysis": _jsonable(cost),
        "hlo_size_chars": len(hlo),
    }
    if shape_name in SHAPES:
        rec.update(analytic_report(cfg, SHAPES[shape_name], chips))
        flops_dev = max(rec["analytic_step_flops_per_device"], flops_hlo)
        bytes_dev = max(rec["analytic_hbm_bytes_per_device"], bytes_hlo)
    else:
        flops_dev, bytes_dev = flops_hlo, bytes_hlo
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll.wire_bytes / ICI_BW_PER_LINK,
    }
    rec["roofline_terms_s"] = terms
    rec["dominant_term"] = max(terms, key=terms.get)
    bound = max(terms.values())
    rec["roofline_fraction"] = terms["compute_s"] / bound if bound else 0.0
    return rec


# ------------------------------------------------------------------ lowering
def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    seq_shard: bool = False,
    capacity_factor: Optional[float] = None,
    remat: Optional[str] = None,
    parallel_mode: str = "auto",
    kv_cache_dtype: Optional[str] = None,
) -> Dict:
    """Lower+compile one cell; returns the result record (also JSON-dumped)."""
    t0 = time.time()
    cfg = get_config(arch)
    if capacity_factor is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    if remat is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, remat=remat)
    if kv_cache_dtype is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_cache_dtype)
    elif SHAPES.get(shape_name) and SHAPES[shape_name].kind == "train":
        # paper-faithful baseline policy: block remat for every train lower
        # (saving full per-layer activations at 4k×256 does not fit any chip)
        import dataclasses

        cfg = dataclasses.replace(cfg, remat="block")
    rec: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_name(multi_pod),
        "family": cfg.family,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if cfg.family == "gnn":
        return _lower_gnn(cfg, rec, multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape_name)
    if reason:
        rec["skipped"] = reason
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh_tp(mesh)
    if parallel_mode == "auto":
        # TP only pays for itself above ~20B params (measured: below that,
        # activation all-reduces dwarf compute); decode always keeps TP for
        # KV-cache sequence sharding.
        parallel_mode = (
            "fsdp"
            if cfg.param_count() < 20e9
            and not cfg.is_moe  # MoE group dispatch needs data-aligned tokens
            and shape.kind in ("train", "prefill")
            else "tp"
        )
    rec["parallel_mode"] = parallel_mode
    policy = make_policy(mesh, seq_shard=seq_shard, mode=parallel_mode)
    key = jax.random.PRNGKey(0)

    params_shape = jax.eval_shape(lambda: model_init(cfg, key, tp=tp))
    param_sh = param_shardings(cfg, params_shape, mesh, mode=parallel_mode)

    if shape.kind == "train":
        state_shape = jax.eval_shape(lambda p: init_train_state(cfg, p), params_shape)
        state_sh = state_shardings(cfg, state_shape, mesh, mode=parallel_mode)
        batch = train_input_specs(cfg, shape)
        batch_sh = batch_shardings(cfg, batch, mesh, mode=parallel_mode)
        step = make_train_step(cfg, policy=policy)
        out_shape = jax.eval_shape(step, state_shape, batch)
        out_sh = (state_sh, jax.tree.map(lambda _: replicated(mesh), out_shape[1]))
        jitted = jax.jit(
            step, in_shardings=(state_sh, batch_sh), out_shardings=out_sh,
            donate_argnums=0,
        )
        lowered = jitted.lower(state_shape, batch)
    elif shape.kind == "prefill":
        batch = prefill_input_specs(cfg, shape)
        batch_sh = batch_shardings(cfg, batch, mesh, mode=parallel_mode)

        def prefill_step(params, b):
            logits, cache, n = model_prefill(params, cfg, b, shape.seq_len, policy=policy)
            return logits, cache, n

        out_shape = jax.eval_shape(prefill_step, params_shape, batch)
        cache_sh = cache_shardings(cfg, out_shape[1], mesh, batch=shape.global_batch)
        logits_sh = jax.tree.map(lambda _: replicated(mesh), out_shape[0])
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import data_axes

        if parallel_mode == "fsdp":
            ba = policy._batch_axes(shape.global_batch)
            seq_ax = "model" if (ba is None or "model" not in (ba or ())) else None
            logits_sh = NamedSharding(mesh, P(ba, seq_ax, None))
        else:
            logits_sh = NamedSharding(mesh, P(data_axes(mesh), None, "model"))
        jitted = jax.jit(
            prefill_step,
            in_shardings=(param_sh, batch_sh),
            out_shardings=(logits_sh, cache_sh, replicated(mesh)),
        )
        lowered = jitted.lower(params_shape, batch)
    else:  # decode
        tok = decode_token_specs(cfg, shape)
        tok_sh = batch_shardings(cfg, tok, mesh)
        cache_batch = dict(tok)
        if cfg.family == "audio":
            cache_batch = {
                "src_embeds": jax.ShapeDtypeStruct(
                    (shape.global_batch, shape.seq_len, cfg.d_model), jnp.float32
                )
            }
        cache_shape = jax.eval_shape(
            lambda p, b: model_init_cache(cfg, p, b, max_len=shape.seq_len, tp=tp),
            params_shape,
            cache_batch,
        )
        cache_sh = cache_shardings(cfg, cache_shape, mesh, batch=shape.global_batch)
        step = make_serve_step(cfg, policy=policy)
        out_shape = jax.eval_shape(
            step, params_shape, tok, cache_shape, jnp.zeros((), jnp.int32)
        )
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import data_axes

        dp = data_axes(mesh)
        bdiv = shape.global_batch % (
            int(jnp.prod(jnp.asarray([mesh.shape[a] for a in dp])))
        ) == 0
        tok_out_sh = NamedSharding(mesh, P(dp if bdiv else None))
        logits_out_sh = NamedSharding(mesh, P(dp if bdiv else None, "model"))
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, tok_sh, cache_sh, replicated(mesh)),
            out_shardings=(tok_out_sh, logits_out_sh, cache_sh),
            donate_argnums=2,
        )
        lowered = jitted.lower(params_shape, tok, cache_shape, jnp.zeros((), jnp.int32))

    compiled = lowered.compile()
    rec.update(_analyze(lowered, compiled, cfg, shape_name, mesh))
    rec["compile_s"] = time.time() - t0
    return rec


def _lower_gnn(cfg: ModelConfig, rec: Dict, *, multi_pod: bool) -> Dict:
    """The paper's own workload at Yelp scale on the production mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.aggregation import DeviceTilePlan, aggregate_edge_tiles
    from repro.launch.mesh import data_axes

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = data_axes(mesh)
    specs, meta = gnn_input_specs(cfg)
    n, s = meta["num_nodes"], meta["segments_per_tile"]

    def gnn_step(x, gather_idx, coeff, seg_ids, out_node, edge_ids, w1, w2):
        dplan = DeviceTilePlan(gather_idx, coeff, seg_ids, out_node, edge_ids)
        m = aggregate_edge_tiles(x, dplan, num_nodes=n, segments_per_tile=s)
        h = jax.nn.relu(m @ w1)
        m2 = aggregate_edge_tiles(h, dplan, num_nodes=n, segments_per_tile=s)
        return m2 @ w2

    sh = {
        "x": NamedSharding(mesh, P(None, None)),
        "gather_idx": NamedSharding(mesh, P(dp, None)),
        "coeff": NamedSharding(mesh, P(dp, None)),
        "seg_ids": NamedSharding(mesh, P(dp, None)),
        "out_node": NamedSharding(mesh, P(dp, None)),
        "edge_ids": NamedSharding(mesh, P(dp, None)),
        "w1": NamedSharding(mesh, P(None, "model")),
        "w2": NamedSharding(mesh, P("model", None)),
    }
    ks = ["x", "gather_idx", "coeff", "seg_ids", "out_node", "edge_ids", "w1", "w2"]
    args = [specs[k] for k in ks]
    in_sh = tuple(sh[k] for k in ks)
    t0 = time.time()
    jitted = jax.jit(gnn_step, in_shardings=in_sh,
                     out_shardings=NamedSharding(mesh, P(None, None)))
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    rec.update(_analyze(lowered, compiled, cfg, "gnn_yelp", mesh))
    rec["shape"] = "gnn_yelp"
    rec["compile_s"] = time.time() - t0
    return rec


# ---------------------------------------------------------------------- CLI
def run_and_save(arch: str, shape: str, multi_pod: bool, out_dir: str,
                 skip_existing: bool = False, **kw) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}__{shape}__{_mesh_name(multi_pod)}.json")
    if skip_existing and os.path.exists(fn):
        with open(fn) as f:
            rec = json.load(f)
        if not rec.get("error"):
            print(f"[CACHED] {arch} × {shape} × {_mesh_name(multi_pod)}", flush=True)
            return rec
    try:
        rec = lower_cell(arch, shape, multi_pod=multi_pod, **kw)
    except Exception as e:  # a failed cell is a bug — record it loudly
        rec = {
            "arch": arch, "shape": shape, "mesh": _mesh_name(multi_pod),
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    status = "SKIP" if rec.get("skipped") else ("FAIL" if rec.get("error") else "OK")
    dom = rec.get("dominant_term", "-")
    print(f"[{status}] {arch} × {shape} × {_mesh_name(multi_pod)}  dominant={dom}  "
          f"t={rec.get('compile_s', 0):.0f}s", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(OUT_DIR))
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--parallel-mode", default="auto")
    ap.add_argument("--kv-cache-dtype", default=None)
    args = ap.parse_args()
    archs = [a for a in list_configs()] if args.arch == "all" else args.arch.split(",")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        fam = get_config(arch).family
        shapes = (
            ["gnn_yelp"] if fam == "gnn"
            else (list(SHAPES) if args.shape == "all" else args.shape.split(","))
        )
        for shape in shapes:
            for mp in meshes:
                run_and_save(
                    arch, shape, mp, args.out, skip_existing=args.skip_existing,
                    capacity_factor=args.capacity_factor, remat=args.remat,
                    parallel_mode=args.parallel_mode,
                    kv_cache_dtype=args.kv_cache_dtype,
                )


if __name__ == "__main__":
    main()
