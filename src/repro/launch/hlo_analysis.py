"""Post-SPMD HLO analysis: collective-byte accounting with loop multipliers.

``compiled.as_text()`` is the per-device program after the SPMD partitioner —
every cross-device transfer appears as an explicit collective op. Two
subtleties make naive grepping wrong, both handled here:

1. **Scan bodies**: layers are rolled into ``while`` loops, so a collective
   inside the loop body executes ``trip_count`` times. We build the
   computation call graph, extract trip counts from loop condition constants,
   and multiply.
2. **Byte semantics per collective**: for a ring implementation, per-device
   bytes on the wire are approximately
      all-gather      result_bytes · (n-1)/n
      reduce-scatter  operand_bytes · (n-1)/n   (= result·(n-1))
      all-reduce      2 · bytes · (n-1)/n       (RS + AG)
      all-to-all      result_bytes · (n-1)/n
      collective-permute  result_bytes
   We report both raw op-byte sums per kind and the ring-adjusted wire total.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["CollectiveStats", "analyze_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)(?: \([^)]*\))? -> .* \{", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    """Total bytes of the first shape (or tuple of shapes) in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    wire_bytes: float  # ring-adjusted per-device bytes on the wire

    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (brace matching from each header)."""
    comps: Dict[str, str] = {}
    for m in re.finditer(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^\n]*\))?\s*->\s*[^\n{]*\{",
                         hlo, re.M):
        name = m.group(2)
        start = m.end()
        depth = 1
        i = start
        while i < len(hlo) and depth:
            c = hlo[i]
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
            i += 1
        comps[name] = hlo[start : i - 1]
        if m.group(1):
            comps["__entry__"] = name
    return comps


def _trip_count(cond_body: str) -> int:
    """Largest integer constant in the loop condition ≈ trip count."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def analyze_collectives(hlo: str, *, ring_size: int) -> CollectiveStats:
    comps = _split_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:  # fall back: treat whole text as one computation
        comps = {"main": hlo, "__entry__": "main"}
        entry = "main"

    # multipliers via DFS from entry through while bodies / calls
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        body = comps[name]
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if wm:
                cond, wbody = wm.group(1), wm.group(2)
                t = _trip_count(comps.get(cond, ""))
                visit(wbody, m * t)
                visit(cond, m * (t + 1))
                continue
            for cm in _CALL_RE.finditer(line):
                callee = cm.group(1)
                if callee != name:
                    visit(callee, m)

    visit(entry, 1.0)

    n = max(ring_size, 2)
    ring = (n - 1) / n
    bytes_by: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    wire = 0.0
    for name, body in comps.items():
        if name == "__entry__" or name not in mult:
            continue
        m = mult[name]
        for line in body.splitlines():
            stripped = line.strip()
            for kind in _COLLECTIVES:
                # match the op kind right after '= shape kind(' to avoid
                # matching fusions whose name merely contains it
                if re.search(rf"=\s*[\w\[\],\s()]*\s{kind}(?:-start|-done)?\(", stripped) or \
                   re.search(rf"=\s*\S+\s+{kind}\(", stripped):
                    if f"{kind}-done" in stripped:
                        continue  # counted at -start
                    lhs = stripped.split("=", 1)[1] if "=" in stripped else stripped
                    b = _shape_bytes(lhs.split(kind)[0]) * m
                    bytes_by[kind] += b
                    count_by[kind] += int(m)
                    if kind == "all-reduce":
                        wire += 2 * b * ring
                    elif kind == "reduce-scatter":
                        wire += b * (n - 1)  # result bytes × (n-1)
                    elif kind == "collective-permute":
                        wire += b
                    else:
                        wire += b * ring
                    break
    return CollectiveStats(bytes_by_kind=bytes_by, count_by_kind=count_by,
                           wire_bytes=wire)
