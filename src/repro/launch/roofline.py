"""Roofline report: aggregate experiments/dryrun/*.json into §Roofline tables.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16] [--md]

Per (arch × shape): the three terms (compute/memory/collective seconds), the
dominant bottleneck, MODEL_FLOPS (6·N·D or 6·N_active·D), the useful-compute
ratio, peak per-device memory, and a one-line "what would move the dominant
term" note generated from the bottleneck structure.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

OUT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


def load_records(dirname: str, mesh: str) -> List[Dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirname, f"*__{mesh}.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def advice(rec: Dict) -> str:
    dom = rec.get("dominant_term", "")
    fam = rec.get("family", "")
    shape = rec.get("shape", "")
    if rec.get("skipped"):
        return "skipped"
    if dom == "collective_s":
        if "train" in shape:
            return (
                "shrink TP for this size (map model axis to DP/FSDP) or "
                "overlap AR with compute (collective matmul)"
            )
        if fam == "moe":
            return "a2a-based EP dispatch instead of partitioner-chosen reshards"
        return "reshard attention internals (context parallelism) / fewer TP hops"
    if dom == "memory_s":
        if "decode" in shape or "long" in shape:
            return "int8 KV cache (halves cache stream) or larger decode batch"
        return "bf16 logits + fused CE; remat less aggressively"
    return "compute-bound: increase per-chip batch or reduce remat recompute"


def fmt_row(rec: Dict) -> List[str]:
    if rec.get("skipped"):
        return [rec["arch"], rec["shape"], "—", "—", "—", "skip", "—", "—", "—",
                "skipped: sub-quadratic attention required"]
    t = rec["roofline_terms_s"]
    mem = rec.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
    mf = rec.get("model_flops_6nd", 0.0)
    useful = rec.get("useful_ratio_model_over_step", 0.0)
    return [
        rec["arch"], rec["shape"],
        f"{t['compute_s']:.3f}", f"{t['memory_s']:.3f}", f"{t['collective_s']:.3f}",
        rec["dominant_term"].replace("_s", ""),
        f"{rec.get('roofline_fraction', 0):.3f}",
        f"{mf:.2e}", f"{useful:.2f}",
        advice(rec),
    ]


HEADERS = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bottleneck", "roofline_frac", "model_flops", "useful", "to improve"]


def to_markdown(recs: List[Dict]) -> str:
    lines = ["| " + " | ".join(HEADERS) + " |",
             "|" + "|".join(["---"] * len(HEADERS)) + "|"]
    for r in recs:
        lines.append("| " + " | ".join(fmt_row(r)) + " |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.normpath(OUT_DIR))
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.dir, args.mesh)
    if args.md:
        print(to_markdown(recs))
        return
    for r in recs:
        row = fmt_row(r)
        print("  ".join(f"{c:<24s}" if i == 0 else f"{c:<12s}"
                        for i, c in enumerate(row[:7])))


if __name__ == "__main__":
    main()
