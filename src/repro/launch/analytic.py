"""Analytic FLOP/byte models per (arch × shape) — the roofline's numerator.

XLA's ``cost_analysis()`` counts a ``while`` body once, so scanned-layer
programs under-report FLOPs/bytes by ~the layer count. These closed-form
models follow the exact einsum structure of models/lm/* (verified against
unrolled HLO for the hillclimb cells, see EXPERIMENTS.md §Roofline), and give:

* ``step_flops``   — global FLOPs per step (train: fwd+bwd(+remat) multiplier);
* ``model_flops``  — the 6·N·D (dense) / 6·N_active·D (MoE) reference;
* ``step_hbm_bytes`` — per-DEVICE HBM traffic estimate (weight streams,
  activation rw, KV-cache rw), for the memory roofline term.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.lm.transformer import block_roles

__all__ = ["analytic_report"]


def _attn_flops(cfg, t_q: int, t_kv: int) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    proj = 2 * t_q * d * (h * hd) + 2 * t_q * d * (2 * kv * hd) + 2 * t_q * (h * hd) * d
    core = 2 * 2 * t_q * t_kv * h * hd  # scores + AV
    return proj + core


def _mlp_flops(cfg, t: int, f: int) -> float:
    mats = 3 if cfg.mlp == "swiglu" else 2
    return 2 * t * cfg.d_model * f * mats


def _moe_flops(cfg, t: int) -> float:
    # capacity-padded routed compute + router + optional shared expert
    routed = _mlp_flops(cfg, int(t * cfg.experts_per_token * cfg.capacity_factor), cfg.d_ff)
    router = 2 * t * cfg.d_model * cfg.num_experts
    shared = _mlp_flops(cfg, t, cfg.d_ff) if cfg.moe_shared_expert else 0
    return routed + router + shared


def _mamba_flops(cfg, t: int) -> float:
    d, di, n, h, p = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    q = cfg.ssm_chunk
    proj = 2 * t * d * (2 * di + 2 * n + h) + 2 * t * di * d
    conv = 2 * t * (di + 2 * n) * cfg.ssm_conv
    ssd = 2 * t * (q * n + q * h * p + 2 * h * p * n)  # cb, y_diag, states+y_off
    return proj + conv + ssd


def _mamba_decode_flops(cfg, b: int) -> float:
    d, di, n, h, p = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    proj = 2 * b * d * (2 * di + 2 * n + h) + 2 * b * di * d
    state = 2 * 2 * b * h * p * n
    return proj + state


def step_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global FLOPs of one lowered step (matching what the dry-run lowers)."""
    b, s = shape.global_batch, shape.seq_len
    roles = block_roles(cfg) if cfg.family != "audio" else [("attn", "dense")]
    units = cfg.num_layers // len(roles) if cfg.family != "audio" else cfg.num_layers

    def stack_flops(t_q, t_kv, causal_frac=1.0):
        total = 0.0
        for mixer, ffn in roles:
            if mixer == "attn":
                f = _attn_flops(cfg, t_q, int(t_kv * causal_frac))
            else:
                f = _mamba_flops(cfg, t_q)
            if ffn == "moe":
                f += _moe_flops(cfg, t_q)
            elif ffn == "dense":
                f += _mlp_flops(cfg, t_q, cfg.d_ff)
            total += f
        return total * units

    if shape.kind in ("train", "prefill"):
        t = b * s
        if cfg.family == "audio":
            t_src, t_tgt = b * s // 2, b * s // 2
            enc = cfg.encoder_layers * (
                _attn_flops(cfg, t_src, s // 2) + _mlp_flops(cfg, t_src, cfg.d_ff)
            )
            dec = cfg.num_layers * (
                _attn_flops(cfg, t_tgt, (s // 2) * 0.5)
                + _attn_flops(cfg, t_tgt, s // 2)  # cross
                + _mlp_flops(cfg, t_tgt, cfg.d_ff)
            )
            fwd = enc + dec + 2 * t_tgt * cfg.d_model * cfg.vocab_size
        else:
            fwd = stack_flops(t, s, causal_frac=0.5)
            fwd += 2 * t * cfg.d_model * cfg.vocab_size  # lm head
        if shape.kind == "train":
            mult = 4.0 if cfg.remat == "block" else 3.0  # bwd=2x, remat=+1x
            return fwd * mult
        return fwd
    # decode: one token per sequence, cache length s
    t = b
    if cfg.family == "audio":
        dec = cfg.num_layers * (
            _attn_flops(cfg, t, s) + _attn_flops(cfg, t, s) + _mlp_flops(cfg, t, cfg.d_ff)
        )
        return dec + 2 * t * cfg.d_model * cfg.vocab_size
    total = 0.0
    for mixer, ffn in block_roles(cfg):
        if mixer == "attn":
            total += _attn_flops(cfg, t, s)
        else:
            total += _mamba_decode_flops(cfg, b)
        if ffn == "moe":
            total += _moe_flops(cfg, t)
        elif ffn == "dense":
            total += _mlp_flops(cfg, t, cfg.d_ff)
    total *= cfg.num_layers // len(block_roles(cfg))
    return total + 2 * t * cfg.d_model * cfg.vocab_size


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token/seq."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, chips: int) -> float:
    """Per-device HBM traffic estimate for one step."""
    pbytes = cfg.param_count() * 2  # bf16 weights
    local_p = pbytes / chips  # FSDP+TP shards over the whole mesh
    b, s = shape.global_batch, shape.seq_len
    dp = max(1, chips // 16)
    if shape.kind == "train":
        t_loc = b * s / dp
        act = cfg.num_layers * t_loc * cfg.d_model * 2 * 8  # rw per sublayer
        # fwd+bwd+remat weight reads, grad write, f32 m/v rw, param update
        wt = local_p * 3 + local_p + (cfg.param_count() * 16 / chips) + local_p
        return wt + act
    if shape.kind == "prefill":
        t_loc = b * s / dp
        act = cfg.num_layers * t_loc * cfg.d_model * 2 * 6
        cache = _cache_bytes(cfg, b, s) / chips
        return local_p + act + cache
    cache = _cache_bytes(cfg, b, s) / chips
    return local_p + 2 * cache / max(s, 1) + cache  # read whole cache, write 1 tok


def _cache_bytes(cfg: ModelConfig, b: int, s: int) -> float:
    roles = block_roles(cfg) if cfg.family != "audio" else [("attn", "dense")]
    units = cfg.num_layers // len(roles)
    n_attn = sum(1 for m, _ in roles if m == "attn") * units
    n_ssm = sum(1 for m, _ in roles if m == "mamba") * units
    if cfg.family == "audio":
        n_attn = cfg.num_layers * 2  # self + cross
    kv_bytes = 1 if cfg.kv_cache_dtype == "int8" else 2
    kv = 2 * n_attn * b * s * cfg.num_kv_heads * (
        cfg.resolved_head_dim * kv_bytes + (4 if kv_bytes == 1 else 0)
    )
    ssm = n_ssm * b * (cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state) * 4 if n_ssm else 0
    return kv + ssm


def analytic_report(cfg: ModelConfig, shape: ShapeSpec, chips: int) -> Dict[str, float]:
    sf = step_flops(cfg, shape)
    mf = model_flops(cfg, shape)
    return {
        "analytic_step_flops_global": sf,
        "analytic_step_flops_per_device": sf / chips,
        "model_flops_6nd": mf,
        "useful_ratio_model_over_step": mf / sf if sf else 0.0,
        "analytic_hbm_bytes_per_device": step_hbm_bytes(cfg, shape, chips),
    }
