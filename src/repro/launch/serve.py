"""Serving launcher — one CLI over both serve engines, dispatched on family.

Token families: batched prefill+decode with the KV-cache engine.

    python -m repro.launch.serve --arch smollm-360m --tokens 32

family="gnn": the plan-cached GNN engine; serves the same graph twice to
show cold-plan vs cache-hit latency, then a batched small-graph mix.

    python -m repro.launch.serve --arch ample-gcn --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.api import model_init
from repro.serve.engine import ServeEngine


def serve_lm(cfg, args) -> None:
    params = model_init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.tokens)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} new_tokens={args.tokens}")
    print(f"throughput: {args.batch * args.tokens / dt:.1f} tok/s (CPU, reduced cfg)")
    print("sample:", out[0, : args.prompt_len + 8].tolist())


def serve_gnn(cfg, args) -> None:
    from repro.graphs import make_dataset
    from repro.serve.gnn_engine import GNNRequest, GNNServeEngine

    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0), num_shards=args.num_shards)
    g = make_dataset(
        args.dataset, max_nodes=args.nodes, max_feature_dim=cfg.d_model, seed=0
    )
    x = g.features
    print(
        f"arch={cfg.name} graph={g.name} nodes={g.num_nodes} edges={g.num_edges} "
        f"shards={args.num_shards}"
    )

    # Repeat traffic on one graph: the second request skips the planner
    # (per shard, when the engine is sharded).
    for i in range(max(args.requests, 2)):
        r = eng.infer(g, x)
        tag = "hit " if r.cache_hit else "cold"
        print(
            f"request {i}: plan[{tag}] {r.plan_ms:7.1f} ms  run {r.run_ms:6.1f} ms  "
            f"out {r.outputs.shape}  shards={r.num_shards}"
        )

    if eng.sharded:
        # Cluster-level lane economics: work balance + halo-exchange volume.
        rep = eng.shard_report()
        print(
            f"shard balance: edge_balance={rep['edge_balance']:.3f} "
            f"edges_per_shard={rep['edges_per_shard']}"
        )
        print(
            f"halo exchange: total={rep['halo_total']} rows/layer "
            f"per_shard={rep['halo_per_shard']}"
        )

    # A batch of independent small graphs in one padded device call.
    small = [
        make_dataset(args.dataset, max_nodes=args.nodes // 4, max_feature_dim=cfg.d_model, seed=s)
        for s in range(1, 4)
    ]
    reqs = [GNNRequest(graph=s, features=s.features) for s in small]
    t0 = time.time()
    outs = eng.infer_batch(reqs)
    dt = (time.time() - t0) * 1e3
    n = sum(s.num_nodes for s in small)
    print(f"batched {len(reqs)} graphs ({n} nodes) in one call: {dt:.1f} ms")
    print("cache:", eng.cache_info())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full", action="store_true")
    # token-family knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    # gnn-family knobs
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--nodes", type=int, default=800)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--num-shards", type=int, default=1,
                    help="partition the served graph into this many "
                         "edge-balanced shards (1 = single-plan path)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.family == "gnn":
        serve_gnn(cfg, args)
    else:
        serve_lm(cfg, args)


if __name__ == "__main__":
    main()
