"""Serving launcher: batched prefill+decode with the KV-cache engine.

``python -m repro.launch.serve --arch smollm-360m --tokens 32``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.api import model_init
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    params = model_init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.tokens)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} new_tokens={args.tokens}")
    print(f"throughput: {args.batch * args.tokens / dt:.1f} tok/s (CPU, reduced cfg)")
    print("sample:", out[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
