"""Serving launcher — one CLI over both serve engines, dispatched on family.

Token families: batched prefill+decode with the KV-cache engine.

    python -m repro.launch.serve --arch smollm-360m --tokens 32

family="gnn": the plan-cached GNN engine; serves the same graph twice to
show cold-plan vs cache-hit latency, then a batched small-graph mix.

    python -m repro.launch.serve --arch ample-gcn --requests 4

With ``--continuous-batching`` the small-graph stream flows through the
event-driven ``AsyncGNNEngine`` instead: requests are admitted into
micro-batch unions as they arrive, padded to size classes
(``--node-bucket`` / ``--edge-bucket``), with the admission window set by
``--window``.

    python -m repro.launch.serve --arch ample-gcn --continuous-batching

``--feature-budget-mb`` caps the device bytes granted to node features:
requests whose feature matrix exceeds the budget are served **out-of-core**
— features stay host-resident in a chunked feature store and stream through
the plan-driven prefetcher, with bitwise-identical outputs.

    python -m repro.launch.serve --arch ample-gcn --nodes 20000 --feature-budget-mb 1

``--tenants`` switches to the multi-tenant serving front (serve/tenancy):
each ``name[:weight[:priority[:rate_rps]]]`` entry registers a tenant, the
offered load is split across them, and admission is deficit-weighted round
robin with priority classes instead of global FIFO. ``--slo-ms`` sets the
latency SLO scored for the highest-priority tenants; the run ends with the
per-tenant telemetry table (p50/p99, queue wait, SLO hit rate, shares).

    python -m repro.launch.serve --arch ample-gcn --tenants gold:4:1,batch:1:0 --slo-ms 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.api import model_init
from repro.serve.engine import ServeEngine


def serve_lm(cfg, args) -> None:
    params = model_init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.tokens)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} new_tokens={args.tokens}")
    print(f"throughput: {args.batch * args.tokens / dt:.1f} tok/s (CPU, reduced cfg)")
    print("sample:", out[0, : args.prompt_len + 8].tolist())


def serve_gnn(cfg, args) -> None:
    from repro.graphs import make_dataset
    from repro.serve.gnn_engine import GNNRequest, GNNServeEngine

    budget = int(args.feature_budget_mb * (1 << 20)) if args.feature_budget_mb > 0 else 0
    eng = GNNServeEngine(
        cfg,
        key=jax.random.PRNGKey(0),
        num_shards=args.num_shards,
        partitioner=args.partitioner or None,
        halo_overlap=True if args.halo_overlap else None,
        feature_budget_bytes=budget or None,
        stream_packing=True if args.stream_packing else None,
        stream_reorder=False if args.no_stream_reorder else None,
    )
    g = make_dataset(
        args.dataset, max_nodes=args.nodes, max_feature_dim=cfg.d_model, seed=0
    )
    x = g.features
    print(
        f"arch={cfg.name} graph={g.name} nodes={g.num_nodes} edges={g.num_edges} "
        f"shards={args.num_shards}"
        + (
            f" feature_budget={budget / (1 << 20):.2f}MB "
            f"(features {x.nbytes / (1 << 20):.2f}MB)"
            if budget
            else ""
        )
    )

    # Repeat traffic on one graph: the second request skips the planner
    # (per shard, when the engine is sharded).
    for i in range(max(args.requests, 2)):
        r = eng.infer(g, x)
        tag = "hit " if r.cache_hit else "cold"
        stream = (
            f"  streamed {r.bytes_streamed >> 10}KB hit={r.chunk_hit_rate:.2f}"
            f" overlap={r.prefetch_overlap:.2f} stall={r.stall_ms:.1f}ms"
            if r.streamed
            else ""
        )
        halo = (
            f"  halo {r.halo_bytes >> 10}KB {r.halo_ms:.1f}ms"
            f" overlap={r.halo_overlap:.2f}"
            if r.halo_bytes
            else ""
        )
        print(
            f"request {i}: plan[{tag}] {r.plan_ms:7.1f} ms  run {r.run_ms:6.1f} ms  "
            f"out {r.outputs.shape}  shards={r.num_shards}{stream}{halo}"
        )

    if eng.sharded:
        # Cluster-level lane economics: work balance + halo-exchange volume.
        rep = eng.shard_report()
        print(
            f"shard balance: partitioner={rep['partitioner']} "
            f"edge_balance={rep['edge_balance']:.3f} "
            f"edges_per_shard={rep['edges_per_shard']}"
        )
        print(
            f"halo exchange: total={rep['halo_total']} rows/layer "
            f"per_shard={rep['halo_per_shard']}"
        )

    # A batch of independent small graphs in one padded device call.
    small = [
        make_dataset(args.dataset, max_nodes=args.nodes // 4, max_feature_dim=cfg.d_model, seed=s)
        for s in range(1, 4)
    ]
    reqs = [GNNRequest(graph=s, features=s.features) for s in small]
    t0 = time.time()
    outs = eng.infer_batch(reqs)
    dt = (time.time() - t0) * 1e3
    n = sum(s.num_nodes for s in small)
    print(f"batched {len(reqs)} graphs ({n} nodes) in one call: {dt:.1f} ms")

    if args.continuous_batching:
        serve_gnn_continuous(cfg, args)
    print("cache:", eng.cache_info())


def serve_gnn_continuous(cfg, args) -> None:
    """Event-driven continuous batching over a varying small-graph mix."""
    from repro.graphs import make_dataset
    from repro.serve.async_gnn import AsyncGNNEngine

    node_bucket = cfg.gnn_union_node_bucket if args.node_bucket < 0 else args.node_bucket
    edge_bucket = cfg.gnn_union_edge_bucket if args.edge_bucket < 0 else args.edge_bucket
    if args.num_shards > 1:
        # Padded size classes only apply to the single-device path: sharded
        # unions are planned exactly (see GNNServeEngine.padded_unions).
        node_bucket = edge_bucket = 0
    elif args.node_bucket < 0 and node_bucket == 0:
        # Reduced configs ship without buckets; size one to the demo workload
        # so the padded-class economics are visible (pass --node-bucket 0 for
        # exact shapes).
        node_bucket = max(args.nodes // 2, 64)
        edge_bucket = 4 * node_bucket if edge_bucket == 0 else edge_bucket
    async_eng = AsyncGNNEngine(
        cfg,
        window=args.window or None,
        window_timeout_ms=(
            args.window_timeout_ms if args.window_timeout_ms >= 0 else None
        ),
        num_shards=args.num_shards,
        union_node_bucket=node_bucket,
        union_edge_bucket=edge_bucket,
        key=jax.random.PRNGKey(0),
    )
    pool = [
        make_dataset(args.dataset, max_nodes=args.nodes // 4, max_feature_dim=cfg.d_model, seed=s)
        for s in range(1, 7)
    ]
    # Offered load: 4 varying mixes of the pool arrive back-to-back; the
    # admission loop recomposes micro-batches while member plans stay cached.
    t0 = time.time()
    tickets = []
    for wave in range(4):
        for g in pool[wave % 3 :: 2]:
            tickets.append(async_eng.submit(g, g.features))
        async_eng.step()  # slots recycle: completed members return now
    async_eng.drain()
    dt = time.time() - t0
    info = async_eng.cache_info()
    lookups = info["member_hits"] + info["member_misses"]
    mode = (
        f"node_bucket={node_bucket}, edge_bucket={edge_bucket}"
        if async_eng.engine.padded_unions
        else ("sharded exact unions" if async_eng.engine.sharded else "exact unions")
    )
    print(
        f"continuous batching: {info['completed']} requests in "
        f"{info['steps']} micro-batches, {info['completed'] / dt:.1f} req/s "
        f"(window={async_eng.window}, {mode})"
    )
    econ = f"planner_calls={info['planner_calls']}"
    if async_eng.window_timeout_ms > 0:
        econ += (
            f", held_windows={info['held_windows']}, "
            f"deadline_closes={info['deadline_closes']}"
        )
    if async_eng.engine.padded_unions:
        econ = (
            f"member-plan hit rate {info['member_hits'] / max(lookups, 1):.2f}, "
            f"size-class hits {info['class_hits']}"
            f"/{info['class_hits'] + info['class_misses']}, " + econ
        )
    print(f"plan economics: {econ}")


def _parse_tenants(spec: str):
    """Parse ``name[:weight[:priority[:rate_rps]]]`` entries, comma-separated."""
    tenants = []
    for entry in filter(None, (e.strip() for e in spec.split(","))):
        parts = entry.split(":")
        if len(parts) > 4:
            raise SystemExit(
                f"--tenants entry {entry!r}: want name[:weight[:priority[:rate_rps]]]"
            )
        name = parts[0]
        weight = float(parts[1]) if len(parts) > 1 else 1.0
        priority = int(parts[2]) if len(parts) > 2 else 0
        rate = float(parts[3]) if len(parts) > 3 else 0.0
        tenants.append((name, weight, priority, rate))
    if not tenants:
        raise SystemExit("--tenants: no tenant entries parsed")
    return tenants


def serve_gnn_tenants(cfg, args) -> None:
    """Multi-tenant serving front: DWRR admission + per-tenant telemetry."""
    from repro.graphs import make_dataset
    from repro.serve.tenancy import RateLimitExceeded, TenantRouter

    tenants = _parse_tenants(args.tenants)
    top_priority = max(p for _, _, p, _ in tenants)
    router = TenantRouter(
        cfg,
        window=args.window or None,
        hold_ms=max(args.window_timeout_ms, 0.0),
        key=jax.random.PRNGKey(0),
    )
    for name, weight, priority, rate in tenants:
        router.add_tenant(
            name, weight=weight, priority=priority, rate_rps=rate,
            # The SLO is scored for the top class(es): the tenants the
            # scheduler's priority + preemption knobs exist to protect.
            slo_ms=args.slo_ms if priority == top_priority else 0.0,
        )
    print(
        f"arch={cfg.name} tenants="
        + ", ".join(
            f"{n}(w={w:g},prio={p}" + (f",rate={r:g}rps" if r else "") + ")"
            for n, w, p, r in tenants
        )
        + f" window={router.window} slo_ms={args.slo_ms:g}"
    )

    pool = [
        make_dataset(
            args.dataset, max_nodes=args.nodes // 4,
            max_feature_dim=cfg.d_model, seed=s,
        )
        for s in range(1, 7)
    ]
    # Offered load: round-robin waves across tenants; lower-priority tenants
    # flood (the whole pool per wave), higher classes trickle one request.
    rejected = 0
    t0 = time.time()
    for wave in range(4):
        for name, _w, priority, _r in tenants:
            picks = [pool[wave % len(pool)]] if priority == top_priority else pool
            for g in picks:
                try:
                    router.submit(name, g, g.features)
                except RateLimitExceeded:
                    rejected += 1
        router.step()
    router.drain()
    dt = time.time() - t0
    stats = router.stats
    print(
        f"served {stats['completed']} requests in {stats['windows']} windows "
        f"({stats['completed'] / dt:.1f} req/s); rejected={rejected} "
        f"preempted={stats['preempted']}"
    )
    snap = router.snapshot()["tenants"]
    total_nodes = max(sum(s["completed_nodes"] for s in snap.values()), 1)
    for name in sorted(snap):
        s = snap[name]
        lat, qw = s["latency_ms"], s["queue_wait_ms"]
        slo = (
            f" slo_hit={s['slo_hit_rate']:.2f}"
            if s["slo_hits"] + s["slo_violations"]
            else ""
        )
        print(
            f"  {name:>10}: done={s['completed']:3d} "
            f"p50={lat['p50']:7.1f}ms p99={lat['p99']:7.1f}ms "
            f"queue_p99={qw['p99']:7.1f}ms "
            f"node_share={s['completed_nodes'] / total_nodes:.2f}"
            f"{slo} rejected={s['rejected']} preempted={s['preempted']}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full", action="store_true")
    # token-family knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    # gnn-family knobs
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--nodes", type=int, default=800)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--num-shards", type=int, default=1,
                    help="partition the served graph into this many "
                         "edge-balanced shards (1 = single-plan path)")
    ap.add_argument("--partitioner", default="",
                    help="sharded-path partitioner: 'edges' (contiguous "
                         "edge-balanced ranges) or 'mincut' (halo-minimizing "
                         "multilevel; params inline, e.g. 'mincut(seed=1)'). "
                         "Empty = cfg.gnn_partitioner")
    ap.add_argument("--halo-overlap", action="store_true",
                    help="sharded path: overlap each shard's halo exchange "
                         "with its interior-tile aggregation (outputs stay "
                         "bitwise-identical; responses report halo_overlap)")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="serve the small-graph stream through the "
                         "event-driven AsyncGNNEngine admission queue")
    ap.add_argument("--window", type=int, default=0,
                    help="continuous-batching admission window "
                         "(0 = cfg.gnn_batch_window)")
    ap.add_argument("--window-timeout-ms", type=float, default=-1,
                    help="latency-aware window close: hold a partially "
                         "filled admission window open until its oldest "
                         "request has waited this long (-1 = cfg."
                         "gnn_window_timeout_ms, 0 = admit immediately)")
    ap.add_argument("--node-bucket", type=int, default=-1,
                    help="pad union batches to this node size class "
                         "(-1 = cfg.gnn_union_node_bucket, 0 = exact shapes)")
    ap.add_argument("--edge-bucket", type=int, default=-1,
                    help="pad union tile stacks to this edge size class "
                         "(-1 = cfg.gnn_union_edge_bucket, 0 = exact shapes)")
    ap.add_argument("--tenants", default="",
                    help="multi-tenant serving front: comma-separated "
                         "name[:weight[:priority[:rate_rps]]] specs, e.g. "
                         "gold:4:1,batch:1:0 — admission becomes deficit-"
                         "weighted round robin across per-tenant queues "
                         "with priority classes (empty = single-tenant "
                         "FIFO paths)")
    ap.add_argument("--slo-ms", type=float, default=100.0,
                    help="latency SLO target scored for the highest-"
                         "priority tenants in --tenants mode (telemetry "
                         "reports the hit rate; nothing is enforced)")
    ap.add_argument("--feature-budget-mb", type=float, default=0,
                    help="out-of-core serving: device feature budget in MB; "
                         "requests whose feature matrix exceeds it stream "
                         "chunk-wise from the host feature store (0 = cfg "
                         "default / off). Outputs are bitwise-identical to "
                         "the in-memory path.")
    ap.add_argument("--stream-packing", action="store_true",
                    help="streamed path: rebuild tile membership around "
                         "source chunks (scheduler.pack_tiles_by_chunk) "
                         "instead of only reordering runs")
    ap.add_argument("--no-stream-reorder", action="store_true",
                    help="streamed path: keep plan tile order (the control "
                         "arm for the locality reorder pass)")
    ap.add_argument("--trace-out", default="",
                    help="record request-lifecycle spans and write a Chrome-"
                         "trace-event JSON here (load it in Perfetto or "
                         "chrome://tracing); empty = tracing disabled, the "
                         "zero-overhead default")
    ap.add_argument("--metrics-dump", default="",
                    help="after serving, dump the unified metrics registry "
                         "in Prometheus text exposition format to this path "
                         "('-' = stdout)")
    args = ap.parse_args()

    from repro.observe import metrics as ometrics, trace as otrace

    if args.trace_out:
        otrace.enable()
    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.family == "gnn" and args.tenants:
        serve_gnn_tenants(cfg, args)
    elif cfg.family == "gnn":
        serve_gnn(cfg, args)
    else:
        serve_lm(cfg, args)
    if args.trace_out:
        rec = otrace.get_recorder()
        rec.export(args.trace_out)
        print(
            f"trace: {len(rec.spans())} spans -> {args.trace_out} "
            f"(dropped={rec.dropped}); open in https://ui.perfetto.dev"
        )
    if args.metrics_dump:
        text = ometrics.get_registry().prometheus_text()
        if args.metrics_dump == "-":
            print(text, end="")
        else:
            with open(args.metrics_dump, "w") as f:
                f.write(text)
            print(f"metrics: registry dump -> {args.metrics_dump}")


if __name__ == "__main__":
    main()
