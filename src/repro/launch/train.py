"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this runs under one process per host with jax.distributed;
here it drives the same Trainer on CPU with reduced configs by default.
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true", help="full (not reduced) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress", choices=["none", "topk", "int8"], default="none")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    comp = None
    if args.compress == "topk":
        from repro.distributed.compression import TopKCompressor

        comp = TopKCompressor(ratio=0.01)
    elif args.compress == "int8":
        from repro.distributed.compression import Int8Compressor

        comp = Int8Compressor()
    tcfg = TrainerConfig(
        steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, opt=AdamWConfig(lr=args.lr), compressor=comp,
    )
    out = Trainer(cfg, tcfg).run()
    for rec in out["metrics"]:
        print(
            f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
            f"grad_norm {rec['grad_norm']:.3f}  lr {rec['lr']:.2e}  "
            f"wall {rec['wall_s']:.1f}s"
        )


if __name__ == "__main__":
    main()
