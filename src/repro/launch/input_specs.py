"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

No device allocation happens here: everything is a ShapeDtypeStruct, weak-type
correct and shardable, mirroring what launch/train.py / serve.py would feed at
runtime. ``[audio]``/``[vlm]`` archs receive precomputed frontend embeddings
(the modality frontend is a stub per the assignment).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec

__all__ = ["train_input_specs", "prefill_input_specs", "decode_token_specs", "gnn_input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "audio":  # enc-dec: frames in, tokens out (split budget)
        return {
            "src_embeds": _sds((b, s // 2, cfg.d_model), jnp.float32),
            "tgt_tokens": _sds((b, s // 2), jnp.int32),
            "labels": _sds((b, s // 2), jnp.int32),
        }
    if cfg.family == "vlm":  # patch+text embeddings from the stub frontend
        return {
            "embeds": _sds((b, s, cfg.d_model), jnp.float32),
            "positions": _sds((3, b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
    return {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    spec = train_input_specs(cfg, shape)
    spec.pop("labels", None)
    return spec


def decode_token_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    b = shape.global_batch
    if cfg.family == "vlm":
        return {"embeds": _sds((b, 1, cfg.d_model), jnp.float32)}
    return {"tokens": _sds((b, 1), jnp.int32)}


def gnn_input_specs(cfg: ModelConfig, *, dataset: str = "yelp",
                    edges_per_tile: int = 256) -> Tuple[Dict, Dict]:
    """(features+plan specs, static meta) for the paper's GNN at full scale.

    Tile counts are derived from the dataset's published edge statistics —
    the ExecutionPlan arrays are inputs (built host-side), so only their
    shapes matter for lowering.
    """
    from repro.graphs.datasets import PAPER_DATASETS

    ds = PAPER_DATASETS[dataset]
    n = ds.num_nodes
    e_total = int(ds.num_nodes * ds.mean_degree)
    t = max(1, int(np.ceil(e_total / edges_per_tile * 1.02)))  # 2% split slack
    t = ((t + 511) // 512) * 512  # divisible by any dp size; pad tiles are inert
    s = edges_per_tile
    specs = {
        "x": _sds((n, cfg.d_model), jnp.float32),
        "gather_idx": _sds((t, edges_per_tile), jnp.int32),
        "coeff": _sds((t, edges_per_tile), jnp.float32),
        "seg_ids": _sds((t, edges_per_tile), jnp.int32),
        "out_node": _sds((t, s), jnp.int32),
        "edge_ids": _sds((t, edges_per_tile), jnp.int32),
        "w1": _sds((cfg.d_model, cfg.d_ff), jnp.float32),
        "w2": _sds((cfg.d_ff, cfg.vocab_size), jnp.float32),
    }
    meta = {"num_nodes": n, "segments_per_tile": s, "num_tiles": t}
    return specs, meta
