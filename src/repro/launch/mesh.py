"""Production mesh construction (per-spec: function, no module-level state).

Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2, data=16,
model=16) = 512 chips, with "pod" as the slowest (DCN-connected) axis — data
parallelism spans pods, tensor/expert parallelism stays inside the fast ICI
domain, the standard hierarchy for 1000+-node deployments.
"""
from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["make_production_mesh", "data_axes", "model_axis", "mesh_tp"]


def make_production_mesh(*, multi_pod: bool = False):
    import os

    debug = os.environ.get("REPRO_DEBUG_MESH")  # e.g. "2x4" or "2x2x4" (tests)
    if debug:
        shape = tuple(int(x) for x in debug.split("x"))
        axes = ("pod", "data", "model")[-len(shape):]
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch shards over (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def mesh_tp(mesh) -> int:
    """Tensor-parallel degree (size of the model axis)."""
    return mesh.shape["model"]
