"""Graph partitioning for distributed (multi-chip) GNN execution.

Nodes are partitioned into contiguous CSR ranges balanced by *edge count*
(aggregation work ∝ edges, the paper's central observation), one range per
data-parallel shard. Each shard owns its nodes' output rows; neighbour
embeddings crossing the cut are exchanged with an all-gather of boundary
("halo") nodes before aggregation — the distributed analogue of the Feature
Bank fetching remote neighbours.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.graphs.csr import Graph

__all__ = [
    "Partition",
    "ShardSubgraph",
    "partition_by_edges",
    "halo_nodes",
    "shard_subgraph",
    "shard_edge_counts",
    "validate_partition",
]


@dataclasses.dataclass(frozen=True)
class Partition:
    """Half-open node ranges [starts[k], starts[k+1]) per shard."""

    starts: np.ndarray  # int64[num_shards + 1]

    @property
    def num_shards(self) -> int:
        return int(self.starts.shape[0]) - 1

    def shard_of(self, node: int) -> int:
        return int(np.searchsorted(self.starts, node, side="right")) - 1

    def nodes(self, k: int) -> Tuple[int, int]:
        return int(self.starts[k]), int(self.starts[k + 1])


def partition_by_edges(g: Graph, num_shards: int) -> Partition:
    """Contiguous ranges with near-equal edge counts (work balance).

    Work balance — not node balance — is what keeps data-parallel shards from
    straggling on skewed graphs; this is the cluster-level restatement of the
    paper's event-driven argument.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    cum = g.indptr  # cumulative edges by node boundary
    total = g.num_edges
    targets = (np.arange(1, num_shards) * total) / num_shards
    cuts = np.searchsorted(cum, targets, side="left")
    starts = np.concatenate([[0], cuts, [g.num_nodes]]).astype(np.int64)
    starts = np.maximum.accumulate(starts)  # keep monotone on degenerate graphs
    return Partition(starts=starts)


def halo_nodes(g: Graph, part: Partition, k: int) -> np.ndarray:
    """Remote neighbour ids shard k must fetch before aggregating its range."""
    lo, hi = part.nodes(k)
    nbrs = g.indices[g.indptr[lo] : g.indptr[hi]]
    remote = nbrs[(nbrs < lo) | (nbrs >= hi)]
    return np.unique(remote)


def validate_partition(g: Graph, part: Partition) -> None:
    """Raise if ``part`` is not a disjoint contiguous cover of ``g``'s nodes."""
    starts = np.asarray(part.starts, np.int64)
    if starts.ndim != 1 or starts.shape[0] < 2:
        raise ValueError("partition needs at least one shard (starts[K+1])")
    if starts[0] != 0 or starts[-1] != g.num_nodes:
        raise ValueError(
            f"partition must span [0, {g.num_nodes}), got [{starts[0]}, {starts[-1]})"
        )
    if np.any(np.diff(starts) < 0):
        raise ValueError("partition starts must be monotone non-decreasing")


def shard_edge_counts(g: Graph, part: Partition) -> np.ndarray:
    """Edges owned by each shard, int64[num_shards] — the work-balance metric."""
    starts = np.asarray(part.starts, np.int64)
    return np.diff(g.indptr[starts])


@dataclasses.dataclass(frozen=True)
class ShardSubgraph:
    """One shard's slice of the global graph, re-indexed into local space.

    The local node space is ``[owned rows | halo rows]``: nodes ``[0,
    num_owned)`` are the shard's own range ``[lo, hi)`` shifted to zero, and
    nodes ``[num_owned, num_owned + halo.size)`` are the remote neighbours in
    ``halo`` order. Halo nodes have empty in-neighbour rows (they are gather
    *sources* only), so aggregation over ``graph`` writes real values exactly
    into the owned rows — the property the sharded executor relies on when it
    keeps ``out[:num_owned]``.

    ``edge_range`` is the shard's half-open slice of the global CSR edge
    arrays; because shards are contiguous node ranges, per-edge data computed
    globally (aggregation coefficients) slices directly onto local edges.
    """

    index: int
    lo: int
    hi: int
    halo: np.ndarray  # int64[H] global ids, sorted unique
    local_ids: np.ndarray  # int64[num_owned + H] global id of each local row
    graph: Graph  # local-index subgraph (owned + halo nodes)
    edge_range: Tuple[int, int]  # [e_lo, e_hi) into the global edge arrays

    @property
    def num_owned(self) -> int:
        return self.hi - self.lo

    @property
    def num_local(self) -> int:
        return int(self.local_ids.shape[0])


def shard_subgraph(g: Graph, part: Partition, k: int) -> ShardSubgraph:
    """Extract shard k's local subgraph (owned rows + halo sources).

    Edge order is preserved from the global CSR, so the local plan a scheduler
    builds over this subgraph aggregates exactly the same per-edge terms as the
    global plan restricted to the shard's nodes.
    """
    lo, hi = part.nodes(k)
    halo = halo_nodes(g, part, k)
    e_lo, e_hi = int(g.indptr[lo]), int(g.indptr[hi])
    src = g.indices[e_lo:e_hi].astype(np.int64)
    owned = hi - lo
    local = np.where(
        (src >= lo) & (src < hi), src - lo, owned + np.searchsorted(halo, src)
    )
    indptr_local = np.concatenate(
        [g.indptr[lo : hi + 1] - e_lo, np.full(halo.size, e_hi - e_lo, np.int64)]
    )
    local_g = Graph(
        indptr=indptr_local.astype(np.int64),
        indices=local.astype(np.int32),
        num_nodes=owned + int(halo.size),
        name=f"{g.name}/shard{k}",
    )
    local_ids = np.concatenate([np.arange(lo, hi, dtype=np.int64), halo])
    return ShardSubgraph(
        index=k,
        lo=lo,
        hi=hi,
        halo=halo,
        local_ids=local_ids,
        graph=local_g,
        edge_range=(e_lo, e_hi),
    )
