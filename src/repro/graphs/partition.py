"""Graph partitioning for distributed (multi-chip) GNN execution.

Nodes are partitioned into per-shard blocks balanced by *edge count*
(aggregation work ∝ edges, the paper's central observation), one block per
data-parallel shard. Each shard owns its nodes' output rows; neighbour
embeddings crossing the cut are exchanged with an all-gather of boundary
("halo") nodes before aggregation — the distributed analogue of the Feature
Bank fetching remote neighbours.

Two partitioners:

* ``partition_by_edges`` — contiguous CSR ranges with near-equal edge counts.
  Zero bookkeeping (per-edge data slices directly onto shards), but blind to
  locality: on a graph whose communities are interleaved in node order it
  cuts nearly every edge.
* ``partition_min_cut`` — METIS-style multilevel refinement: greedy heavy-edge
  coarsening, an initial cut seeded from ``partition_by_edges``, then
  boundary-vertex refinement that moves nodes across the cut whenever it
  reduces cut edges without violating the edge-balance bound. Produces a
  *non-contiguous* assignment carried by ``Partition.order``.

The halo-exchange volume (``partition_halo_volume``) is the distributed
analogue of off-chip traffic; the min-cut partitioner exists purely to shrink
it while ``shard_edge_counts`` stays balanced.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import List, Optional, Tuple

import numpy as np

from repro.graphs.csr import Graph

__all__ = [
    "Partition",
    "ShardSubgraph",
    "partition_by_edges",
    "partition_min_cut",
    "make_partition",
    "halo_nodes",
    "shard_subgraph",
    "shard_edge_counts",
    "partition_cut_edges",
    "partition_halo_volume",
    "validate_partition",
]


@dataclasses.dataclass(frozen=True)
class Partition:
    """Shard assignment of graph nodes, contiguous or permuted.

    ``starts`` are half-open block boundaries into the (implicit or explicit)
    node order: shard ``k`` owns positions ``[starts[k], starts[k+1])``.

    * ``order is None`` — the historical contiguous layout: shard ``k`` owns
      global node ids ``[starts[k], starts[k+1])`` directly, and per-edge data
      slices onto shards as contiguous CSR ranges.
    * ``order`` int64[N] — a node permutation; shard ``k`` owns global ids
      ``order[starts[k]:starts[k+1]]``. Invariant: each block is sorted
      ascending (canonical form — constructors enforce it), so local row
      ``i`` of a shard is its ``i``-th smallest owned node.

    ``kind`` names the partitioner (and its parameters) that produced this
    assignment; it is folded into ``partition_fingerprint`` so plan caches
    never collide across partitioners that happen to emit the same shapes.
    """

    starts: np.ndarray  # int64[num_shards + 1] block boundaries (positions)
    order: Optional[np.ndarray] = None  # int64[N] permutation; None = identity
    kind: str = "custom"

    @property
    def num_shards(self) -> int:
        return int(self.starts.shape[0]) - 1

    @property
    def contiguous(self) -> bool:
        return self.order is None

    @property
    def num_nodes(self) -> int:
        return int(self.starts[-1])

    def nodes(self, k: int) -> Tuple[int, int]:
        """Half-open *position* range of shard k (global ids iff contiguous)."""
        return int(self.starts[k]), int(self.starts[k + 1])

    def owned(self, k: int) -> np.ndarray:
        """Global node ids owned by shard k, sorted ascending."""
        lo, hi = self.nodes(k)
        if self.order is None:
            return np.arange(lo, hi, dtype=np.int64)
        return np.asarray(self.order[lo:hi], np.int64)

    @cached_property
    def _position(self) -> np.ndarray:
        """int64[N]: position of each global node in the concatenated order."""
        pos = np.empty(self.num_nodes, np.int64)
        pos[np.asarray(self.order, np.int64)] = np.arange(
            self.num_nodes, dtype=np.int64
        )
        return pos

    def owner_of(self, nodes: np.ndarray) -> np.ndarray:
        """Owning shard of each global node id, int32[...]."""
        nodes = np.asarray(nodes, np.int64)
        if self.order is None:
            return (
                np.searchsorted(self.starts, nodes, side="right") - 1
            ).astype(np.int32)
        return (
            np.searchsorted(self.starts, self._position[nodes], side="right") - 1
        ).astype(np.int32)

    def rank_of(self, nodes: np.ndarray) -> np.ndarray:
        """Local row index of each node within its owner's block, int64[...]."""
        nodes = np.asarray(nodes, np.int64)
        if self.order is None:
            owner = np.searchsorted(self.starts, nodes, side="right") - 1
            return nodes - self.starts[owner]
        pos = self._position[nodes]
        owner = np.searchsorted(self.starts, pos, side="right") - 1
        return pos - self.starts[owner]

    def shard_of(self, node: int) -> int:
        return int(self.owner_of(np.asarray([node]))[0])


def partition_by_edges(g: Graph, num_shards: int) -> Partition:
    """Contiguous ranges with near-equal edge counts (work balance).

    Work balance — not node balance — is what keeps data-parallel shards from
    straggling on skewed graphs; this is the cluster-level restatement of the
    paper's event-driven argument.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    cum = g.indptr  # cumulative edges by node boundary
    total = g.num_edges
    targets = (np.arange(1, num_shards) * total) / num_shards
    cuts = np.searchsorted(cum, targets, side="left")
    starts = np.concatenate([[0], cuts, [g.num_nodes]]).astype(np.int64)
    starts = np.maximum.accumulate(starts)  # keep monotone on degenerate graphs
    return Partition(starts=starts, kind="edges")


# ---------------------------------------------------------------------------
# Min-cut multilevel partitioner (METIS-style coarsen → seed → refine)
# ---------------------------------------------------------------------------


def _symmetric_edges(g: Graph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Undirected weighted edge list (a, b, w) with both directions present,
    duplicates coalesced and self-loops dropped."""
    dst = np.repeat(
        np.arange(g.num_nodes, dtype=np.int64), np.diff(g.indptr)
    )
    src = np.asarray(g.indices, np.int64)
    a = np.concatenate([dst, src])
    b = np.concatenate([src, dst])
    keep = a != b
    a, b = a[keep], b[keep]
    if a.size == 0:
        return a, b, np.zeros(0, np.int64)
    key = a * g.num_nodes + b
    key, inv = np.unique(key, return_inverse=True)
    w = np.bincount(inv, minlength=key.size).astype(np.int64)
    return key // g.num_nodes, key % g.num_nodes, w


def _heavy_edge_matching(
    n: int,
    a: np.ndarray,
    b: np.ndarray,
    w: np.ndarray,
    rng: np.random.Generator,
) -> np.ndarray:
    """Greedy heavy-edge matching → coarse cluster id per vertex, int64[n]."""
    match = np.full(n, -1, np.int64)
    # adjacency in CSR-ish form over the symmetric edge list
    order_e = np.argsort(a, kind="stable")
    a_s, b_s, w_s = a[order_e], b[order_e], w[order_e]
    ptr = np.searchsorted(a_s, np.arange(n + 1))
    for u in rng.permutation(n):
        if match[u] >= 0:
            continue
        nbrs = b_s[ptr[u] : ptr[u + 1]]
        wts = w_s[ptr[u] : ptr[u + 1]]
        free = match[nbrs] < 0
        nbrs, wts = nbrs[free & (nbrs != u)], wts[free & (nbrs != u)]
        if nbrs.size == 0:
            match[u] = u
            continue
        # heaviest edge wins; ties break on the smallest neighbour id
        best = nbrs[np.lexsort((nbrs, -wts))][0]
        match[u] = best
        match[best] = u
    # pair (u, match[u]) -> one coarse id (the min of the pair)
    rep = np.minimum(np.arange(n, dtype=np.int64), match)
    _, coarse = np.unique(rep, return_inverse=True)
    return coarse.astype(np.int64)


def _coarsen_edges(
    coarse: np.ndarray,
    n_coarse: int,
    a: np.ndarray,
    b: np.ndarray,
    w: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    ca, cb = coarse[a], coarse[b]
    keep = ca != cb
    ca, cb, w = ca[keep], cb[keep], w[keep]
    if ca.size == 0:
        return ca, cb, w
    key = ca * n_coarse + cb
    key_u, inv = np.unique(key, return_inverse=True)
    w_u = np.bincount(inv, weights=w.astype(np.float64), minlength=key_u.size)
    return key_u // n_coarse, key_u % n_coarse, w_u.astype(np.int64)


def _refine(
    assign: np.ndarray,
    vw: np.ndarray,
    a: np.ndarray,
    b: np.ndarray,
    w: np.ndarray,
    num_shards: int,
    cap: float,
    passes: int,
) -> np.ndarray:
    """Greedy boundary refinement: move vertices across the cut when it
    reduces cut weight and keeps every shard's vertex-weight load ≤ cap.

    One pass computes the full connectivity matrix conn[u, s] = Σ w of u's
    edges into shard s, ranks boundary vertices by best gain, and applies
    moves sequentially (loads updated live, connectivity stale within the
    pass — recomputed next pass). Deterministic: stable sorts, id tiebreaks.
    """
    n = assign.shape[0]
    load = np.bincount(assign, weights=vw.astype(np.float64), minlength=num_shards)
    for _ in range(passes):
        conn = np.bincount(
            a * num_shards + assign[b],
            weights=w.astype(np.float64),
            minlength=n * num_shards,
        ).reshape(n, num_shards)
        internal = conn[np.arange(n), assign]
        ext_best = conn.copy()
        ext_best[np.arange(n), assign] = -np.inf
        target = np.argmax(ext_best, axis=1)
        gain = ext_best[np.arange(n), target] - internal
        cand = np.nonzero(gain > 0)[0]
        if cand.size == 0:
            # cut is locally optimal; only balance repair could remain
            moved = _repair_balance(
                assign, vw, conn, load, num_shards, cap
            )
            if not moved:
                break
            continue
        cand = cand[np.lexsort((cand, -gain[cand]))]
        moved = 0
        for u in cand:
            s, t = int(assign[u]), int(target[u])
            if s == t:
                continue
            if load[t] + vw[u] > cap and load[t] + vw[u] >= load[s]:
                continue  # would overload the target beyond the source
            assign[u] = t
            load[s] -= vw[u]
            load[t] += vw[u]
            moved += 1
        moved += _repair_balance(assign, vw, conn, load, num_shards, cap)
        if moved == 0:
            break
    return assign


def _repair_balance(
    assign: np.ndarray,
    vw: np.ndarray,
    conn: np.ndarray,
    load: np.ndarray,
    num_shards: int,
    cap: float,
) -> int:
    """Move lowest-loss vertices out of overloaded shards. Returns #moves."""
    moved = 0
    for s in range(num_shards):
        guard = 0
        while load[s] > cap and guard < assign.shape[0]:
            members = np.nonzero(assign == s)[0]
            if members.size <= 1:
                break
            t = int(np.argmin(load))
            if t == s:
                break
            # prefer the member whose move loses the least cut weight
            loss = conn[members, s] - conn[members, t]
            u = int(members[np.lexsort((members, loss))][0])
            assign[u] = t
            load[s] -= vw[u]
            load[t] += vw[u]
            moved += 1
            guard += 1
    return moved


def partition_min_cut(
    g: Graph,
    num_shards: int,
    *,
    seed: int = 0,
    balance: float = 1.25,
    refine_passes: int = 8,
    coarsen_to: int = 0,
) -> Partition:
    """Halo-minimizing multilevel partition (coarsen → seed → uncoarsen+refine).

    Greedy heavy-edge matching coarsens the symmetrized graph until it has
    roughly ``max(coarsen_to, 32 * num_shards)`` vertices; the coarsest graph
    is seeded from ``partition_by_edges`` (projected through the coarsening
    maps), then each uncoarsening level runs ``refine_passes`` of boundary
    refinement under the edge-balance bound ``max shard edges ≤ balance ×
    ideal``. Deterministic in ``seed``. Falls back to ``partition_by_edges``
    for a single shard or an edgeless graph.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    n = g.num_nodes
    vw = np.diff(g.indptr).astype(np.int64)  # work = owned in-edges
    if num_shards == 1 or g.num_edges == 0 or n <= num_shards:
        base = partition_by_edges(g, num_shards)
        return Partition(
            starts=base.starts,
            order=None,
            kind=_min_cut_kind(seed, balance, refine_passes),
        )
    a, b, w = _symmetric_edges(g)
    rng = np.random.default_rng(seed)
    stop_at = max(coarsen_to or 0, 32 * num_shards)

    levels: List[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []
    maps: List[np.ndarray] = []
    cur_vw, cur_a, cur_b, cur_w, cur_n = vw, a, b, w, n
    while cur_n > stop_at:
        coarse = _heavy_edge_matching(cur_n, cur_a, cur_b, cur_w, rng)
        n_coarse = int(coarse.max()) + 1 if coarse.size else 0
        if n_coarse >= cur_n or n_coarse == 0:
            break  # matching stalled (e.g. star graphs)
        levels.append((cur_vw, cur_a, cur_b, cur_w))
        maps.append(coarse)
        cur_vw = np.bincount(
            coarse, weights=cur_vw.astype(np.float64), minlength=n_coarse
        ).astype(np.int64)
        cur_a, cur_b, cur_w = _coarsen_edges(coarse, n_coarse, cur_a, cur_b, cur_w)
        cur_n = n_coarse

    # Seed: project the contiguous edge-balance cut onto the coarsest level
    # by weighted majority vote of each coarse vertex's fine members.
    seed_part = partition_by_edges(g, num_shards)
    fine_assign = (
        np.searchsorted(seed_part.starts, np.arange(n), side="right") - 1
    ).astype(np.int64)
    coarse_of_fine = np.arange(n, dtype=np.int64)
    for m in maps:
        coarse_of_fine = m[coarse_of_fine]
    votes = np.bincount(
        coarse_of_fine * num_shards + fine_assign,
        weights=vw.astype(np.float64),
        minlength=cur_n * num_shards,
    ).reshape(cur_n, num_shards)
    assign = np.argmax(votes, axis=1).astype(np.int64)

    cap = balance * vw.sum() / num_shards
    assign = _refine(
        assign, cur_vw, cur_a, cur_b, cur_w, num_shards, cap, refine_passes
    )
    for (lvl_vw, lvl_a, lvl_b, lvl_w), m in zip(
        reversed(levels), reversed(maps)
    ):
        assign = assign[m]  # project to the finer level
        assign = _refine(
            assign, lvl_vw, lvl_a, lvl_b, lvl_w, num_shards, cap, refine_passes
        )

    counts = np.bincount(assign, minlength=num_shards)
    starts = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    order = np.argsort(assign, kind="stable").astype(np.int64)
    part = Partition(
        starts=starts,
        order=order,
        kind=_min_cut_kind(seed, balance, refine_passes),
    )
    if np.array_equal(order, np.arange(n)):
        # canonical contiguous form (keeps the fast paths on trivial graphs)
        part = Partition(starts=starts, order=None, kind=part.kind)
    return part


def _min_cut_kind(seed: int, balance: float, passes: int) -> str:
    return f"mincut(seed={int(seed)},balance={balance:g},passes={int(passes)})"


_MIN_CUT_NAMES = ("mincut", "min-cut", "min_cut", "metis")


def make_partition(
    g: Graph, num_shards: int, kind: str = "edges", **params
) -> Partition:
    """Partitioner dispatch: ``kind`` ∈ {"edges", "mincut"} (+ aliases).

    This is the one place the serving layer maps ``cfg.gnn_partitioner`` to an
    algorithm; params (seed/balance/refine_passes) pass through to
    ``partition_min_cut``. Params may also ride inline in the kind string —
    ``"mincut(seed=1,balance=1.1)"`` — which is how config-file and CLI
    strings (and ``Partition.kind`` fingerprint components) spell them.
    """
    name = (kind or "edges").strip().lower()
    if "(" in name and name.endswith(")"):
        name, _, arg_str = name.partition("(")
        name = name.strip()
        for item in filter(None, (s.strip() for s in arg_str[:-1].split(","))):
            pkey, _, pval = item.partition("=")
            pkey = {"passes": "refine_passes"}.get(pkey.strip(), pkey.strip())
            num = float(pval)
            params.setdefault(pkey, int(num) if num == int(num) and pkey != "balance" else num)
    if name in ("", "edges", "edge", "contiguous"):
        return partition_by_edges(g, num_shards)
    if name in _MIN_CUT_NAMES:
        return partition_min_cut(g, num_shards, **params)
    raise ValueError(
        f"unknown partitioner kind {kind!r}; expected 'edges' or 'mincut'"
    )


# ---------------------------------------------------------------------------
# Halo extraction and shard subgraphs
# ---------------------------------------------------------------------------


def _owned_edge_idx(g: Graph, owned: np.ndarray) -> np.ndarray:
    """Global CSR edge positions of all in-edges of ``owned`` rows, in local
    CSR order (row-major over owned nodes), int64[e_k]."""
    deg = (g.indptr[owned + 1] - g.indptr[owned]).astype(np.int64)
    total = int(deg.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    row_start = np.repeat(g.indptr[owned].astype(np.int64), deg)
    local_ptr = np.concatenate([[0], np.cumsum(deg)])[:-1]
    offset = np.arange(total, dtype=np.int64) - np.repeat(local_ptr, deg)
    return row_start + offset


def halo_nodes(g: Graph, part: Partition, k: int) -> np.ndarray:
    """Remote neighbour ids shard k must fetch before aggregating its nodes."""
    if part.contiguous:
        lo, hi = part.nodes(k)
        nbrs = g.indices[g.indptr[lo] : g.indptr[hi]]
        remote = nbrs[(nbrs < lo) | (nbrs >= hi)]
        return np.unique(remote)
    owned = part.owned(k)
    nbrs = g.indices[_owned_edge_idx(g, owned)].astype(np.int64)
    owned_mask = np.zeros(g.num_nodes, bool)
    owned_mask[owned] = True
    return np.unique(nbrs[~owned_mask[nbrs]])


def validate_partition(g: Graph, part: Partition) -> None:
    """Raise if ``part`` is not a disjoint cover of ``g``'s nodes (canonical
    form: contiguous ranges, or a permutation with sorted per-shard blocks)."""
    starts = np.asarray(part.starts, np.int64)
    if starts.ndim != 1 or starts.shape[0] < 2:
        raise ValueError("partition needs at least one shard (starts[K+1])")
    if starts[0] != 0 or starts[-1] != g.num_nodes:
        raise ValueError(
            f"partition must span [0, {g.num_nodes}), got [{starts[0]}, {starts[-1]})"
        )
    if np.any(np.diff(starts) < 0):
        raise ValueError("partition starts must be monotone non-decreasing")
    if part.order is not None:
        order = np.asarray(part.order, np.int64)
        if order.shape != (g.num_nodes,):
            raise ValueError(
                f"partition order must be a permutation of [{g.num_nodes}] "
                f"nodes, got shape {order.shape}"
            )
        seen = np.zeros(g.num_nodes, bool)
        seen[order] = True
        if not seen.all():
            raise ValueError("partition order must be a permutation (exact cover)")
        for k in range(part.num_shards):
            lo, hi = part.nodes(k)
            if np.any(np.diff(order[lo:hi]) <= 0):
                raise ValueError(
                    f"partition order block of shard {k} must be sorted "
                    f"ascending (canonical form)"
                )


def shard_edge_counts(g: Graph, part: Partition) -> np.ndarray:
    """Edges owned by each shard, int64[num_shards] — the work-balance metric."""
    if part.contiguous:
        starts = np.asarray(part.starts, np.int64)
        return np.diff(g.indptr[starts])
    deg = np.diff(g.indptr).astype(np.int64)
    return np.asarray(
        [int(deg[part.owned(k)].sum()) for k in range(part.num_shards)],
        np.int64,
    )


def partition_cut_edges(g: Graph, part: Partition) -> int:
    """Edges whose source lives on a different shard than their destination."""
    dst = np.repeat(np.arange(g.num_nodes, dtype=np.int64), np.diff(g.indptr))
    src = np.asarray(g.indices, np.int64)
    return int(np.sum(part.owner_of(dst) != part.owner_of(src)))


def partition_halo_volume(g: Graph, part: Partition) -> int:
    """Σ_k |halo(k)| — rows exchanged per layer, the distributed off-chip
    traffic metric ``bench_sharded_serve`` tracks."""
    return sum(
        int(halo_nodes(g, part, k).size) for k in range(part.num_shards)
    )


@dataclasses.dataclass(frozen=True)
class ShardSubgraph:
    """One shard's slice of the global graph, re-indexed into local space.

    The local node space is ``[owned rows | halo rows]``: nodes ``[0,
    num_owned)`` are the shard's owned global ids in ascending order
    (``owned``), and nodes ``[num_owned, num_owned + halo.size)`` are the
    remote neighbours in ``halo`` order. Halo nodes have empty in-neighbour
    rows (they are gather *sources* only), so aggregation over ``graph``
    writes real values exactly into the owned rows — the property the sharded
    executor relies on when it keeps ``out[:num_owned]``.

    Per-edge data computed globally (aggregation coefficients, runtime
    attention scores) maps onto local edges via ``edge_range`` — the shard's
    half-open slice of the global CSR edge arrays when the partition is
    contiguous — or via ``edge_idx`` (int64[num_edges] global CSR positions
    in local edge order) when it is not. Exactly one of the two is set.
    """

    index: int
    lo: int  # position range within the partition order
    hi: int
    halo: np.ndarray  # int64[H] global ids, sorted unique
    local_ids: np.ndarray  # int64[num_owned + H] global id of each local row
    graph: Graph  # local-index subgraph (owned + halo nodes)
    edge_range: Optional[Tuple[int, int]]  # [e_lo, e_hi) into global edges
    edge_idx: Optional[np.ndarray] = None  # int64[num_edges] global positions

    @property
    def num_owned(self) -> int:
        return self.hi - self.lo

    @property
    def num_local(self) -> int:
        return int(self.local_ids.shape[0])

    @property
    def owned(self) -> np.ndarray:
        """Global ids of the owned rows, ascending (= local rows [0, num_owned))."""
        return self.local_ids[: self.num_owned]

    @property
    def num_edges(self) -> int:
        if self.edge_range is not None:
            return int(self.edge_range[1] - self.edge_range[0])
        return int(self.edge_idx.shape[0])

    def slice_edges(self, vec: np.ndarray) -> np.ndarray:
        """Slice a global per-edge array onto this shard's local edge order."""
        if self.edge_range is not None:
            e_lo, e_hi = self.edge_range
            return vec[e_lo:e_hi]
        return vec[self.edge_idx]


def shard_subgraph(g: Graph, part: Partition, k: int) -> ShardSubgraph:
    """Extract shard k's local subgraph (owned rows + halo sources).

    Edge order is preserved from the global CSR row-major over the shard's
    owned rows, so the local plan a scheduler builds over this subgraph
    aggregates exactly the same per-edge terms as the global plan restricted
    to the shard's nodes.
    """
    lo, hi = part.nodes(k)
    halo = halo_nodes(g, part, k)
    if part.contiguous:
        e_lo, e_hi = int(g.indptr[lo]), int(g.indptr[hi])
        src = g.indices[e_lo:e_hi].astype(np.int64)
        owned_n = hi - lo
        local = np.where(
            (src >= lo) & (src < hi), src - lo, owned_n + np.searchsorted(halo, src)
        )
        indptr_local = np.concatenate(
            [g.indptr[lo : hi + 1] - e_lo, np.full(halo.size, e_hi - e_lo, np.int64)]
        )
        owned_ids = np.arange(lo, hi, dtype=np.int64)
        edge_range: Optional[Tuple[int, int]] = (e_lo, e_hi)
        edge_idx = None
    else:
        owned_ids = part.owned(k)
        owned_n = owned_ids.shape[0]
        edge_idx = _owned_edge_idx(g, owned_ids)
        src = g.indices[edge_idx].astype(np.int64)
        owned_mask = np.zeros(g.num_nodes, bool)
        owned_mask[owned_ids] = True
        local = np.where(
            owned_mask[src],
            np.searchsorted(owned_ids, src),
            owned_n + np.searchsorted(halo, src),
        )
        deg = (g.indptr[owned_ids + 1] - g.indptr[owned_ids]).astype(np.int64)
        indptr_local = np.concatenate(
            [[0], np.cumsum(deg), np.full(halo.size, edge_idx.size, np.int64)]
        )
        edge_range = None
    local_g = Graph(
        indptr=indptr_local.astype(np.int64),
        indices=local.astype(np.int32),
        num_nodes=owned_n + int(halo.size),
        name=f"{g.name}/shard{k}",
    )
    local_ids = np.concatenate([owned_ids, halo])
    return ShardSubgraph(
        index=k,
        lo=lo,
        hi=hi,
        halo=halo,
        local_ids=local_ids,
        graph=local_g,
        edge_range=edge_range,
        edge_idx=edge_idx,
    )
