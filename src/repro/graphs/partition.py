"""Graph partitioning for distributed (multi-chip) GNN execution.

Nodes are partitioned into contiguous CSR ranges balanced by *edge count*
(aggregation work ∝ edges, the paper's central observation), one range per
data-parallel shard. Each shard owns its nodes' output rows; neighbour
embeddings crossing the cut are exchanged with an all-gather of boundary
("halo") nodes before aggregation — the distributed analogue of the Feature
Bank fetching remote neighbours.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.graphs.csr import Graph

__all__ = ["Partition", "partition_by_edges", "halo_nodes"]


@dataclasses.dataclass(frozen=True)
class Partition:
    """Half-open node ranges [starts[k], starts[k+1]) per shard."""

    starts: np.ndarray  # int64[num_shards + 1]

    @property
    def num_shards(self) -> int:
        return int(self.starts.shape[0]) - 1

    def shard_of(self, node: int) -> int:
        return int(np.searchsorted(self.starts, node, side="right")) - 1

    def nodes(self, k: int) -> Tuple[int, int]:
        return int(self.starts[k]), int(self.starts[k + 1])


def partition_by_edges(g: Graph, num_shards: int) -> Partition:
    """Contiguous ranges with near-equal edge counts (work balance).

    Work balance — not node balance — is what keeps data-parallel shards from
    straggling on skewed graphs; this is the cluster-level restatement of the
    paper's event-driven argument.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    cum = g.indptr  # cumulative edges by node boundary
    total = g.num_edges
    targets = (np.arange(1, num_shards) * total) / num_shards
    cuts = np.searchsorted(cum, targets, side="left")
    starts = np.concatenate([[0], cuts, [g.num_nodes]]).astype(np.int64)
    starts = np.maximum.accumulate(starts)  # keep monotone on degenerate graphs
    return Partition(starts=starts)


def halo_nodes(g: Graph, part: Partition, k: int) -> np.ndarray:
    """Remote neighbour ids shard k must fetch before aggregating its range."""
    lo, hi = part.nodes(k)
    nbrs = g.indices[g.indptr[lo] : g.indptr[hi]]
    remote = nbrs[(nbrs < lo) | (nbrs >= hi)]
    return np.unique(remote)
