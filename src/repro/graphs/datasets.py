"""Synthetic graph datasets calibrated to the paper's Table 4.

There is no network access in this environment, so the six benchmark graphs
(Cora, CiteSeer, PubMed, Flickr, Reddit, Yelp) are *regenerated* as random
graphs whose node count, mean degree, feature width and degree skew match the
published statistics. Degree distributions of citation/social graphs are heavy
tailed; we draw degrees from a discretized lognormal calibrated so that

  * mean(degree)  == Table 4 mean degree,
  * max(degree)   is a large multiple of the mean (social graphs have hubs),

which is the property AMPLE's event-driven flow exploits (the double-buffered
baseline's cost is driven by the *max* degree per batch while AMPLE's is driven
by the *sum*). All generators are deterministic in ``seed``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import numpy as np

from repro.graphs.csr import Graph, from_edge_list

__all__ = [
    "DatasetSpec",
    "PAPER_DATASETS",
    "make_dataset",
    "make_lognormal_graph",
    "make_clustered_graph",
    "dataset_cache_dir",
]

#: Environment variable naming the on-disk dataset cache directory. Unset
#: (and no explicit ``cache_dir``) disables caching — generation stays pure.
CACHE_ENV = "REPRO_DATASET_CACHE"

#: Cache-key version of the structure generator. Bump on ANY change to
#: ``make_lognormal_graph``'s output so cached graphs can't go stale.
_GEN_VERSION = 1


def dataset_cache_dir() -> Optional[str]:
    """The configured on-disk cache directory, or None when disabled."""
    d = os.environ.get(CACHE_ENV, "").strip()
    return d or None


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_nodes: int
    mean_degree: float
    feature_dim: int
    dq_float_ratio: float  # Table 4 "DQ ratio": fraction of nodes kept in float
    num_classes: int = 16
    sigma: float = 1.25  # lognormal shape: degree skew (hubs)


# Table 4 of the paper. (num_classes is not in the paper; chosen plausibly.)
PAPER_DATASETS: Dict[str, DatasetSpec] = {
    "cora": DatasetSpec("cora", 2_708, 3.9, 1_433, 0.021, num_classes=7),
    "citeseer": DatasetSpec("citeseer", 3_327, 2.7, 3_703, 0.027, num_classes=6),
    "pubmed": DatasetSpec("pubmed", 19_717, 4.5, 500, 0.029, num_classes=3),
    "flickr": DatasetSpec("flickr", 89_250, 10.0, 500, 0.002, num_classes=7),
    "reddit": DatasetSpec("reddit", 232_965, 99.6, 602, 0.027, num_classes=41),
    "yelp": DatasetSpec("yelp", 716_847, 19.5, 300, 0.004, num_classes=100),
}


def _lognormal_degrees(
    n: int, mean_degree: float, sigma: float, rng: np.random.Generator
) -> np.ndarray:
    """Integer degree sequence with the requested mean and lognormal tail."""
    # E[lognormal(mu, sigma)] = exp(mu + sigma^2/2)  =>  solve mu for the mean.
    mu = np.log(max(mean_degree, 1e-6)) - 0.5 * sigma * sigma
    deg = rng.lognormal(mean=mu, sigma=sigma, size=n)
    deg = np.maximum(np.rint(deg), 1).astype(np.int64)
    deg = np.minimum(deg, n - 1 if n > 1 else 1)
    # Rescale-by-sampling to hit the target edge count nearly exactly: adjust a
    # random subset up/down by 1 until the total matches.
    target = int(round(mean_degree * n))
    diff = target - int(deg.sum())
    if diff != 0:
        idx = rng.permutation(n)
        step = 1 if diff > 0 else -1
        k = abs(diff)
        # nodes eligible for decrement must keep degree >= 1
        pos = 0
        while k > 0 and pos < n:
            i = idx[pos % n]
            nd = deg[i] + step
            if 1 <= nd <= n - 1:
                deg[i] = nd
                k -= 1
            pos += 1
    return deg


def make_lognormal_graph(
    num_nodes: int,
    mean_degree: float,
    *,
    sigma: float = 1.25,
    seed: int = 0,
    name: str = "synthetic",
) -> Graph:
    """Random CSR graph with lognormal in-degree distribution.

    Neighbour ids are sampled uniformly (with replacement then dedup within a
    row); the realized mean degree is within ~1% of the request after dedup.
    Built row-wise directly in CSR form to stay O(E) in memory.
    """
    rng = np.random.default_rng(seed)
    deg = _lognormal_degrees(num_nodes, mean_degree, sigma, rng)
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, num_nodes, size=int(indptr[-1]), dtype=np.int64)
    # per-row sort + dedup (replace dups by resample once; residual dups get
    # dropped by compaction). Vectorized: sort (row, idx) pairs and mask repeats.
    rows = np.repeat(np.arange(num_nodes, dtype=np.int64), deg)
    order = np.lexsort((indices, rows))
    rows, indices = rows[order], indices[order]
    dup = np.zeros(indices.shape[0], bool)
    if indices.size:
        dup[1:] = (indices[1:] == indices[:-1]) & (rows[1:] == rows[:-1])
    self_loop = indices == rows
    keep = ~(dup | self_loop)
    rows, indices = rows[keep], indices[keep]
    new_deg = np.zeros(num_nodes, np.int64)
    np.add.at(new_deg, rows, 1)
    # guarantee min degree 1 (isolated rows get one random neighbour)
    iso = np.nonzero(new_deg == 0)[0]
    if iso.size:
        extra = (iso + 1 + rng.integers(0, num_nodes - 1, iso.size)) % num_nodes
        rows = np.concatenate([rows, iso])
        indices = np.concatenate([indices, extra])
        order = np.lexsort((indices, rows))
        rows, indices = rows[order], indices[order]
        new_deg[iso] = 1
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.cumsum(new_deg, out=indptr[1:])
    return Graph(
        indptr=indptr,
        indices=indices.astype(np.int32),
        num_nodes=num_nodes,
        name=name,
    )


def make_clustered_graph(
    num_nodes: int,
    num_clusters: int,
    *,
    intra_degree: float = 8.0,
    inter_degree: float = 1.0,
    seed: int = 0,
    shuffle: bool = True,
    name: str = "clustered",
) -> Graph:
    """Planted-community graph: dense inside clusters, sparse across them.

    Each node draws ~``intra_degree`` in-neighbours from its own cluster and
    ~``inter_degree`` from the rest of the graph. With ``shuffle=True`` node
    ids are permuted so cluster membership is *uncorrelated with node order*
    — the adversarial case for contiguous-range partitioning (it cuts nearly
    every intra-cluster edge) and exactly the structure a min-cut partitioner
    recovers. The partitioner tests and ``bench_sharded_serve`` use this as
    the halo-volume workload.
    """
    if num_clusters < 1 or num_nodes < num_clusters:
        raise ValueError("need num_nodes >= num_clusters >= 1")
    rng = np.random.default_rng(seed)
    cluster = np.arange(num_nodes, dtype=np.int64) % num_clusters
    members = [np.nonzero(cluster == c)[0] for c in range(num_clusters)]
    n_intra = rng.poisson(intra_degree, num_nodes).astype(np.int64)
    n_inter = rng.poisson(inter_degree, num_nodes).astype(np.int64)
    dst_parts, src_parts = [], []
    for v in range(num_nodes):
        mine = members[cluster[v]]
        ki = int(n_intra[v])
        if ki and mine.size > 1:
            src_parts.append(mine[rng.integers(0, mine.size, ki)])
            dst_parts.append(np.full(ki, v, np.int64))
        ke = int(n_inter[v])
        if ke:
            src_parts.append(rng.integers(0, num_nodes, ke))
            dst_parts.append(np.full(ke, v, np.int64))
    src = np.concatenate(dst_parts and src_parts or [np.zeros(0, np.int64)])
    dst = np.concatenate(dst_parts or [np.zeros(0, np.int64)])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if shuffle:
        perm = rng.permutation(num_nodes)
        src, dst = perm[src], perm[dst]
    g = from_edge_list(src, dst, num_nodes, dedup=True, name=name)
    # guarantee min in-degree 1 so every row aggregates something
    deg = np.diff(g.indptr)
    iso = np.nonzero(deg == 0)[0]
    if iso.size:
        extra_src = (iso + 1) % num_nodes
        dsts = np.concatenate([dst, iso])
        srcs = np.concatenate([src, extra_src])
        g = from_edge_list(srcs, dsts, num_nodes, dedup=True, name=name)
    return g


def _cached_structure(
    cache_dir: str, spec: DatasetSpec, n: int, seed: int
) -> Graph:
    """Load (or generate-and-save) a graph *structure* from the disk cache.

    Keyed on everything that shapes the topology: a generator version (bump
    ``_GEN_VERSION`` whenever ``make_lognormal_graph``'s construction
    changes, or stale structures survive on disk), name, node count, mean
    degree, sigma and seed. Only the structure is cached — features are
    cheap to regenerate deterministically and would triple the disk
    footprint. The write is atomic (tmp + rename) so concurrent test
    workers never observe a half-written file.
    """
    key = (
        f"{spec.name}-n{n}-d{spec.mean_degree:g}-s{spec.sigma:g}-seed{seed}"
        f"-g{_GEN_VERSION}"
    )
    path = os.path.join(cache_dir, f"{key}.npz")
    if os.path.exists(path):
        with np.load(path) as z:
            return Graph(
                indptr=z["indptr"],
                indices=z["indices"],
                num_nodes=int(z["num_nodes"]),
                name=str(z["name"]),
            )
    g = make_lognormal_graph(
        n, spec.mean_degree, sigma=spec.sigma, seed=seed, name=spec.name
    )
    os.makedirs(cache_dir, exist_ok=True)
    tmp = f"{path}.{os.getpid()}.tmp.npz"  # savez appends .npz otherwise
    np.savez(
        tmp,
        indptr=g.indptr,
        indices=g.indices,
        num_nodes=np.int64(g.num_nodes),
        name=np.str_(g.name),
    )
    os.replace(tmp, path)
    return g


def make_dataset(
    spec_or_name,
    *,
    seed: int = 0,
    with_features: bool = True,
    feature_scale: float = 1.0,
    max_nodes: Optional[int] = None,
    max_feature_dim: Optional[int] = None,
    cache_dir: Optional[str] = None,
) -> Graph:
    """Instantiate a paper dataset (optionally size-reduced for CPU benches).

    ``max_nodes`` / ``max_feature_dim`` scale the graph down proportionally —
    used by smoke tests and CPU wall-clock benches; the discrete-event
    simulator always uses the full published sizes.

    ``cache_dir`` (or the ``REPRO_DATASET_CACHE`` env var) enables an
    on-disk structure cache keyed on (spec, size, seed): regenerating yelp's
    717K-node lognormal graph dominates every large-graph test/bench run, so
    repeat processes load the CSR arrays instead. Cached loads are
    bit-identical to generation (asserted by tests).
    """
    spec = (
        spec_or_name
        if isinstance(spec_or_name, DatasetSpec)
        else PAPER_DATASETS[str(spec_or_name).lower()]
    )
    n = spec.num_nodes if max_nodes is None else min(spec.num_nodes, max_nodes)
    d = (
        spec.feature_dim
        if max_feature_dim is None
        else min(spec.feature_dim, max_feature_dim)
    )
    cdir = cache_dir if cache_dir is not None else dataset_cache_dir()
    if cdir:
        g = _cached_structure(cdir, spec, n, seed)
    else:
        g = make_lognormal_graph(
            n, spec.mean_degree, sigma=spec.sigma, seed=seed, name=spec.name
        )
    if with_features:
        rng = np.random.default_rng(seed + 1)
        feats = rng.standard_normal((n, d)).astype(np.float32) * feature_scale
        g = g.with_features(feats)
    return g
