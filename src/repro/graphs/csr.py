"""CSR graph structures — the substrate under AMPLE's scheduler.

Graphs are host-side objects (numpy) because the ExecutionPlan (the analogue of
AMPLE's Node Instruction Decoder programming) is built on the host before any
device computation, exactly as the paper's host programs nodeslots ahead of the
accelerator. Device-side code only ever sees the dense tile arrays the planner
emits.

Conventions
-----------
* ``indptr[i]:indptr[i+1]`` spans the *incoming* neighbour list of node ``i``
  (message sources ``j`` in Eq. 1 of the paper).
* ``indices`` holds the neighbour node ids, sorted per node for determinism.
* Self-loops are represented explicitly when a model requires them (GCN adds
  them; GIN uses an epsilon-weighted residual instead).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "add_self_loops",
    "from_edge_list",
    "disjoint_union",
    "validate",
    "gcn_norm_coeffs",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """A directed graph in CSR form over incoming edges.

    Attributes:
      indptr:   int64[N+1]  CSR row pointers (row i = in-neighbours of node i).
      indices:  int32[E]    neighbour (source) node ids.
      num_nodes: N.
      features: optional float32[N, D] node feature matrix.
      edge_weights: optional float32[E] aligned with ``indices``.
      name: human-readable dataset name.
    """

    indptr: np.ndarray
    indices: np.ndarray
    num_nodes: int
    features: Optional[np.ndarray] = None
    edge_weights: Optional[np.ndarray] = None
    name: str = "graph"

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        """In-degree per node, int64[N]."""
        return np.diff(self.indptr)

    @property
    def mean_degree(self) -> float:
        return float(self.num_edges) / float(max(self.num_nodes, 1))

    @property
    def feature_dim(self) -> int:
        if self.features is None:
            raise ValueError("graph has no features attached")
        return int(self.features.shape[1])

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i] : self.indptr[i + 1]]

    def with_features(self, features: np.ndarray) -> "Graph":
        if features.shape[0] != self.num_nodes:
            raise ValueError(
                f"features rows {features.shape[0]} != num_nodes {self.num_nodes}"
            )
        return dataclasses.replace(self, features=np.asarray(features, np.float32))

    def dense_adjacency(self) -> np.ndarray:
        """float32[N, N] with A[i, j] = weight of edge j->i. Test-scale only."""
        if self.num_nodes > 20_000:
            raise ValueError("dense adjacency requested for a large graph")
        a = np.zeros((self.num_nodes, self.num_nodes), np.float32)
        w = (
            self.edge_weights
            if self.edge_weights is not None
            else np.ones(self.num_edges, np.float32)
        )
        for i in range(self.num_nodes):
            a[i, self.neighbors(i)] += w[self.indptr[i] : self.indptr[i + 1]]
        return a


def from_edge_list(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    *,
    undirected: bool = False,
    dedup: bool = True,
    name: str = "graph",
) -> Graph:
    """Build a CSR ``Graph`` from (src -> dst) edge arrays.

    Incoming-edge CSR: row ``i`` lists all ``src`` with an edge into ``i``.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if src.shape != dst.shape:
        raise ValueError("src/dst shape mismatch")
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    if src.size and (src.min() < 0 or src.max() >= num_nodes):
        raise ValueError("src node id out of range")
    if dst.size and (dst.min() < 0 or dst.max() >= num_nodes):
        raise ValueError("dst node id out of range")
    if dedup and src.size:
        pair = dst * num_nodes + src
        _, keep = np.unique(pair, return_index=True)
        src, dst = src[keep], dst[keep]
    order = np.lexsort((src, dst))
    src, dst = src[order], dst[order]
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(
        indptr=indptr,
        indices=src.astype(np.int32),
        num_nodes=num_nodes,
        name=name,
    )


def add_self_loops(g: Graph) -> Graph:
    """Return a copy of ``g`` with a self edge on every node (idempotent)."""
    n = g.num_nodes
    rows = np.repeat(np.arange(n, dtype=np.int64), g.degrees)
    has_loop = np.zeros(n, bool)
    has_loop[rows[g.indices == rows]] = True if g.num_edges else False
    missing = np.nonzero(~has_loop)[0]
    if missing.size == 0:
        return g
    src = np.concatenate([g.indices.astype(np.int64), missing])
    dst = np.concatenate([rows, missing])
    out = from_edge_list(src, dst, n, dedup=True, name=g.name)
    if g.features is not None:
        out = out.with_features(g.features)
    return out


def disjoint_union(
    graphs: "list[Graph]",
    *,
    pad_num_nodes: Optional[int] = None,
    pad_num_edges: Optional[int] = None,
) -> Graph:
    """Block-diagonal union of independent graphs (no cross edges).

    Node ids of graph k are offset by the node counts of graphs 0..k-1, so
    CSR rows concatenate directly. Because every aggregation coefficient in
    this codebase depends only on per-node degree (sum/mean/GCN norm), any
    GNN layer over the union equals the per-graph layers stacked — this is
    what lets the serving engine batch independent small-graph requests into
    one padded device call. Features are concatenated when all graphs carry
    them; edge weights likewise.

    ``pad_num_nodes``/``pad_num_edges`` grow the union to a **size class**:
    padding nodes are appended after the real members (isolated, zero
    features), and padding edges — when requested — are self-edges spread
    over the padding nodes, so they can never influence a real node's
    aggregate. Padding a union to a node/edge bucket makes different member
    mixes share device-call shapes, which is what lets the continuous-
    batching serve path reuse one compiled executable across ever-changing
    batch compositions. (That path pads *nodes* here and pads edge capacity
    at the tile level via ``assemble_union_plan`` — cheaper than planning
    fake edges; graph-level ``pad_num_edges`` is for callers that feed a
    shape-stable union straight into ``compile_plans`` without the
    member-piece machinery.)
    """
    if not graphs:
        raise ValueError("disjoint_union of no graphs")
    if len(graphs) == 1 and pad_num_nodes is None and pad_num_edges is None:
        return graphs[0]
    offsets = np.cumsum([0] + [g.num_nodes for g in graphs])
    n_real = int(offsets[-1])
    e_real = sum(g.num_edges for g in graphs)
    n_total = n_real if pad_num_nodes is None else int(pad_num_nodes)
    e_total = e_real if pad_num_edges is None else int(pad_num_edges)
    if n_total < n_real:
        raise ValueError(f"pad_num_nodes {n_total} < union nodes {n_real}")
    if e_total < e_real:
        raise ValueError(f"pad_num_edges {e_total} < union edges {e_real}")
    n_pad, e_pad = n_total - n_real, e_total - e_real
    if e_pad > 0 and n_pad == 0:
        raise ValueError(
            "edge padding needs at least one padding node to attach self-edges "
            f"to (pad_num_nodes={n_total} leaves none)"
        )
    indptr = [np.asarray([0], np.int64)]
    indices = []
    edge_off = 0
    for g, off in zip(graphs, offsets):
        indptr.append(g.indptr[1:] + edge_off)
        indices.append(g.indices.astype(np.int64) + off)
        edge_off += g.num_edges
    if n_pad:
        # e_pad self-edges spread round-robin over the padding nodes; a
        # padding node's degree only ever shapes its own (discarded) row.
        per = np.full(n_pad, e_pad // n_pad, np.int64)
        per[: e_pad % n_pad] += 1
        pad_ids = np.arange(n_real, n_total, dtype=np.int64)
        indptr.append(edge_off + np.cumsum(per))
        indices.append(np.repeat(pad_ids, per))
    features = None
    if all(g.features is not None for g in graphs):
        features = np.concatenate([g.features for g in graphs], axis=0)
        if n_pad:
            features = np.concatenate(
                [features, np.zeros((n_pad, features.shape[1]), np.float32)], axis=0
            )
    edge_weights = None
    if all(g.edge_weights is not None for g in graphs):
        edge_weights = np.concatenate(
            [g.edge_weights for g in graphs] + [np.zeros(e_pad, np.float32)]
        )
    return Graph(
        indptr=np.concatenate(indptr),
        indices=np.concatenate(indices).astype(np.int32),
        num_nodes=n_total,
        features=features,
        edge_weights=edge_weights,
        name="+".join(dict.fromkeys(g.name for g in graphs)),
    )


def validate(g: Graph) -> None:
    """Raise if structural invariants are broken (used by property tests)."""
    if g.indptr.ndim != 1 or g.indptr.shape[0] != g.num_nodes + 1:
        raise AssertionError("indptr shape")
    if g.indptr[0] != 0 or g.indptr[-1] != g.num_edges:
        raise AssertionError("indptr endpoints")
    if np.any(np.diff(g.indptr) < 0):
        raise AssertionError("indptr not monotone")
    if g.num_edges and (g.indices.min() < 0 or g.indices.max() >= g.num_nodes):
        raise AssertionError("indices out of range")
    if g.features is not None and g.features.shape[0] != g.num_nodes:
        raise AssertionError("features rows")
    if g.edge_weights is not None and g.edge_weights.shape[0] != g.num_edges:
        raise AssertionError("edge_weights length")


def gcn_norm_coeffs(g: Graph) -> np.ndarray:
    """Per-edge GCN normalization 1/sqrt(d̂_j d̂_i) (Eq. 2), float32[E].

    ``d̂_i = 1 + in_degree(i)`` as in the paper (self-connection counted).
    Assumes self-loops have already been added when the model calls for them;
    the coefficient uses the paper's d̂ definition regardless, so the oracle
    and engine agree by construction.
    """
    deg_hat = (g.degrees.astype(np.float64)).clip(min=0) + 0.0
    # Paper: d̂_i = 1 + Σ_j e_{j,i}; with explicit self-loops the +1 is the loop
    # itself, so use raw in-degree here to avoid double counting.
    deg_hat = np.maximum(deg_hat, 1.0)
    inv_sqrt = 1.0 / np.sqrt(deg_hat)
    rows = np.repeat(np.arange(g.num_nodes, dtype=np.int64), g.degrees)
    coeff = inv_sqrt[rows] * inv_sqrt[g.indices]
    return coeff.astype(np.float32)
