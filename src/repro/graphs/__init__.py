"""Graph substrate: CSR structures, synthetic datasets, partitioning."""
from repro.graphs.csr import (
    Graph, add_self_loops, disjoint_union, from_edge_list, gcn_norm_coeffs, validate,
)
from repro.graphs.datasets import (
    PAPER_DATASETS, DatasetSpec, make_clustered_graph, make_dataset,
    make_lognormal_graph,
)
from repro.graphs.partition import (
    Partition, ShardSubgraph, halo_nodes, make_partition, partition_by_edges,
    partition_cut_edges, partition_halo_volume, partition_min_cut,
    shard_edge_counts, shard_subgraph, validate_partition,
)
