"""Trainer: the driver loop with checkpoint/restart and fault injection.

Design for 1000+ nodes, demonstrated at laptop scale:
* deterministic data from (seed, step) → restart replays the exact stream;
* async checkpoints every ``ckpt_every`` steps, atomic on disk;
* automatic resume: ``run()`` picks up the newest checkpoint if present;
* fault injection hook (``crash_at``) kills the process state mid-run in
  tests; resume must be bit-exact (verified in tests/test_fault_tolerance.py);
* optional gradient compression (top-k/int8 + error feedback).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import synthetic_batch
from repro.models.api import model_init
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 64
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    ckpt_async: bool = False
    log_every: int = 10
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    warmup: int = 10
    compressor: Optional[object] = None


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig, *, policy=None):
        self.cfg = cfg
        self.tcfg = tcfg
        kwargs = dict(
            total_steps=tcfg.steps, warmup=tcfg.warmup, compressor=tcfg.compressor
        )
        if policy is not None:
            kwargs["policy"] = policy
        self.step_fn = jax.jit(make_train_step(cfg, tcfg.opt, **kwargs))
        self.metrics_log: List[Dict] = []

    def init_state(self):
        params = model_init(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        state = init_train_state(self.cfg, params)
        if self.tcfg.compressor is not None:
            state["compress"] = self.tcfg.compressor.init_state(params)
        return state

    def _batch(self, step: int) -> Dict:
        b = synthetic_batch(
            seed=self.tcfg.seed,
            step=step,
            batch=self.tcfg.batch,
            seq=self.tcfg.seq,
            vocab=self.cfg.vocab_size,
            family=self.cfg.family,
            d_model=self.cfg.d_model,
        )
        return {k: jnp.asarray(v) for k, v in b.items()}

    def run(self, *, crash_at: Optional[int] = None) -> Dict:
        """Train to tcfg.steps; resume from the newest checkpoint if any.

        ``crash_at``: raise after that step completes (fault-injection tests).
        """
        t = self.tcfg
        state = self.init_state()
        start = 0
        if t.ckpt_dir and ckpt.latest_step(t.ckpt_dir) is not None:
            start = ckpt.latest_step(t.ckpt_dir)
            state = ckpt.restore(t.ckpt_dir, state)
            state = jax.tree.map(jnp.asarray, state)
        t0 = time.time()
        for step in range(start, t.steps):
            batch = self._batch(step)
            state, metrics = self.step_fn(state, batch)
            if (step + 1) % t.log_every == 0 or step + 1 == t.steps:
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = step + 1
                rec["wall_s"] = time.time() - t0
                self.metrics_log.append(rec)
            if t.ckpt_dir and (step + 1) % t.ckpt_every == 0:
                if t.ckpt_async:
                    ckpt.save_async(state, t.ckpt_dir, step + 1)
                else:
                    ckpt.save(state, t.ckpt_dir, step + 1)
            if crash_at is not None and step + 1 >= crash_at:
                raise RuntimeError(f"injected fault after step {step + 1}")
        ckpt.wait_pending()
        if t.ckpt_dir:
            ckpt.save(state, t.ckpt_dir, t.steps)
        return {"state": state, "metrics": self.metrics_log}
