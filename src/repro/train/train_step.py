"""train_step / serve_step factories — the functions the dry-run lowers.

``make_train_step(cfg)`` builds the full optimization step: loss (CE + MoE
aux) → grads → optional gradient compression → AdamW update. The returned
function is pure, jit/pjit-friendly, and is exactly what launch/dryrun.py
lowers onto the production mesh and launch/train.py runs on real hardware.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.api import loss_fn, model_decode_step, model_init_cache
from repro.models.lm.transformer import NO_POLICY
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine

__all__ = ["TrainState", "make_train_step", "make_serve_step", "init_train_state"]


class TrainState(dict):
    """Plain-dict train state: {params, opt (AdamWState), step}."""


def init_train_state(cfg: ModelConfig, params, opt_cfg: AdamWConfig = AdamWConfig()):
    return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    policy=NO_POLICY,
    schedule: Optional[Callable] = None,
    total_steps: int = 10_000,
    warmup: int = 100,
    compressor=None,  # distributed/compression.Compressor or None
) -> Callable:
    sched = schedule or functools.partial(
        warmup_cosine, peak_lr=opt_cfg.lr, warmup=warmup, total=total_steps
    )

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        def _loss(p):
            return loss_fn(p, cfg, batch, policy=policy)

        (loss, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(
            state["params"]
        )
        if compressor is not None:
            grads, state_c = compressor.compress_decompress(
                grads, state.get("compress")
            )
        lr = sched(state["step"] + 1)  # 1-indexed: warmup starts at lr>0
        params, opt, opt_metrics = adamw_update(
            grads, state["opt"], state["params"], opt_cfg, lr=lr
        )
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if compressor is not None:
            new_state["compress"] = state_c
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, *, policy=NO_POLICY) -> Callable:
    """One batched decode step (the function decode_* shape cells lower)."""

    def serve_step(params, batch: Dict, cache, cache_len):
        logits, cache = model_decode_step(
            params, cfg, batch, cache, cache_len, policy=policy
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return serve_step
