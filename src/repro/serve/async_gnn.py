"""Event-driven continuous batching for GNN serving — AMPLE at the queue.

AMPLE's core move is replacing the synchronous double-buffering barrier with
event-driven nodeslots: a slot frees the moment its node finishes, so short
nodes never wait behind stragglers. ``GNNServeEngine.infer_batch`` still has
exactly that barrier at the serving layer — every request up front, one
exact-shape union, everyone waits for everyone. ``AsyncGNNEngine`` removes
it:

  * **admission queue** — ``submit`` validates a request immediately (clear
    errors at the door, not deep in a union concatenate) and enqueues a
    ticket; the caller keeps the ticket and reads its result whenever it
    completes;
  * **micro-batch window** — each ``step`` admits up to ``window`` queued
    requests (bounded by a node budget) into the next disjoint-union batch,
    exactly the slot-recycling loop of continuous-batching LLM engines:
    slots freed by a completed batch are refilled from the queue head on the
    very next tick;
  * **slot recycling without starvation** — admission is strictly FIFO: an
    oversized request closes the current window rather than being skipped,
    so completion order equals submission order and no request starves;
  * **padded size classes** — when the underlying engine has union buckets
    configured, each window's union is padded to a node/edge size class and
    its plan assembled from cached per-member pieces, so the ever-changing
    batch composition stops churning the plan cache and the jit cache.

The engine is deterministic and loop-agnostic: ``submit`` is O(1), ``step``
is the event-loop tick, and ``GNNTicket.result()`` drives the loop until its
request completes. A window served by ``step`` goes through the very same
``_plan_for_batch`` + ``_run`` steps as the synchronous ``infer_batch``, so
async outputs are **bitwise-identical** to the synchronous engine given the
same admitted composition.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.graphs.csr import Graph
from repro.serve.gnn_engine import GNNRequest, GNNResponse, GNNServeEngine

__all__ = ["GNNTicket", "AsyncGNNEngine"]


@dataclasses.dataclass
class GNNTicket:
    """A submitted request's handle: pending until its micro-batch ran."""

    seq: int  # admission order, assigned by submit()
    request: GNNRequest
    response: Optional[GNNResponse] = None
    _engine: Optional["AsyncGNNEngine"] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def done(self) -> bool:
        return self.response is not None

    def result(self) -> GNNResponse:
        """The response; drives the owning engine's loop until completion."""
        while not self.done:
            if self._engine is None or not self._engine.step():
                raise RuntimeError(
                    f"ticket {self.seq} is pending but its engine has no "
                    "admissible work — was it detached?"
                )
        return self.response


class AsyncGNNEngine:
    """Continuous-batching front end over a ``GNNServeEngine``.

    Parameters
    ----------
    engine: a configured ``GNNServeEngine`` — or a ``family="gnn"``
        ModelConfig, from which one is built (``engine_kwargs`` forwarded,
        e.g. ``union_node_bucket``/``num_shards``).
    window: max requests admitted into one micro-batch; defaults to
        ``cfg.gnn_batch_window``. The window is the slot count: a completed
        batch frees all its slots for the next tick's admissions.
    max_batch_nodes: optional node budget per micro-batch. A queued request
        that would overflow the budget closes the window (it is served first
        next tick) — stragglers delay nobody behind them beyond their own
        batch, and nobody overtakes them.
    """

    def __init__(
        self,
        engine,
        params=None,
        *,
        window: Optional[int] = None,
        max_batch_nodes: Optional[int] = None,
        **engine_kwargs,
    ):
        if isinstance(engine, GNNServeEngine):
            if params is not None or engine_kwargs:
                raise ValueError(
                    "pass params/engine kwargs only when constructing from a "
                    "ModelConfig, not when wrapping an existing engine"
                )
            self.engine = engine
        elif isinstance(engine, ModelConfig):
            self.engine = GNNServeEngine(engine, params, **engine_kwargs)
        else:
            raise TypeError(
                f"engine must be a GNNServeEngine or a ModelConfig, got "
                f"{type(engine).__name__}"
            )
        w = self.engine.cfg.gnn_batch_window if window is None else window
        if w < 1:
            raise ValueError("window must be >= 1")
        self.window = int(w)
        self.max_batch_nodes = max_batch_nodes
        self._queue: Deque[GNNTicket] = deque()
        self._seq = 0
        self.stats: Dict[str, int] = {
            "submitted": 0,
            "completed": 0,
            "steps": 0,
            "max_queue_depth": 0,
        }

    # ------------------------------------------------------------ admission
    def submit(self, graph: Graph, features, *, arch: str = "") -> GNNTicket:
        """Admit one request into the queue; returns its ticket immediately.

        Validation happens here, at the admission boundary: a mismatched
        feature matrix or an empty graph raises now, before the request can
        poison a union batch other members are riding in.
        """
        arch = self.engine._arch(arch)
        features = self.engine._validate_request(graph, features)
        ticket = GNNTicket(
            seq=self._seq,
            request=GNNRequest(graph=graph, features=features, arch=arch),
            _engine=self,
        )
        self._seq += 1
        self._queue.append(ticket)
        self.stats["submitted"] += 1
        self.stats["max_queue_depth"] = max(
            self.stats["max_queue_depth"], len(self._queue)
        )
        return ticket

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ event loop
    def _admit(self) -> List[GNNTicket]:
        """Pop the next micro-batch off the queue head (FIFO, budgeted)."""
        batch: List[GNNTicket] = []
        nodes = 0
        while self._queue and len(batch) < self.window:
            nxt = self._queue[0]
            n = nxt.request.graph.num_nodes
            if (
                batch
                and self.max_batch_nodes is not None
                and nodes + n > self.max_batch_nodes
            ):
                break  # close the window; nxt leads the next batch
            batch.append(self._queue.popleft())
            nodes += n
        return batch

    def step(self) -> List[GNNTicket]:
        """One event-loop tick: admit a window, run its union, complete it.

        Returns the completed tickets (empty when the queue was idle). The
        union call is ``GNNServeEngine.infer_batch`` — plan assembly + one
        device call — so everything the synchronous engine guarantees
        (per-member Degree-Quant tags, plan/size-class caching, bitwise
        warm repeats) holds per micro-batch.
        """
        batch = self._admit()
        if not batch:
            return []
        try:
            responses = self.engine.infer_batch([t.request for t in batch])
        except Exception:
            # Never strand admitted tickets: put the window back at the queue
            # head in order, so the failure propagates to whoever is driving
            # the loop while every request stays observable and retryable.
            self._queue.extendleft(reversed(batch))
            raise
        self.stats["steps"] += 1
        for ticket, resp in zip(batch, responses):
            ticket.response = resp
        self.stats["completed"] += len(batch)
        return batch

    def drain(self) -> List[GNNResponse]:
        """Run the loop until the queue is empty; responses in admission order."""
        done: List[GNNTicket] = []
        while self._queue:
            done.extend(self.step())
        return [t.response for t in sorted(done, key=lambda t: t.seq)]

    def serve(self, requests: Sequence[GNNRequest]) -> List[GNNResponse]:
        """Submit a request stream and drain it — the offered-load benchmark
        entry point. Unlike ``infer_batch`` this never builds one giant
        union: requests flow through ``window``-sized micro-batches."""
        for r in requests:
            self.submit(r.graph, r.features, arch=r.arch)
        return self.drain()

    # ------------------------------------------------------------- metrics
    def cache_info(self) -> Dict[str, int]:
        return {**self.engine.cache_info(), **self.stats}
