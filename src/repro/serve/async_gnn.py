"""Event-driven continuous batching for GNN serving — AMPLE at the queue.

AMPLE's core move is replacing the synchronous double-buffering barrier with
event-driven nodeslots: a slot frees the moment its node finishes, so short
nodes never wait behind stragglers. ``GNNServeEngine.infer_batch`` still has
exactly that barrier at the serving layer — every request up front, one
exact-shape union, everyone waits for everyone. ``AsyncGNNEngine`` removes
it:

  * **admission queue** — ``submit`` validates a request immediately (clear
    errors at the door, not deep in a union concatenate) and enqueues a
    ticket; the caller keeps the ticket and reads its result whenever it
    completes;
  * **micro-batch window** — each ``step`` admits up to ``window`` queued
    requests (bounded by a node budget) into the next disjoint-union batch,
    exactly the slot-recycling loop of continuous-batching LLM engines:
    slots freed by a completed batch are refilled from the queue head on the
    very next tick;
  * **slot recycling without starvation** — admission is strictly FIFO: an
    oversized request closes the current window rather than being skipped,
    so completion order equals submission order and no request starves;
  * **padded size classes** — when the underlying engine has union buckets
    configured, each window's union is padded to a node/edge size class and
    its plan assembled from cached per-member pieces, so the ever-changing
    batch composition stops churning the plan cache and the jit cache.

The engine is deterministic and loop-agnostic: ``submit`` is O(1), ``step``
is the event-loop tick, and ``GNNTicket.result()`` drives the loop until its
request completes. A window served by ``step`` goes through the very same
``_plan_for_batch`` + ``_run`` steps as the synchronous ``infer_batch``, so
async outputs are **bitwise-identical** to the synchronous engine given the
same admitted composition.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.graphs.csr import Graph
from repro.observe import metrics as ometrics
from repro.observe import trace as otrace
from repro.serve.gnn_engine import (
    GNNRequest,
    GNNResponse,
    GNNServeEngine,
    request_stamp,
)

__all__ = ["GNNTicket", "AsyncGNNEngine"]


@dataclasses.dataclass
class GNNTicket:
    """A submitted request's handle: pending until its micro-batch ran.

    Completion is signalled through a ``threading.Event``: a caller blocked
    in ``result()`` wakes the moment its window executes — whoever drives the
    loop — instead of sleeping out a held window's full deadline remainder.
    A ticket completes either with a ``response`` or, when its window
    exhausted the engine's execution retries, with the ``error`` attached
    (``result()`` re-raises it).
    """

    seq: int  # admission order, assigned by submit()
    request: GNNRequest
    response: Optional[GNNResponse] = None
    arrival: float = 0.0  # request_stamp() at submit; drives the SLO close
    trace_id: str = ""  # per-request correlation id (observe.trace)
    error: Optional[BaseException] = None  # terminal failure, attached after
    # the window's execution retries were exhausted (see window_retries)
    failures: int = 0  # executions of this ticket's window that raised
    _engine: Optional["AsyncGNNEngine"] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False
    )

    @property
    def done(self) -> bool:
        return self.response is not None or self.error is not None

    def _complete(self, response: Optional[GNNResponse] = None,
                  error: Optional[BaseException] = None) -> None:
        self.response = response
        self.error = error
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> GNNResponse:
        """The response; drives the owning engine's loop until completion.

        With a ``window_timeout_ms`` configured, a partially filled window
        is held open for late arrivals — this call waits out the remaining
        deadline on the completion event (so a concurrent driver executing
        the window wakes it immediately, it never oversleeps) and then steps
        again. ``timeout`` bounds the total wait in seconds
        (``TimeoutError`` when exceeded); a ticket whose window exhausted
        its execution retries re-raises the attached error.
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        while not self.done:
            if self._engine is None:
                raise RuntimeError(
                    f"ticket {self.seq} is pending but has no engine — was "
                    "it detached?"
                )
            if self._engine.step():
                continue
            if self.done:  # a concurrent driver completed us mid-step
                break
            wait = self._engine._deadline_wait()
            if wait is None:
                raise RuntimeError(
                    f"ticket {self.seq} is pending but its engine has no "
                    "admissible work — was it detached?"
                )
            if deadline is not None:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    raise TimeoutError(
                        f"ticket {self.seq} still pending after {timeout}s"
                    )
                wait = min(wait, remaining)
            if wait > 0:
                # Event, not sleep: wakes the instant the window executes.
                self._event.wait(wait)
        if self.error is not None:
            raise self.error
        return self.response


class AsyncGNNEngine:
    """Continuous-batching front end over a ``GNNServeEngine``.

    Parameters
    ----------
    engine: a configured ``GNNServeEngine`` — or a ``family="gnn"``
        ModelConfig, from which one is built (``engine_kwargs`` forwarded,
        e.g. ``union_node_bucket``/``num_shards``).
    window: max requests admitted into one micro-batch; defaults to
        ``cfg.gnn_batch_window``. The window is the slot count: a completed
        batch frees all its slots for the next tick's admissions.
    max_batch_nodes: optional node budget per micro-batch. A queued request
        that would overflow the budget closes the window (it is served first
        next tick) — stragglers delay nobody behind them beyond their own
        batch, and nobody overtakes them.
    window_timeout_ms: latency-aware window close. 0 (the historical
        behaviour) admits whatever is queued on every tick; > 0 holds a
        *partially* filled window open — ``step`` returns nothing — until
        either the window fills (count or node budget closes it) or the
        oldest queued request has waited this long, at which point the
        partial window admits at the deadline. Defaults to
        ``cfg.gnn_window_timeout_ms``. ``drain`` always flushes.
    window_retries: how many times one ticket's window may fail execution
        before the ticket is **failed** — the error is attached and
        ``result()`` re-raises it — instead of being requeued again.
        Failures 1..N-1 requeue the window at the queue head (retryable,
        the error propagates to the loop driver); failure N completes the
        tickets exceptionally so a poisoned window can never wedge the
        queue forever. Defaults to ``cfg.gnn_window_retries``.
    """

    def __init__(
        self,
        engine,
        params=None,
        *,
        window: Optional[int] = None,
        max_batch_nodes: Optional[int] = None,
        window_timeout_ms: Optional[float] = None,
        window_retries: Optional[int] = None,
        **engine_kwargs,
    ):
        if isinstance(engine, GNNServeEngine):
            if params is not None or engine_kwargs:
                raise ValueError(
                    "pass params/engine kwargs only when constructing from a "
                    "ModelConfig, not when wrapping an existing engine"
                )
            self.engine = engine
        elif isinstance(engine, ModelConfig):
            self.engine = GNNServeEngine(engine, params, **engine_kwargs)
        else:
            raise TypeError(
                f"engine must be a GNNServeEngine or a ModelConfig, got "
                f"{type(engine).__name__}"
            )
        w = self.engine.cfg.gnn_batch_window if window is None else window
        if w < 1:
            raise ValueError("window must be >= 1")
        self.window = int(w)
        self.max_batch_nodes = max_batch_nodes
        wt = (
            self.engine.cfg.gnn_window_timeout_ms
            if window_timeout_ms is None
            else window_timeout_ms
        )
        if wt < 0:
            raise ValueError("window_timeout_ms must be >= 0")
        self.window_timeout_ms = float(wt)
        wr = (
            self.engine.cfg.gnn_window_retries
            if window_retries is None
            else window_retries
        )
        if wr < 1:
            raise ValueError("window_retries must be >= 1")
        self.window_retries = int(wr)
        self._queue: Deque[GNNTicket] = deque()
        self._seq = 0
        self._held_head: Optional[int] = None  # seq of the last held window head
        # Serializes the event-loop tick: result() may be driven from several
        # waiter threads at once; only one executes a window at a time, the
        # rest wake on their ticket's completion event.
        self._drive_lock = threading.RLock()
        # Registry-backed counters behind the historical dict API; see
        # GNNServeEngine.stats for the rationale.
        self.instance = ometrics.next_instance("gnn_async")
        self.stats: ometrics.StatsView = ometrics.StatsView(
            ometrics.get_registry(),
            "gnn_async",
            {"engine": self.instance},
            keys=(
                "submitted",
                "completed",
                "steps",
                "max_queue_depth",
                "held_windows",  # partial windows held open for late arrivals
                "deadline_closes",  # partial windows admitted at the deadline
                "window_failures",  # executions that raised (requeued or fatal)
                "failed_tickets",  # tickets completed exceptionally (retries out)
            ),
        )

    # ------------------------------------------------------------ admission
    def submit(
        self, graph: Graph, features, *, arch: str = "",
        arrival: Optional[float] = None, trace_id: str = "",
    ) -> GNNTicket:
        """Admit one request into the queue; returns its ticket immediately.

        Validation happens here, at the admission boundary: a mismatched
        feature matrix or an empty graph raises now, before the request can
        poison a union batch other members are riding in. ``arrival`` lets
        an upstream front (the tenancy router) carry its own admission
        timestamp through (a ``request_stamp()``/``perf_counter`` value), so
        ``queue_ms`` covers the full wait from the moment the caller handed
        the request over, not just this queue. ``trace_id`` likewise carries
        an upstream correlation id; one is minted here when tracing is
        enabled and none was passed.
        """
        arch = self.engine._arch(arch)
        features = self.engine._validate_request(graph, features)
        at = request_stamp() if arrival is None else float(arrival)
        rec = otrace.get_recorder()
        if rec.enabled and not trace_id:
            trace_id = otrace.new_trace_id()
        ticket = GNNTicket(
            seq=self._seq,
            request=GNNRequest(
                graph=graph, features=features, arch=arch, admitted_at=at,
                trace_id=trace_id,
            ),
            arrival=at,
            trace_id=trace_id,
            _engine=self,
        )
        if rec.enabled:
            rec.add_instant(
                "submit", cat="serve", trace_id=trace_id,
                args={"seq": ticket.seq, "nodes": graph.num_nodes},
            )
        self._seq += 1
        self._queue.append(ticket)
        self.stats["submitted"] += 1
        self.stats["max_queue_depth"] = max(
            self.stats["max_queue_depth"], len(self._queue)
        )
        return ticket

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ event loop
    def _deadline_wait(self) -> Optional[float]:
        """Seconds until the oldest queued request's deadline; None when no
        timeout applies (idle queue, or no timeout configured)."""
        if self.window_timeout_ms <= 0 or not self._queue:
            return None
        age = request_stamp() - self._queue[0].arrival
        return max(self.window_timeout_ms / 1e3 - age, 0.0)

    def _admit(self, *, flush: bool = False) -> List[GNNTicket]:
        """Pop the next micro-batch off the queue head (FIFO, budgeted).

        With a window timeout, a *partial* window (queue drained before the
        count/node budget closed it) is held back until the oldest member
        has waited out the deadline; ``flush`` overrides (drain/shutdown).
        """
        batch: List[GNNTicket] = []
        nodes = 0
        while self._queue and len(batch) < self.window:
            nxt = self._queue[0]
            n = nxt.request.graph.num_nodes
            if (
                batch
                and self.max_batch_nodes is not None
                and nodes + n > self.max_batch_nodes
            ):
                break  # close the window; nxt leads the next batch
            batch.append(self._queue.popleft())
            nodes += n
        # A window is "closed" — never held — when the count or node budget
        # can admit nothing more: full by count, a successor already waiting
        # (the budget break fired), or the budget itself saturated (nothing
        # that arrives later could ever join this window).
        budget_full = (
            self.max_batch_nodes is not None and nodes >= self.max_batch_nodes
        )
        partial = (
            bool(batch)
            and len(batch) < self.window
            and not self._queue
            and not budget_full
        )
        if partial and not flush and self.window_timeout_ms > 0:
            age_ms = (request_stamp() - batch[0].arrival) * 1e3
            if age_ms < self.window_timeout_ms:
                # Hold the window open for late arrivals; the admission
                # order is untouched (back at the head, in order). Counted
                # once per distinct window head, not per polling tick.
                self._queue.extendleft(reversed(batch))
                if self._held_head != batch[0].seq:
                    self._held_head = batch[0].seq
                    self.stats["held_windows"] += 1
                    rec = otrace.get_recorder()
                    if rec.enabled:
                        rec.add_instant(
                            "window_hold", cat="serve",
                            trace_id=batch[0].trace_id,
                            args={"head_seq": batch[0].seq,
                                  "size": len(batch)},
                        )
                return []
            self.stats["deadline_closes"] += 1
            rec = otrace.get_recorder()
            if rec.enabled:
                # The hold interval as a span: the head waited [arrival,
                # now] for a window that never filled.
                t1 = request_stamp()
                rec.add_span(
                    "window_hold", t1 - age_ms / 1e3, t1, cat="serve",
                    trace_id=batch[0].trace_id,
                    args={"head_seq": batch[0].seq, "deadline_close": True},
                )
        return batch

    def step(self, *, flush: bool = False) -> List[GNNTicket]:
        """One event-loop tick: admit a window, run its union, complete it.

        Returns the completed tickets (empty when the queue was idle, or a
        partial window is being held for its ``window_timeout_ms`` deadline;
        ``flush=True`` admits regardless — the drain/shutdown path). The
        union call is ``GNNServeEngine.infer_batch`` — plan assembly + one
        device call — so everything the synchronous engine guarantees
        (per-member Degree-Quant tags, plan/size-class caching, bitwise
        warm repeats) holds per micro-batch.

        Execution failure is **bounded** by ``window_retries``: the first
        N-1 failures requeue the window at the queue head (in order) and
        re-raise, so the driver observes a retryable fault; the Nth failure
        completes every ticket exceptionally (error attached, events set)
        and returns them — a poisoned window fails loudly instead of
        re-raising to the loop driver forever.
        """
        with self._drive_lock:
            batch = self._admit(flush=flush)
            if not batch:
                return []
            try:
                responses = self.engine.infer_batch([t.request for t in batch])
            except Exception as exc:
                self.stats["window_failures"] += 1
                for t in batch:
                    t.failures += 1
                if batch[0].failures >= self.window_retries:
                    # Retries exhausted: fail the window's tickets instead of
                    # wedging the queue. They complete (done == True) with
                    # the error attached; result() re-raises it.
                    for t in batch:
                        t._complete(error=exc)
                    self.stats["failed_tickets"] += len(batch)
                    return batch
                # Never strand admitted tickets: put the window back at the
                # queue head in order, so the failure propagates to whoever
                # is driving the loop while every request stays observable
                # and retryable.
                self._queue.extendleft(reversed(batch))
                raise
            self.stats["steps"] += 1
            for ticket, resp in zip(batch, responses):
                ticket._complete(response=resp)
            self.stats["completed"] += len(batch)
            return batch

    def drain(self) -> List[GNNResponse]:
        """Run the loop until the queue is empty; responses in admission
        order. Flushes held partial windows — drain is the shutdown path,
        so nothing waits out a deadline here. A ticket that exhausted its
        execution retries contributes ``None`` (its error is attached to
        the ticket itself); transient failures below the retry bound
        propagate as exceptions exactly like ``step``."""
        done: List[GNNTicket] = []
        while self._queue:
            done.extend(self.step(flush=True))
        return [t.response for t in sorted(done, key=lambda t: t.seq)]

    def serve(self, requests: Sequence[GNNRequest]) -> List[GNNResponse]:
        """Submit a request stream and drain it — the offered-load benchmark
        entry point. Unlike ``infer_batch`` this never builds one giant
        union: requests flow through ``window``-sized micro-batches."""
        for r in requests:
            self.submit(r.graph, r.features, arch=r.arch)
        return self.drain()

    # ------------------------------------------------------------- metrics
    def cache_info(self) -> Dict[str, int]:
        return {**self.engine.cache_info(), **self.stats}
