"""Serving telemetry: streaming latency histograms + per-tenant rollups.

The multi-tenant front (``serve/tenancy``) needs latency *distributions*,
not averages — an SLO is a statement about p99, and a mean hides exactly the
tail the admission scheduler exists to protect. Keeping every sample would
grow without bound under production traffic, so latencies stream into a
**log-bucketed histogram**: geometric bucket edges give a fixed relative
error (``rel_error``, default 2.5%) at O(1) memory and O(log B) record cost,
the same trade HDR-histogram-style serving telemetry makes in LLM engines.

``TenantTelemetry`` is the per-tenant rollup the router feeds: two
histograms per tenant (end-to-end latency and admission→execution queue
wait), admission / rejection / preemption / failure counters, SLO
hit-or-violation accounting against the tenant's target, and throughput in
both requests/s and served nodes/s (node-throughput is the unit DWRR
fairness is measured in — a tenant of few huge graphs and a tenant of many
small ones can both hold their weight share). ``snapshot()`` exports the
whole thing as plain dicts for logs, benches and the launcher.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, Optional

import numpy as np

__all__ = ["StreamingHistogram", "TenantTelemetry"]


class StreamingHistogram:
    """Fixed-memory latency histogram with bounded relative quantile error.

    Bucket edges grow geometrically by ``1 + 2 * rel_error`` between ``low``
    and ``high`` (values clamp into the end buckets), so any quantile read
    back by linear interpolation inside its bucket is within ``rel_error``
    of the true sample quantile — verified against the numpy percentile
    oracle in ``tests/test_telemetry.py``. Exact min/max/sum/count ride
    along, and quantiles clamp into [min, max] so the extremes are exact.
    """

    def __init__(
        self,
        low: float = 1e-3,
        high: float = 1e6,
        rel_error: float = 0.025,
    ):
        if not (0 < low < high):
            raise ValueError("need 0 < low < high")
        if not (0 < rel_error < 1):
            raise ValueError("rel_error must be in (0, 1)")
        self.low = float(low)
        self.high = float(high)
        self.rel_error = float(rel_error)
        growth = 1.0 + 2.0 * rel_error
        n = int(math.ceil(math.log(high / low) / math.log(growth)))
        # edges[0]=low … edges[n]=high; bucket i covers [edges[i], edges[i+1])
        # plus one underflow bucket below low and one overflow above high.
        self._edges = low * np.power(growth, np.arange(n + 1))
        self._edges[-1] = high
        self._counts = np.zeros(n + 2, np.int64)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, value: float) -> None:
        v = float(value)
        if math.isnan(v):
            raise ValueError("cannot record NaN")
        # searchsorted over the interior edges; 0 is the underflow bucket.
        self._counts[int(np.searchsorted(self._edges, v, side="right"))] += 1
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """The q-th percentile (q in [0, 100]), linearly interpolated.

        Matches ``np.percentile(samples, q, method="lower")``-style rank
        selection to within the histogram's relative error; returns 0.0
        when empty.
        """
        if not 0 <= q <= 100:
            raise ValueError("q must be in [0, 100]")
        if self.count == 0:
            return 0.0
        if q == 0:
            return self.min  # extremes are tracked exactly
        if q == 100:
            return self.max
        rank = q / 100.0 * (self.count - 1)
        target = math.floor(rank) + 1  # 1-based count of samples <= answer
        cum = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if cum + c >= target:
                # interpolate inside the bucket by rank position
                lo = self._edges[i - 1] if 0 < i <= len(self._edges) else self.min
                hi = (
                    self._edges[i]
                    if i < len(self._edges)
                    else self.max
                )
                frac = (target - cum) / c
                v = lo + (hi - lo) * frac
                return min(max(v, self.min), self.max)
            cum += c
        return self.max

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


@dataclasses.dataclass
class _TenantStats:
    """One tenant's rollup (histograms + counters); see TenantTelemetry."""

    latency: StreamingHistogram
    queue_wait: StreamingHistogram
    submitted: int = 0
    rejected: int = 0  # rate-limit rejections at the admission door
    preempted: int = 0  # staged-window evictions by a higher priority class
    completed: int = 0
    failed: int = 0  # windows that exhausted their retries
    slo_hits: int = 0
    slo_violations: int = 0
    completed_nodes: int = 0
    first_event: float = 0.0  # perf_counter time of the first admission
    last_completion: float = 0.0


class TenantTelemetry:
    """Per-tenant serving telemetry the ``TenantRouter`` feeds.

    All record_* methods create the tenant's rollup on first touch, so the
    telemetry layer never needs the registry — it observes whatever tenant
    names flow through the router.
    """

    def __init__(self, rel_error: float = 0.025):
        from repro.observe import metrics as ometrics

        self.rel_error = rel_error
        self._tenants: Dict[str, _TenantStats] = {}
        # Each tenant's histograms are *adopted* by the process-wide metrics
        # registry (one shared object, no second copy), so the Prometheus
        # dump carries per-tenant latency quantiles without the router doing
        # anything. The instance label keeps concurrent telemetry objects
        # (common in tests) from aliasing each other's tenants.
        self._registry = ometrics.get_registry()
        self.instance = ometrics.next_instance("tenant_telemetry")

    def _get(self, tenant: str) -> _TenantStats:
        ts = self._tenants.get(tenant)
        if ts is None:
            ts = _TenantStats(
                latency=StreamingHistogram(rel_error=self.rel_error),
                queue_wait=StreamingHistogram(rel_error=self.rel_error),
            )
            self._tenants[tenant] = ts
            self._registry.register_histogram(
                "tenant_latency_ms", ts.latency,
                help="end-to-end latency per tenant",
                tenant=tenant, telemetry=self.instance,
            )
            self._registry.register_histogram(
                "tenant_queue_wait_ms", ts.queue_wait,
                help="admission->execution wait per tenant",
                tenant=tenant, telemetry=self.instance,
            )
        return ts

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._tenants

    # ------------------------------------------------------------- recording
    def record_submitted(self, tenant: str, *, now: Optional[float] = None) -> None:
        ts = self._get(tenant)
        ts.submitted += 1
        if ts.first_event == 0.0:
            # perf_counter: the serving stack's one lifecycle clock (see
            # serve.gnn_engine.request_stamp) — router-passed `now` stamps
            # and the default must come from the same clock.
            ts.first_event = time.perf_counter() if now is None else now

    def record_rejected(self, tenant: str) -> None:
        self._get(tenant).rejected += 1

    def record_preempted(self, tenant: str) -> None:
        self._get(tenant).preempted += 1

    def record_failure(self, tenant: str) -> None:
        self._get(tenant).failed += 1

    def record_completion(
        self,
        tenant: str,
        *,
        latency_ms: float,
        queue_ms: float = 0.0,
        nodes: int = 0,
        slo_ms: float = 0.0,
        now: Optional[float] = None,
    ) -> bool:
        """Record one served request; returns True iff it met its SLO
        (vacuously True when the tenant has no SLO target)."""
        ts = self._get(tenant)
        ts.latency.record(latency_ms)
        ts.queue_wait.record(queue_ms)
        ts.completed += 1
        ts.completed_nodes += nodes
        ts.last_completion = time.perf_counter() if now is None else now
        ok = slo_ms <= 0 or latency_ms <= slo_ms
        if slo_ms > 0:
            if ok:
                ts.slo_hits += 1
            else:
                ts.slo_violations += 1
        return ok

    # -------------------------------------------------------------- export
    def tenant_snapshot(
        self, tenant: str, *, queue_depth: int = 0
    ) -> Dict[str, object]:
        ts = self._get(tenant)
        elapsed = max(ts.last_completion - ts.first_event, 0.0)
        slo_total = ts.slo_hits + ts.slo_violations
        return {
            "submitted": ts.submitted,
            "completed": ts.completed,
            "rejected": ts.rejected,
            "preempted": ts.preempted,
            "failed": ts.failed,
            "queue_depth": queue_depth,
            "latency_ms": ts.latency.snapshot(),
            "queue_wait_ms": ts.queue_wait.snapshot(),
            "slo_hits": ts.slo_hits,
            "slo_violations": ts.slo_violations,
            "slo_hit_rate": (ts.slo_hits / slo_total) if slo_total else 1.0,
            "throughput_rps": (ts.completed / elapsed) if elapsed > 0 else 0.0,
            "node_throughput": (
                ts.completed_nodes / elapsed if elapsed > 0 else 0.0
            ),
            "completed_nodes": ts.completed_nodes,
        }

    def snapshot(
        self, queue_depths: Optional[Dict[str, int]] = None
    ) -> Dict[str, Dict[str, object]]:
        """Per-tenant rollups as plain dicts (p50/p90/p99, counters, rates).

        ``queue_depths`` lets the router stamp its live per-tenant queue
        depth into the export; tenants present there but never recorded
        still appear (all-zero), so an idle tenant is visible, not absent.
        """
        depths = queue_depths or {}
        for t in depths:
            self._get(t)
        return {
            t: self.tenant_snapshot(t, queue_depth=depths.get(t, 0))
            for t in sorted(self._tenants)
        }
