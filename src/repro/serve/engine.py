"""Serving engine: batched prefill + greedy decode over the KV-cache stack.

The event-driven idea shows up here as **continuous batching metadata**: each
sequence in the batch carries its own length; finished sequences are masked
(their slot is reusable by the caller — the LM analogue of nodeslot
recycling). Prefill is one forward pass that also writes every layer's cache
(models/lm/transformer.prefill); decode is one token per step for the whole
batch.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.api import model_decode_step, model_prefill

__all__ = ["ServeEngine"]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int, policy=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.policy = policy
        self._decode = jax.jit(self._decode_impl)

    def _decode_impl(self, params, tok, cache, cache_len):
        kw = {} if self.policy is None else {"policy": self.policy}
        logits, cache = model_decode_step(
            params, self.cfg, {"tokens": tok}, cache, cache_len, **kw
        )
        return jnp.argmax(logits[..., : self.cfg.vocab_size], -1).astype(jnp.int32), cache

    def generate(
        self,
        prompts: jnp.ndarray,  # int32[B, P]
        *,
        max_new_tokens: int,
        eos_id: Optional[int] = None,
    ) -> jnp.ndarray:
        b, p = prompts.shape
        assert p + max_new_tokens <= self.max_len, "max_len too small"
        kw = {} if self.policy is None else {"policy": self.policy}
        logits, cache, cache_len = model_prefill(
            self.params, self.cfg, {"tokens": prompts}, self.max_len, **kw
        )
        next_tok = jnp.argmax(
            logits[:, -1, : self.cfg.vocab_size], -1
        ).astype(jnp.int32)
        out = [prompts]
        done = jnp.zeros((b,), bool)
        for _ in range(max_new_tokens):
            out.append(next_tok[:, None])
            if eos_id is not None:
                done = done | (next_tok == eos_id)
                if bool(done.all()):
                    break
            tok, cache = self._decode(self.params, next_tok[:, None], cache, cache_len)
            cache_len = cache_len + 1
            next_tok = jnp.where(done, next_tok, tok)
        return jnp.concatenate(out, axis=1)
