"""Multi-tenant serving front: DWRR admission over per-tenant queues.

``AsyncGNNEngine`` gave the serving stack continuous batching, but its
admission is one strict-FIFO queue — every caller is the same caller, so a
batch backfill flooding the queue adds its whole backlog to an interactive
request's latency. ``TenantRouter`` is the front door that fixes that,
modeled on the engine/scheduler split of LLM serving engines:

  * **per-tenant queues** — ``submit(tenant, graph, features)`` goes through
    the tenant's token bucket (admission control: over-rate requests are
    rejected at the door, never queued) into that tenant's own FIFO queue;
  * **deficit-weighted round robin** — each micro-batch window is filled by
    DWRR over the backlogged tenants: every service round grants each tenant
    ``quantum x weight`` node-credits, and a tenant admits queue-head
    requests while its credit covers their node cost. Under contention every
    tenant's admitted node-volume converges to its weight share — a flood of
    small graphs and a trickle of huge ones are both held to the same
    currency (nodes, the unit of engine work);
  * **priority classes** — higher classes fill first within every round
    (latency ordering, at equal long-run weight share: credits, not class,
    bound each tenant's volume — so a saturating high class cannot starve
    best-effort, it can only get ahead of it in line), and a high-class
    arrival that finds the staged window full may **preempt** strictly
    lower-class members back to their queue heads before the window runs;
  * **telemetry** — every completion lands in ``serve.telemetry``: per-tenant
    streaming p50/p99 end-to-end latency and queue-wait histograms, queue
    depth, throughput (requests/s and nodes/s), and admission / rejection /
    preemption / failure counters.

Routing changes *when* a request executes and *who* shares its window —
never the numbers: an executed window flows through the same
``AsyncGNNEngine.step`` -> ``GNNServeEngine.infer_batch`` path as direct
serving, so routed outputs are bitwise-identical to driving the engine
directly with the same window compositions (``window_log`` records them).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.graphs.csr import Graph
from repro.observe import metrics as ometrics
from repro.observe import trace as otrace
from repro.serve.async_gnn import AsyncGNNEngine, GNNTicket
from repro.serve.gnn_engine import GNNResponse, GNNServeEngine, request_stamp
from repro.serve.telemetry import TenantTelemetry
from repro.serve.tenancy.registry import TenantRegistry, TenantSpec, TokenBucket

__all__ = ["RateLimitExceeded", "RoutedTicket", "TenantRouter"]


class RateLimitExceeded(RuntimeError):
    """A tenant's token bucket is empty: the request was rejected, not queued."""

    def __init__(self, tenant: str):
        super().__init__(
            f"tenant {tenant!r} is over its rate limit; request rejected at "
            "admission"
        )
        self.tenant = tenant


@dataclasses.dataclass
class RoutedTicket:
    """One routed request's handle: queued -> staged -> executing -> done."""

    seq: int  # router-wide admission order
    tenant: str
    graph: Graph
    features: object  # validated f32[N, D]
    arch: str
    arrival: float  # request_stamp() at router admission
    preemptions: int = 0  # times bumped out of a staged window by a higher class
    trace_id: str = ""  # per-request correlation id (observe.trace)
    _router: Optional["TenantRouter"] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _ticket: Optional[GNNTicket] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # engine-side ticket, set when the window is handed to the engine

    @property
    def done(self) -> bool:
        return self._ticket is not None and self._ticket.done

    @property
    def response(self) -> Optional[GNNResponse]:
        return self._ticket.response if self._ticket is not None else None

    @property
    def error(self) -> Optional[BaseException]:
        return self._ticket.error if self._ticket is not None else None

    def result(self, timeout: Optional[float] = None) -> GNNResponse:
        """The response; drives the router's loop until this completes.

        Mirrors ``GNNTicket.result``: a held partial window is waited out
        (bounded by its ``hold_ms`` deadline) and re-stepped; ``timeout``
        bounds the total wait; a ticket whose window exhausted execution
        retries re-raises the attached error.
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        while not self.done:
            if self._router is None:
                raise RuntimeError(
                    f"routed ticket {self.seq} is pending but has no router"
                )
            if self._router.step():
                continue
            if self.done:
                break
            wait = self._router._hold_wait()
            if wait is None:
                raise RuntimeError(
                    f"routed ticket {self.seq} is pending but its router has "
                    "no admissible work"
                )
            if deadline is not None:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    raise TimeoutError(
                        f"routed ticket {self.seq} still pending after "
                        f"{timeout}s"
                    )
                wait = min(wait, remaining)
            if wait > 0:
                time.sleep(wait)
        if self.error is not None:
            raise self.error
        return self.response


class TenantRouter:
    """DWRR admission front over an ``AsyncGNNEngine``.

    Parameters
    ----------
    engine: an ``AsyncGNNEngine``, or anything its constructor accepts (a
        ``GNNServeEngine`` or a ``family="gnn"`` ModelConfig, with
        ``params``/``engine_kwargs``/``window``/``max_batch_nodes``
        forwarded). The router owns the engine's queue: submit requests
        through the router only.
    registry: the ``TenantRegistry``; defaults to a fresh one (populate with
        ``add_tenant``). Submitting under an unregistered name raises.
    hold_ms: router-level latency-aware window close, the analogue of the
        engine's ``window_timeout_ms`` (which the router bypasses — it
        always flushes exactly the window it composed): a *partial* staged
        window is held open for late arrivals until its oldest member has
        waited this long. 0 executes whatever is staged on every step.
    quantum_nodes: DWRR credit granted per service round is
        ``quantum_nodes x weight``. 0 (default) adapts the quantum each
        round to the largest backlogged queue-head cost, the classic choice
        that guarantees at least one admission per round for every tenant
        whose turn comes with credit banked.
    telemetry: a ``TenantTelemetry`` to record into (default: fresh).
    window_log_size: how many executed window compositions to keep in
        ``window_log`` (each entry is a tuple of (tenant, seq) pairs) — the
        replay record for bitwise parity checks against direct serving.
    """

    def __init__(
        self,
        engine,
        params=None,
        *,
        registry: Optional[TenantRegistry] = None,
        window: Optional[int] = None,
        max_batch_nodes: Optional[int] = None,
        hold_ms: float = 0.0,
        quantum_nodes: int = 0,
        telemetry: Optional[TenantTelemetry] = None,
        window_log_size: int = 256,
        **engine_kwargs,
    ):
        if isinstance(engine, AsyncGNNEngine):
            if params is not None or engine_kwargs:
                raise ValueError(
                    "pass params/engine kwargs only when constructing from a "
                    "config, not when wrapping an existing AsyncGNNEngine"
                )
            if window is not None or max_batch_nodes is not None:
                raise ValueError(
                    "window/max_batch_nodes come from the wrapped engine"
                )
            self.engine = engine
        else:
            # The router owns window composition; the engine must admit each
            # staged window in one flushed step, so its own hold is disabled.
            self.engine = AsyncGNNEngine(
                engine,
                params,
                window=window,
                max_batch_nodes=max_batch_nodes,
                window_timeout_ms=0.0,
                **engine_kwargs,
            )
        if hold_ms < 0:
            raise ValueError("hold_ms must be >= 0")
        if quantum_nodes < 0:
            raise ValueError("quantum_nodes must be >= 0")
        self.window = self.engine.window
        self.max_batch_nodes = self.engine.max_batch_nodes
        self.hold_ms = float(hold_ms)
        self.quantum_nodes = int(quantum_nodes)
        self.registry = registry if registry is not None else TenantRegistry()
        self.telemetry = telemetry if telemetry is not None else TenantTelemetry()
        self._queues: Dict[str, Deque[RoutedTicket]] = {}
        self._deficit: Dict[str, float] = {}
        self._buckets: Dict[str, TokenBucket] = {}
        self._rr: Dict[int, int] = {}  # per-priority-class rotation offset
        self._staged: List[RoutedTicket] = []
        self._staged_nodes = 0
        self._inflight: List[RoutedTicket] = []  # handed to the engine
        self._held_head: Optional[int] = None
        self._seq = 0
        self.window_log: Deque[Tuple[Tuple[str, int], ...]] = deque(
            maxlen=window_log_size
        )
        # Registry-backed counters behind the historical dict API; see
        # GNNServeEngine.stats for the rationale.
        self.instance = ometrics.next_instance("gnn_router")
        self.stats: ometrics.StatsView = ometrics.StatsView(
            ometrics.get_registry(),
            "gnn_router",
            {"router": self.instance},
            keys=(
                "submitted",
                "completed",
                "rejected",  # token-bucket rejections at the door
                "preempted",  # staged members bumped by a higher class
                "windows",  # executed window count
                "held_windows",
                "deadline_closes",
                "failed",  # tickets whose window exhausted execution retries
            ),
        )

    # --------------------------------------------------------------- tenants
    def add_tenant(self, name: str, **kwargs) -> TenantSpec:
        """Register a tenant (convenience passthrough to the registry)."""
        return self.registry.add(name, **kwargs)

    def _queue(self, tenant: str) -> Deque[RoutedTicket]:
        q = self._queues.get(tenant)
        if q is None:
            q = self._queues[tenant] = deque()
        return q

    def _bucket(self, spec: TenantSpec) -> TokenBucket:
        b = self._buckets.get(spec.name)
        if b is None:
            b = self._buckets[spec.name] = spec.make_bucket()
        return b

    # ------------------------------------------------------------- admission
    def submit(
        self, tenant: str, graph: Graph, features, *, arch: str = ""
    ) -> RoutedTicket:
        """Admit one request under a tenant; returns its ticket immediately.

        Admission control happens at the door: an unknown tenant or invalid
        request raises, an over-rate one raises ``RateLimitExceeded`` (and
        is counted as rejected — rejected requests consume no queue space
        and no engine work). A high-priority admission may preempt
        strictly-lower-class members out of a full staged window.
        """
        spec = self.registry.get(tenant)
        rec = otrace.get_recorder()
        if not self._bucket(spec).try_acquire():
            self.stats["rejected"] += 1
            self.telemetry.record_rejected(tenant)
            if rec.enabled:
                rec.add_instant("reject", cat="tenancy",
                                args={"tenant": tenant})
            raise RateLimitExceeded(tenant)
        serve_engine = self.engine.engine
        arch = serve_engine._arch(arch)
        features = serve_engine._validate_request(graph, features)
        trace_id = otrace.new_trace_id() if rec.enabled else ""
        ticket = RoutedTicket(
            seq=self._seq,
            tenant=tenant,
            graph=graph,
            features=features,
            arch=arch,
            arrival=request_stamp(),
            trace_id=trace_id,
            _router=self,
        )
        if rec.enabled:
            rec.add_instant(
                "admit", t=ticket.arrival, cat="tenancy", trace_id=trace_id,
                args={"tenant": tenant, "seq": ticket.seq,
                      "nodes": graph.num_nodes},
            )
        self._seq += 1
        self._queue(tenant).append(ticket)
        self.stats["submitted"] += 1
        self.telemetry.record_submitted(tenant, now=ticket.arrival)
        self._maybe_preempt(spec)
        return ticket

    @property
    def pending(self) -> int:
        queued = sum(len(q) for q in self._queues.values())
        return queued + len(self._staged) + len(self._inflight)

    def queue_depths(self) -> Dict[str, int]:
        """Live queued+staged depth per tenant (executing windows excluded)."""
        depths = {t: len(q) for t, q in self._queues.items()}
        for rt in self._staged:
            depths[rt.tenant] = depths.get(rt.tenant, 0) + 1
        return depths

    # ------------------------------------------------------------ preemption
    def _room_for(self, nodes: int, *, exclude: Sequence[RoutedTicket] = ()) -> bool:
        """Would the staged window (minus ``exclude``) admit one more request
        of this node cost, under the same rules as engine admission (an
        oversized request riding an otherwise empty window is admitted)?"""
        slots = len(self._staged) - len(exclude)
        if slots >= self.window:
            return False
        if slots == 0 or self.max_batch_nodes is None:
            return True
        staged_nodes = self._staged_nodes - sum(
            rt.graph.num_nodes for rt in exclude
        )
        return staged_nodes + nodes <= self.max_batch_nodes

    def _maybe_preempt(self, spec: TenantSpec) -> None:
        """Bump strictly-lower-class members out of a full staged window.

        Only a *staged* (held, not yet executing) window is preemptible —
        an executing window is never interrupted. Victims leave largest
        first within the lowest class, go back to their own queue heads in
        original order, and keep their arrival stamps (their queue wait
        honestly includes the preemption). No room even after evicting
        every lower-class member means no preemption happens at all.
        """
        if not self._staged:
            return
        q = self._queues.get(spec.name)
        if not q:
            return
        head = q[0]
        n = head.graph.num_nodes
        if self._room_for(n):
            return  # the next fill tops the held window up; nothing to bump
        victims = [
            rt
            for rt in self._staged
            if self.registry.get(rt.tenant).priority < spec.priority
        ]
        if not victims:
            return
        victims.sort(
            key=lambda rt: (
                self.registry.get(rt.tenant).priority,
                -rt.graph.num_nodes,
            )
        )
        evicted: List[RoutedTicket] = []
        for v in victims:
            if self._room_for(n, exclude=evicted):
                break
            evicted.append(v)
        if not self._room_for(n, exclude=evicted):
            return  # even a clean sweep of lower classes can't make room
        # Requeue evicted members at their queue heads, preserving their
        # original staged order (reverse iteration + appendleft).
        rec = otrace.get_recorder()
        for v in sorted(evicted, key=lambda rt: self._staged.index(rt), reverse=True):
            self._staged.remove(v)
            self._staged_nodes -= v.graph.num_nodes
            v.preemptions += 1
            self._queues[v.tenant].appendleft(v)
            self.stats["preempted"] += 1
            self.telemetry.record_preempted(v.tenant)
            if rec.enabled:
                rec.add_instant(
                    "preempt", cat="tenancy", trace_id=v.trace_id,
                    args={"tenant": v.tenant, "by": spec.name},
                )
        q.popleft()
        self._staged.append(head)
        self._staged_nodes += n

    # ------------------------------------------------------- DWRR window fill
    def _backlogged(self) -> List[str]:
        return [t for t, q in self._queues.items() if q]

    def _fill_staged(self) -> None:
        """Fill the staged window by deficit-weighted round robin.

        Every round: each backlogged tenant — higher priority classes first,
        rotating the start position within a class — banks ``quantum x
        weight`` node-credits (clamped so idle banking can't turn into an
        unbounded burst: at most its queue-head cost plus one round's
        grant), then admits queue-head requests while the credit covers
        their cost and the window has room. Deficits persist while a tenant
        stays backlogged (an oversized head accumulates credit across
        rounds and windows until it fits) and reset when its queue empties.
        A round with no admissions closes the window — unless it is still
        empty, in which case the highest-priority, largest-credit head is
        force-admitted (charging its full cost, going into debt that later
        rounds repay) so an oversized straggler rides alone rather than
        stalling the queue.
        """
        while len(self._staged) < self.window:
            backlogged = self._backlogged()
            if not backlogged:
                break
            quantum = self.quantum_nodes or max(
                self._queues[t][0].graph.num_nodes for t in backlogged
            )
            progressed = False
            by_prio: Dict[int, List[str]] = {}
            for t in backlogged:
                by_prio.setdefault(self.registry.get(t).priority, []).append(t)
            for prio in sorted(by_prio, reverse=True):
                tenants = sorted(by_prio[prio])
                off = self._rr.get(prio, 0)
                self._rr[prio] = off + 1
                for i in range(len(tenants)):
                    t = tenants[(off + i) % len(tenants)]
                    q = self._queues[t]
                    if not q:
                        continue
                    w = self.registry.get(t).weight
                    grant = quantum * w
                    head_cost = q[0].graph.num_nodes
                    self._deficit[t] = min(
                        self._deficit.get(t, 0.0) + grant, head_cost + grant
                    )
                    while (
                        q
                        and len(self._staged) < self.window
                        and q[0].graph.num_nodes <= self._deficit[t]
                        and self._room_for(q[0].graph.num_nodes)
                    ):
                        rt = q.popleft()
                        self._staged.append(rt)
                        self._staged_nodes += rt.graph.num_nodes
                        self._deficit[t] -= rt.graph.num_nodes
                        progressed = True
                    if not q:
                        self._deficit[t] = 0.0  # no banking while idle
                    if len(self._staged) >= self.window:
                        break
                if len(self._staged) >= self.window:
                    break
            if not progressed:
                if self._staged:
                    break  # budget/credit closed a non-empty window
                # Empty window, backlog present: force the best head through
                # (highest class, then largest banked credit) so an
                # oversized straggler rides alone instead of wedging.
                t = max(
                    self._backlogged(),
                    key=lambda t: (
                        self.registry.get(t).priority,
                        self._deficit.get(t, 0.0),
                        -self._queues[t][0].seq,
                    ),
                )
                rt = self._queues[t].popleft()
                self._staged.append(rt)
                self._staged_nodes += rt.graph.num_nodes
                self._deficit[t] = self._deficit.get(t, 0.0) - rt.graph.num_nodes
                if not self._queues[t]:
                    self._deficit[t] = 0.0

    # ------------------------------------------------------------ event loop
    def _budget_full(self) -> bool:
        return (
            self.max_batch_nodes is not None
            and self._staged_nodes >= self.max_batch_nodes
        )

    def _hold_wait(self) -> Optional[float]:
        """Seconds until the staged window's hold deadline; None when no
        hold applies (no hold configured, nothing staged or queued)."""
        if self.hold_ms <= 0:
            return None
        oldest = None
        if self._staged:
            oldest = min(rt.arrival for rt in self._staged)
        else:
            heads = [q[0].arrival for q in self._queues.values() if q]
            if heads:
                oldest = min(heads)
        if oldest is None:
            return None
        return max(self.hold_ms / 1e3 - (request_stamp() - oldest), 0.0)

    def step(self, *, flush: bool = False) -> List[RoutedTicket]:
        """One router tick: fill a window by DWRR, execute it, complete it.

        Returns the completed routed tickets (empty when idle or when a
        partial window is held for its ``hold_ms`` deadline; ``flush=True``
        executes regardless). A window that failed execution below the
        engine's retry bound stays in flight — the error propagates, and the
        next step retries it before composing anything new.
        """
        rec = otrace.get_recorder()
        if self._inflight:
            return self._run_engine()  # retry the failed window first
        fill_t0 = time.perf_counter()
        self._fill_staged()
        if rec.enabled and self._staged:
            rec.add_span(
                "dwrr_fill", fill_t0, time.perf_counter(), cat="tenancy",
                trace_id=self._staged[0].trace_id,
                args={"staged": len(self._staged),
                      "nodes": self._staged_nodes},
            )
        if not self._staged:
            return []
        partial = (
            len(self._staged) < self.window
            and not self._backlogged()
            and not self._budget_full()
        )
        if partial and not flush and self.hold_ms > 0:
            oldest = min(rt.arrival for rt in self._staged)
            if (request_stamp() - oldest) * 1e3 < self.hold_ms:
                if self._held_head != self._staged[0].seq:
                    self._held_head = self._staged[0].seq
                    self.stats["held_windows"] += 1
                    if rec.enabled:
                        rec.add_instant(
                            "window_hold", cat="tenancy",
                            trace_id=self._staged[0].trace_id,
                            args={"head_seq": self._staged[0].seq,
                                  "size": len(self._staged)},
                        )
                return []
            self.stats["deadline_closes"] += 1
            if rec.enabled:
                t1 = request_stamp()
                rec.add_span(
                    "window_hold", oldest, t1, cat="tenancy",
                    trace_id=self._staged[0].trace_id,
                    args={"head_seq": self._staged[0].seq,
                          "deadline_close": True},
                )
        staged, self._staged, self._staged_nodes = self._staged, [], 0
        self.window_log.append(tuple((rt.tenant, rt.seq) for rt in staged))
        for rt in staged:
            rt._ticket = self.engine.submit(
                rt.graph, rt.features, arch=rt.arch, arrival=rt.arrival,
                trace_id=rt.trace_id,
            )
        self._inflight = staged
        return self._run_engine()

    def _run_engine(self) -> List[RoutedTicket]:
        """Drive the engine through the in-flight window; complete tickets.

        Transient execution failures (below the engine's retry bound)
        propagate after the engine requeued the window internally — the
        tickets stay in flight and the next call retries them. Tickets the
        engine failed permanently complete exceptionally here.
        """
        self.engine.step(flush=True)  # raises on transient failure
        done: List[RoutedTicket] = []
        still: List[RoutedTicket] = []
        for rt in self._inflight:
            (done if rt.done else still).append(rt)
        self._inflight = still
        if done and not still:
            self.stats["windows"] += 1
        for rt in done:
            self._on_complete(rt)
        return done

    def _on_complete(self, rt: RoutedTicket) -> None:
        spec = self.registry.get(rt.tenant)
        if rt.error is not None:
            self.stats["failed"] += 1
            self.telemetry.record_failure(rt.tenant)
            return
        resp = rt.response
        latency_ms = (request_stamp() - rt.arrival) * 1e3
        self.stats["completed"] += 1
        self.telemetry.record_completion(
            rt.tenant,
            latency_ms=latency_ms,
            queue_ms=resp.queue_ms,
            nodes=rt.graph.num_nodes,
            slo_ms=spec.slo_ms,
        )

    def drain(self) -> List[RoutedTicket]:
        """Run the loop until nothing is queued, staged or in flight;
        tickets back in router admission order. Flushes held windows."""
        done: List[RoutedTicket] = []
        while self.pending:
            done.extend(self.step(flush=True))
        return sorted(done, key=lambda rt: rt.seq)

    def serve(
        self, requests: Sequence[Tuple[str, Graph, object]]
    ) -> List[RoutedTicket]:
        """Submit a (tenant, graph, features) stream and drain it — the
        offered-load entry point. Rate-limited submissions raise; catch
        ``RateLimitExceeded`` upstream to shed load instead."""
        for tenant, graph, features in requests:
            self.submit(tenant, graph, features)
        return self.drain()

    # -------------------------------------------------------------- metrics
    def snapshot(self) -> Dict[str, object]:
        """Router counters + per-tenant telemetry + engine cache economics."""
        return {
            **self.stats,
            "pending": self.pending,
            "tenants": self.telemetry.snapshot(self.queue_depths()),
            "engine": self.engine.cache_info(),
        }
