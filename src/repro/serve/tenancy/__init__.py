"""Multi-tenant serving front: registry, DWRR router, rate limits, SLOs.

Public surface::

    from repro.serve.tenancy import TenantRouter, TenantRegistry, TenantSpec

    router = TenantRouter(cfg, params, hold_ms=2.0)
    router.add_tenant("gold", weight=4.0, priority=1, slo_ms=50.0)
    router.add_tenant("batch", weight=1.0, rate_rps=100.0)
    ticket = router.submit("gold", graph, features)
    response = ticket.result(timeout=5.0)
"""
from repro.serve.tenancy.registry import (
    TenantRegistry,
    TenantSpec,
    TokenBucket,
    UnknownTenant,
)
from repro.serve.tenancy.router import (
    RateLimitExceeded,
    RoutedTicket,
    TenantRouter,
)

__all__ = [
    "RateLimitExceeded",
    "RoutedTicket",
    "TenantRegistry",
    "TenantRouter",
    "TenantSpec",
    "TokenBucket",
    "UnknownTenant",
]
