"""Tenant registry: who may submit, how fast, at what priority, to what SLO.

A **tenant** is a traffic class with an identity: an interactive product
surface, a batch backfill job, a free-tier API key. The registry holds one
``TenantSpec`` per tenant — DWRR weight (capacity share under contention),
priority class (who goes first when both are backlogged, and who may preempt
whom out of a staged window), a token-bucket rate limit (admission control at
the door), and an SLO target the telemetry scores end-to-end latency against.

Specs are frozen; runtime state (token buckets, deficit counters, queues)
lives in the router so one registry can front many routers.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, Iterator, Optional

__all__ = ["TokenBucket", "TenantSpec", "TenantRegistry", "UnknownTenant"]


class UnknownTenant(KeyError):
    """Raised when a request names a tenant the registry has never seen."""


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` capacity.

    ``try_acquire`` is O(1) and lazy — tokens accrue on read, no timer
    thread. A zero rate disables limiting (always admits). ``now`` is
    injectable for deterministic tests.
    """

    def __init__(self, rate: float, burst: float):
        if rate < 0 or burst < 0:
            raise ValueError("rate and burst must be >= 0")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        # Clock origin is set by the first acquire, so an injected test
        # clock is fully deterministic (never mixed with time.monotonic()).
        self._last: Optional[float] = None

    def _refill(self, now: float) -> None:
        if self._last is None:
            self._last = now
        elif now > self._last:
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate
            )
            self._last = now

    def try_acquire(self, now: Optional[float] = None) -> bool:
        if self.rate <= 0:
            return True
        t = time.monotonic() if now is None else now
        self._refill(t)
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False

    @property
    def tokens(self) -> float:
        return self._tokens if self.rate > 0 else math.inf


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's contract with the serving front.

    weight: DWRR share under contention; a weight-4 tenant is granted 4x the
        admitted node-volume of a weight-1 tenant while both are backlogged.
    priority: class ordering. Higher classes are admitted first within a
        window and may preempt strictly-lower-class members back out of a
        staged (held, not yet executed) window. Equal-priority tenants never
        preempt each other — fairness between them is DWRR's job.
    rate_rps: token-bucket admission limit in requests/s (0 = unlimited);
        ``burst`` is the bucket depth (0 derives ceil(rate), min 1).
    slo_ms: end-to-end latency target the telemetry scores completions
        against (0 = no SLO; nothing is enforced either way — the SLO is an
        observability contract, the scheduler's knobs are weight/priority).
    """

    name: str
    weight: float = 1.0
    priority: int = 0
    rate_rps: float = 0.0
    burst: float = 0.0
    slo_ms: float = 0.0

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if self.rate_rps < 0 or self.burst < 0 or self.slo_ms < 0:
            raise ValueError(
                f"tenant {self.name!r}: rate_rps/burst/slo_ms must be >= 0"
            )

    @property
    def effective_burst(self) -> float:
        """Bucket depth: explicit, else ceil(rate) (min 1 so rps<1 admits)."""
        if self.burst > 0:
            return self.burst
        return max(math.ceil(self.rate_rps), 1.0)

    def make_bucket(self) -> TokenBucket:
        return TokenBucket(self.rate_rps, self.effective_burst)


class TenantRegistry:
    """Name -> TenantSpec mapping with a convenience ``add`` constructor."""

    def __init__(self, *specs: TenantSpec):
        self._specs: Dict[str, TenantSpec] = {}
        for s in specs:
            self.register(s)

    def register(self, spec: TenantSpec) -> TenantSpec:
        if spec.name in self._specs:
            raise ValueError(f"tenant {spec.name!r} is already registered")
        self._specs[spec.name] = spec
        return spec

    def add(self, name: str, **kwargs) -> TenantSpec:
        return self.register(TenantSpec(name=name, **kwargs))

    def get(self, name: str) -> TenantSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise UnknownTenant(
                f"unknown tenant {name!r}; registered: {sorted(self._specs)}"
            ) from None

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self) -> Iterator[TenantSpec]:
        return iter(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)

    @property
    def names(self):
        return tuple(self._specs)
