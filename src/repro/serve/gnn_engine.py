"""Plan-cached GNN serving engine — the GNN analogue of the token ServeEngine.

AMPLE's host programs a graph into nodeslots once and then streams inference;
the expensive part of serving a GNN request on this stack is likewise the
host-side planner (Degree-Quant tagging + edge-tile packing), not the device
call. ``GNNServeEngine`` therefore treats the compiled ``ExecutionPlan`` as
the cacheable artifact:

  * requests are ``(graph, features)``; the engine keys an LRU cache on the
    graph's **structure fingerprint** + engine config + arch, so repeat
    traffic on the same graph skips plan compilation entirely — the serving
    analogue of nodeslot recycling;
  * independent small-graph requests are batched by ``infer_batch`` into one
    disjoint-union graph and served in a single padded device call (the
    union's plan is itself cached under the union fingerprint, so a repeated
    batch mix is also a cache hit);
  * cached plans are bitwise-faithful: a warm request returns exactly the
    output a cold engine would produce for the same graph and features.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.degree_quant import inference_precision_tags
from repro.core.message_passing import (
    AmpleEngine,
    EngineConfig,
    ExecutionPlan,
    ShardPlan,
    ShardedExecutionPlan,
    assemble_union_plan,
    compile_plans,
    compile_shard_plan,
    compile_sharded_plans,
    engine_precision_tags,
    shard_plan_key,
)
from repro.core.scheduler import plan_fingerprint, size_class, union_bucket_fingerprint
from repro.distributed.graph_shard import ShardedAmpleEngine
from repro.graphs.csr import Graph, disjoint_union
from repro.graphs.partition import Partition, make_partition, validate_partition
from repro.models.gnn import api as gnn_api
from repro.observe import metrics as ometrics
from repro.observe import trace as otrace

__all__ = ["GNNRequest", "GNNResponse", "GNNServeEngine", "request_stamp"]


def request_stamp() -> float:
    """The serving stack's one lifecycle clock: ``time.perf_counter()``.

    Every admission/arrival stamp (``GNNRequest.admitted_at``,
    ``GNNTicket.arrival``, ``RoutedTicket.arrival``) and every duration
    (``plan_ms``/``run_ms``/``stall_ms``/``copy_ms``) must come from this
    clock. Mixing clocks (the old code stamped lifecycle points with
    ``time.monotonic()``) silently breaks queue-wait arithmetic on
    platforms where the two clocks differ, and splits the trace into two
    irreconcilable timelines. Routed (tenancy) and direct async requests
    both stamp through here, at admission — the parity the satellite tests
    pin down.
    """
    return time.perf_counter()


@dataclasses.dataclass(frozen=True)
class GNNRequest:
    """One inference request: a graph, its node features, optional arch."""

    graph: Graph
    features: np.ndarray  # f32[N, D]
    arch: str = ""  # "" -> the engine config's arch
    admitted_at: float = 0.0  # time.perf_counter() at admission; 0 = unqueued.
    # Set by queueing fronts (AsyncGNNEngine.submit, the tenancy router) so
    # the response's queue_ms attributes wait separately from compute. The
    # stamp shares the perf_counter clock with every duration measurement,
    # so admission->execution renders as one span on the trace timeline.
    trace_id: str = ""  # per-request correlation id (observe.trace); ""
    # when tracing is disabled — the engine then skips span recording.


@dataclasses.dataclass(frozen=True)
class GNNResponse:
    outputs: np.ndarray  # f32[N, num_classes]
    cache_hit: bool
    fingerprint: str  # plan-cache key the request resolved to
    plan_ms: float  # host planning time (0.0 on a cache hit)
    run_ms: float  # device execution wall time of the WHOLE batch this
    # request rode in (every member of one union call reports the same
    # number; divide by batch_size — or read run_ms_per_member — for an
    # amortized per-request figure)
    num_shards: int = 1  # shards the plan executed over (1 = unsharded path)
    batch_size: int = 1  # members in the union device call that produced this
    queue_ms: float = 0.0  # admission -> execution-start wait. 0.0 for
    # requests that never queued (direct sync calls without admitted_at);
    # on the async/tenancy paths this is the time the request spent waiting
    # for its micro-batch window, so SLO attribution can separate queueing
    # (scheduler's fault) from plan_ms + run_ms (compute's fault).
    # Out-of-core telemetry (all zero on the in-memory path). Like run_ms,
    # these describe the WHOLE device call: every member of one streamed
    # union batch reports the same bytes_streamed — read
    # bytes_streamed_per_member for an amortized per-request figure.
    streamed: bool = False  # features stayed host-resident, chunk-streamed
    bytes_streamed: int = 0  # feature bytes moved host->device by the call
    chunk_hit_rate: float = 0.0  # chunk-cache hits / accesses
    prefetch_overlap: float = 0.0  # wall-clock copy time hidden behind compute
    stall_ms: float = 0.0  # wall time the stream blocked on feature copies
    copy_ms: float = 0.0  # wall time of the feature copies themselves
    trace_id: str = ""  # correlation id of this request's trace spans ("" =
    # tracing disabled or no id assigned upstream)
    # Halo-exchange telemetry (sharded host-loop path; zero elsewhere). Like
    # run_ms these describe the whole device call this request rode in.
    halo_ms: float = 0.0  # wall time of the fenced halo row fetches
    halo_bytes: int = 0  # feature bytes crossing shard boundaries this call
    halo_overlap: float = 0.0  # fraction of halo fetch time hidden behind
    # interior-tile aggregation (1 - wait/fetch); 0.0 when overlap is off
    # or the engine is unsharded

    @property
    def run_ms_per_member(self) -> float:
        """Amortized device time per batch member (= run_ms when served solo)."""
        return self.run_ms / max(self.batch_size, 1)

    @property
    def bytes_streamed_per_member(self) -> float:
        """Amortized feature traffic per batch member (= bytes_streamed solo)."""
        return self.bytes_streamed / max(self.batch_size, 1)


class GNNServeEngine:
    """Serve ``(graph, features)`` requests with an LRU ``ExecutionPlan`` cache.

    Parameters
    ----------
    cfg: a ``family="gnn"`` ModelConfig (arch, dims, precision policy).
    params: model params; initialised from ``key`` when omitted.
    engine_cfg: EngineConfig override; derived from ``cfg`` by default.
    plan_cache_size: max distinct graph structures kept warm (LRU).
    num_shards: >1 partitions every served graph edge-balanced into this many
        shards and executes through ``ShardedAmpleEngine`` (halo exchange +
        one plan per shard); 1 is the existing single-plan path.
    partition: explicit ``Partition`` override (validated per graph); implies
        the sharded path and fixes ``num_shards`` to its shard count.
    partitioner: algorithm that splits served graphs when no explicit
        ``partition`` is given — "edges" (contiguous edge-balanced ranges)
        or "mincut" (halo-minimizing multilevel; params inline, e.g.
        "mincut(seed=1)"). Default ``cfg.gnn_partitioner``. Part of the plan
        cache key: the same graph served under two partitioners yields two
        distinct cached plans.
    mesh: optional 1-D ``("shard",)`` device mesh for SPMD shard execution;
        without one, shards run as a host loop on the local device. Must
        hold exactly ``num_shards`` devices.
    halo_overlap: overlap each shard's halo exchange with its interior-tile
        aggregation (outputs bitwise-identical; see
        ``scheduler.split_plan_by_halo``). Default ``cfg.gnn_halo_overlap``.
        Mutually exclusive with the Pallas kernel path.
    union_node_bucket / union_edge_bucket: >0 switches batched serving to
        **padded union size classes**: member graphs are planned (and cached)
        individually, the union plan is assembled by index relabelling, and
        nodes/tiles are padded up to the bucket so different member mixes
        share device shapes. 0 (default) keeps exact-shape union plans.
        Defaults come from ``cfg.gnn_union_node_bucket`` /
        ``cfg.gnn_union_edge_bucket``; ignored on the sharded path, whose
        unions are planned exactly.
    feature_budget_bytes: >0 enables **out-of-core serving**: a request whose
        feature matrix exceeds the budget keeps features host-resident in a
        chunked ``memory.FeatureStore`` and the engine streams them through
        a budget-bound device chunk cache (reuse-distance eviction, double-
        buffered prefetch) — outputs are bitwise-identical to the in-memory
        path. Requests that fit take the existing path unchanged. Default
        ``cfg.gnn_feature_budget_bytes`` (0 = off).
    feature_chunk_rows: rows per feature chunk (0 derives a size from the
        budget). Default ``cfg.gnn_feature_chunk_rows``.
    stream_packing: serve streamed requests through chunk-packed tile plans
        (``scheduler.pack_tiles_by_chunk``; bitwise-identical outputs, tiles
        draw from fewer chunks). Default ``cfg.gnn_stream_packing``.
    stream_reorder: locality-reorder tile runs on the streamed path; False
        keeps plan order (the reorder-vs-pack control arm benchmarks A/B
        without hand-built prefetchers). Default ``cfg.gnn_stream_reorder``.
    stream_prefetch_depth: tiles of lookahead granted to the async staging
        worker and slot prefetcher (0 = fully synchronous streaming).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params=None,
        *,
        engine_cfg: Optional[EngineConfig] = None,
        plan_cache_size: int = 32,
        num_shards: int = 1,
        partition: Optional[Partition] = None,
        partitioner: Optional[str] = None,
        mesh=None,
        halo_overlap: Optional[bool] = None,
        union_node_bucket: Optional[int] = None,
        union_edge_bucket: Optional[int] = None,
        feature_budget_bytes: Optional[int] = None,
        feature_chunk_rows: Optional[int] = None,
        stream_packing: Optional[bool] = None,
        stream_reorder: Optional[bool] = None,
        stream_prefetch_depth: int = 2,
        key=None,
    ):
        if cfg.family != "gnn":
            raise ValueError(f"GNNServeEngine needs a family='gnn' config, got {cfg.family!r}")
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.cfg = cfg
        self.engine_cfg = engine_cfg if engine_cfg is not None else gnn_api.engine_config(cfg)
        if params is None:
            params = gnn_api.gnn_init(cfg, key if key is not None else jax.random.PRNGKey(0))
        self.params = params
        self.plan_cache_size = plan_cache_size
        self.partition = partition
        self.num_shards = partition.num_shards if partition is not None else num_shards
        self.partitioner = (
            cfg.gnn_partitioner if partitioner is None else partitioner
        ) or "edges"
        self.mesh = mesh
        self.halo_overlap = (
            cfg.gnn_halo_overlap if halo_overlap is None else halo_overlap
        )
        if self.halo_overlap and self.engine_cfg.use_kernel:
            # Same contract as the streamed-path refusal below: the split
            # interior/boundary schedule continues a scan accumulator, which
            # the fused Pallas kernel has no hook for — refuse loudly rather
            # than silently serving unsplit.
            raise ValueError(
                "halo_overlap and use_kernel are mutually exclusive: the "
                "overlapped halo exchange continues the jnp scan accumulator "
                "(the Pallas kernel owns its own). Drop "
                "ModelConfig.gnn_use_kernel / EngineConfig.use_kernel, or "
                "set gnn_halo_overlap=False / --halo-overlap off."
            )
        if mesh is not None and mesh.devices.size != self.num_shards:
            raise ValueError(
                f"mesh has {mesh.devices.size} devices but num_shards="
                f"{self.num_shards}; pass --num-shards {mesh.devices.size} "
                f"(or a mesh with one device per shard)"
            )
        self.union_node_bucket = (
            cfg.gnn_union_node_bucket if union_node_bucket is None else union_node_bucket
        )
        self.union_edge_bucket = (
            cfg.gnn_union_edge_bucket if union_edge_bucket is None else union_edge_bucket
        )
        self.feature_budget_bytes = (
            cfg.gnn_feature_budget_bytes
            if feature_budget_bytes is None
            else feature_budget_bytes
        )
        self.feature_chunk_rows = (
            cfg.gnn_feature_chunk_rows
            if feature_chunk_rows is None
            else feature_chunk_rows
        )
        self.stream_packing = (
            cfg.gnn_stream_packing if stream_packing is None else stream_packing
        )
        self.stream_reorder = (
            cfg.gnn_stream_reorder if stream_reorder is None else stream_reorder
        )
        self.stream_prefetch_depth = max(int(stream_prefetch_depth), 0)
        if self.feature_budget_bytes > 0 and self.engine_cfg.use_kernel:
            # The streamed executors are jnp-only (chunk-blocked passes are
            # bitwise-equal to the dense jnp path; the Pallas kernels
            # re-associate) — refuse the combination outright rather than
            # silently serving every request fully in-memory.
            raise ValueError(
                "feature_budget_bytes and use_kernel are mutually exclusive: "
                "the out-of-core streamed executors serve the jnp path only "
                "(Pallas kernel rounding differs from the streamed oracle). "
                "Drop EngineConfig.use_kernel / ModelConfig.gnn_use_kernel, "
                "or set feature_budget_bytes=0 to serve in-memory."
            )
        if self.feature_budget_bytes > 0 and self.sharded:
            # Better a loud no-op than a user believing the cap is active
            # and meeting an OOM on a genuinely large graph.
            import warnings

            warnings.warn(
                "feature_budget_bytes is ignored on sharded engines: the "
                "streamed executors serve the plain single-device jnp path "
                "only; requests will run fully in-memory",
                stacklevel=2,
            )
        # fingerprint -> (prepared graph, plan, engine); OrderedDict as LRU.
        # The engine rides along so its weight-quant cache survives across
        # requests (params are fixed for this serve engine's lifetime).
        # Sharded requests store (prepared, ShardedExecutionPlan,
        # ShardedAmpleEngine) tuples under the same LRU.
        self._cache: "OrderedDict[str, Tuple[Graph, Union[ExecutionPlan, ShardedExecutionPlan], AmpleEngine]]" = OrderedDict()
        # Per-shard plan LRU, keyed on shard_plan_key (structure, partition
        # boundaries, shard index, planner config): a shard compiled for one
        # request is reusable by any later request on the same partitioned
        # structure, independently of the assembled plan above.
        self._shard_plans: "OrderedDict[str, ShardPlan]" = OrderedDict()
        # Member-plan pieces for the padded-union path, keyed on the member's
        # structure fingerprint: value = (prepared member graph, its solo
        # ExecutionPlan). A member planned for one batch mix is reusable by
        # every later mix containing it — this cache, not the assembled-plan
        # LRU, is what keeps the planner cold under varying compositions.
        self._member_plans: "OrderedDict[str, Tuple[Graph, ExecutionPlan]]" = OrderedDict()
        # Size classes already served (device shapes warm); statistics only.
        self._classes_seen: "OrderedDict[str, None]" = OrderedDict()
        # FeatureStore LRU for the out-of-core path, keyed on (feature array
        # identity, row count, chunk rows) with a strong ref held — id()
        # alone is unsound once the original is collected, same reasoning as
        # the weight-quant cache.
        self._stores: "OrderedDict[tuple, Tuple[np.ndarray, object]]" = OrderedDict()
        self._last_stream = None  # StreamStats of the most recent _run
        # Historical dict API over registry-backed cells: the metrics
        # registry (observe.metrics) holds the single copy of every counter;
        # this view keeps `engine.stats[...]` value-identical to the old
        # ad-hoc dict (ints stay ints, the *_ms accumulators stay floats).
        self.instance = ometrics.next_instance("gnn_serve")
        self.stats: ometrics.StatsView = ometrics.StatsView(
            ometrics.get_registry(),
            "gnn_serve",
            {"engine": self.instance},
            keys=(
                "requests",
                "batches",
                "cache_hits",
                "cache_misses",
                "planner_calls",
                "evictions",
                "shard_hits",
                "warm_loads",
                "member_hits",
                "member_misses",
                "class_hits",
                "class_misses",
                "streamed_requests",
                "bytes_streamed",
                "chunk_hits",
                "chunk_misses",
                "prefetched_uploads",
                "stream_fallbacks",
                "stall_ms",
                "copy_ms",
                "halo_exchanges",
                "halo_bytes",
                "halo_ms",
                "halo_wait_ms",
            ),
            float_keys=("stall_ms", "copy_ms", "halo_ms", "halo_wait_ms"),
        )
        self._last_halo: Optional[Dict[str, float]] = None

    @property
    def sharded(self) -> bool:
        return self.num_shards > 1 or self.partition is not None

    @property
    def padded_unions(self) -> bool:
        """True when batched requests plan through padded union size classes."""
        return (
            (self.union_node_bucket > 0 or self.union_edge_bucket > 0)
            and not self.sharded
        )

    # ------------------------------------------------------------ plan cache
    def _cache_key(self, g: Graph, arch: str, members: Optional[Sequence[Graph]]) -> str:
        """Structure hash + engine config + arch — everything that shapes a plan.

        Keyed on the *raw* request graph so arch-specific preprocessing
        (GCN's self-loops) is part of the cached work, not repeated per hit.
        Batched unions also key on the member boundaries, since Degree-Quant
        tags are computed per member graph (the same union structure split
        differently plans differently).
        """
        parts = [repr(self.engine_cfg), arch]
        if members is not None:
            parts.append("bounds:" + ",".join(str(m.num_nodes) for m in members))
        if self.sharded:
            if self.partition is not None:
                parts.append(
                    "starts:" + ",".join(str(int(s)) for s in self.partition.starts)
                )
                parts.append(f"kind:{self.partition.kind}")
            else:
                parts.append(f"shards:{self.num_shards}")
                parts.append(f"partitioner:{self.partitioner}")
            if self.halo_overlap:
                # plan contents are identical, but the cached engine holds
                # split-plan device state — keep the entries distinct
                parts.append("halo_overlap")
        return plan_fingerprint(g, *parts)

    def _plan_for(
        self, g: Graph, arch: str, members: Optional[Sequence[Graph]] = None
    ) -> Tuple[Graph, ExecutionPlan, AmpleEngine, bool, float]:
        key = self._cache_key(g, arch, members)
        hit = key in self._cache
        plan_ms = 0.0
        if hit:
            self._cache.move_to_end(key)
            self.stats["cache_hits"] += 1
        else:
            self.stats["cache_misses"] += 1
            self.stats["planner_calls"] += 1
            cfg = dataclasses.replace(self.cfg, gnn_arch=arch)
            t0 = time.perf_counter()
            prepared = gnn_api.prepare_graph(cfg, g)
            tags = None
            if members is not None and self.engine_cfg.mixed_precision:
                # Tag each member independently: a small graph batched with a
                # hub-heavy one must keep its own Degree-Quant-protected
                # nodes, exactly as if served solo.
                tags = self._member_tags(cfg, members)
            plan = compile_plans(
                prepared, self.engine_cfg, modes=(gnn_api.agg_mode(cfg),),
                precision_tags=tags,
            )
            plan_ms = (time.perf_counter() - t0) * 1e3
            self._cache[key] = (prepared, plan, AmpleEngine(prepared, plan=plan))
            while len(self._cache) > self.plan_cache_size:
                self._cache.popitem(last=False)
                self.stats["evictions"] += 1
        prepared, plan, engine = self._cache[key]
        return prepared, plan, engine, hit, plan_ms

    def _member_tags(self, cfg, members: Sequence[Graph]) -> np.ndarray:
        """Per-member Degree-Quant tags for a batched disjoint union."""
        return np.concatenate([
            inference_precision_tags(
                gnn_api.prepare_graph(cfg, m), self.engine_cfg.dq
            )
            for m in members
        ])

    # ------------------------------------------ padded union size classes
    def _member_plan(self, cfg, m: Graph, arch: str) -> Tuple[Graph, ExecutionPlan]:
        """One member graph's (prepared graph, solo plan), LRU-cached.

        Tags are computed on the member's own degree distribution — identical
        Degree-Quant protection to solo serving — so any assembly of cached
        members preserves the per-member tagging guarantee of ``infer_batch``.
        """
        key = plan_fingerprint(m, repr(self.engine_cfg), arch, "member")
        if key in self._member_plans:
            self._member_plans.move_to_end(key)
            self.stats["member_hits"] += 1
            return self._member_plans[key]
        self.stats["member_misses"] += 1
        self.stats["planner_calls"] += 1
        prepared = gnn_api.prepare_graph(cfg, m)
        plan = compile_plans(
            prepared,
            self.engine_cfg,
            modes=(gnn_api.agg_mode(cfg),),
            precision_tags=engine_precision_tags(prepared, self.engine_cfg),
        )
        self._member_plans[key] = (prepared, plan)
        while len(self._member_plans) > max(self.plan_cache_size * 8, 64):
            self._member_plans.popitem(last=False)
        return prepared, plan

    def _plan_for_padded(
        self, members: Sequence[Graph], arch: str
    ) -> Tuple[Graph, ExecutionPlan, AmpleEngine, bool, float]:
        """Size-class planning: cached member pieces → assembled padded union.

        The serve cache resolves in two levels. The **size class**
        (``union_bucket_fingerprint`` over the bucketed node/edge counts) is
        the shape-level key: a warm class means the device executable and
        upload shapes recur, whatever the member mix. The member mix itself
        only decides which cached plan pieces are relabelled into the
        assembled plan — an O(E) copy, never a planner call for known
        members. ``cache_hit`` is True when neither the members nor the
        assembly needed the planner; ``plan_ms`` covers whatever planning +
        assembly this call actually paid (member compilation still counts
        when the assembled plan itself was resident, e.g. right after
        ``load_plan_cache`` warmed the assembled LRU but not the pieces).
        """
        cfg = dataclasses.replace(self.cfg, gnn_arch=arch)
        t0 = time.perf_counter()
        misses_before = self.stats["member_misses"]
        pieces = [self._member_plan(cfg, m, arch) for m in members]
        members_cold = self.stats["member_misses"] > misses_before
        n_real = sum(p.num_nodes for p, _ in pieces)
        e_real = sum(p.num_edges for p, _ in pieces)
        class_fp = union_bucket_fingerprint(
            n_real,
            e_real,
            self.union_node_bucket,
            self.union_edge_bucket,
            repr(self.engine_cfg),
            arch,
        )
        if class_fp in self._classes_seen:
            self._classes_seen.move_to_end(class_fp)
            self.stats["class_hits"] += 1
        else:
            self._classes_seen[class_fp] = None
            self.stats["class_misses"] += 1
            while len(self._classes_seen) > self.plan_cache_size * 8:
                self._classes_seen.popitem(last=False)

        h = hashlib.blake2b(digest_size=16)
        h.update(class_fp.encode())
        for _, mp in pieces:
            h.update(b"\x00")
            h.update(mp.fingerprint.encode())
        key = h.hexdigest()
        if key in self._cache:
            self._cache.move_to_end(key)
            self.stats["cache_hits"] += 1
            prepared, plan, engine = self._cache[key]
            plan_ms = (
                (time.perf_counter() - t0) * 1e3 if members_cold else 0.0
            )
            return prepared, plan, engine, not members_cold, plan_ms

        self.stats["cache_misses"] += 1
        n_class, _ = size_class(
            n_real, e_real, self.union_node_bucket, self.union_edge_bucket
        )
        union = disjoint_union(
            [p for p, _ in pieces], pad_num_nodes=n_class
        )
        plan = assemble_union_plan(
            [mp for _, mp in pieces],
            union,
            cfg=self.engine_cfg,
            edge_bucket=self.union_edge_bucket,
        )
        engine = AmpleEngine(union, plan=plan)
        plan_ms = (time.perf_counter() - t0) * 1e3
        self._cache[key] = (union, plan, engine)
        while len(self._cache) > self.plan_cache_size:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1
        return union, plan, engine, False, plan_ms

    def _plan_for_sharded(
        self, g: Graph, arch: str, members: Optional[Sequence[Graph]] = None
    ) -> Tuple[Graph, ShardedExecutionPlan, ShardedAmpleEngine, bool, float]:
        """Sharded analogue of ``_plan_for``: per-shard plan-cache economics.

        The assembled (prepared graph, ShardedExecutionPlan, engine) triple is
        cached under the request key like the unsharded path; below it, every
        ShardPlan lives in a per-shard LRU keyed on (structure, partition,
        shard) fingerprints, so only shards never seen before run the planner.
        ``cache_hit`` is True iff no shard needed compiling; ``plan_ms``
        counts planner time only (0.0 on a full hit).
        """
        key = self._cache_key(g, arch, members)
        if key in self._cache:
            self._cache.move_to_end(key)
            self.stats["cache_hits"] += 1
            prepared, splan, engine = self._cache[key]
            return prepared, splan, engine, True, 0.0

        cfg = dataclasses.replace(self.cfg, gnn_arch=arch)
        prepared = gnn_api.prepare_graph(cfg, g)
        if self.partition is not None:
            validate_partition(prepared, self.partition)
            part = self.partition
        else:
            part = make_partition(prepared, self.num_shards, self.partitioner)
        modes = (gnn_api.agg_mode(cfg),)
        if members is not None and self.engine_cfg.mixed_precision:
            tags = self._member_tags(cfg, members)
        else:
            tags = None
        eff_tags = (
            tags if tags is not None else engine_precision_tags(prepared, self.engine_cfg)
        )

        plan_ms = 0.0
        warm: Dict[int, ShardPlan] = {}
        missing: List[int] = []
        for k in range(part.num_shards):
            skey = shard_plan_key(
                prepared, part, k, self.engine_cfg, modes=modes, precision_tags=eff_tags
            )
            if skey in self._shard_plans:
                self._shard_plans.move_to_end(skey)
                warm[k] = self._shard_plans[skey]
                self.stats["shard_hits"] += 1
            else:
                missing.append(k)
        if missing:
            from repro.core.message_passing import aggregation_coefficients

            self.stats["planner_calls"] += len(missing)
            t0 = time.perf_counter()
            # Global O(E) coefficient work once per request, not per shard.
            mode_coeffs = {m: aggregation_coefficients(prepared, m) for m in modes}
            for k in missing:
                sp = compile_shard_plan(
                    prepared, part, k, self.engine_cfg,
                    modes=modes, precision_tags=eff_tags, mode_coeffs=mode_coeffs,
                )
                warm[k] = sp
                self._shard_plans[sp.fingerprint] = sp
            plan_ms = (time.perf_counter() - t0) * 1e3
            while len(self._shard_plans) > self.plan_cache_size * max(self.num_shards, 1):
                self._shard_plans.popitem(last=False)
        splan = compile_sharded_plans(
            prepared, self.engine_cfg,
            partition=part, modes=modes, precision_tags=eff_tags, shard_plans=warm,
        )
        engine = ShardedAmpleEngine(
            prepared, splan, mesh=self.mesh, halo_overlap=self.halo_overlap
        )
        hit = not missing
        self.stats["cache_hits" if hit else "cache_misses"] += 1
        self._cache[key] = (prepared, splan, engine)
        while len(self._cache) > self.plan_cache_size:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1
        return prepared, splan, engine, hit, plan_ms

    # -------------------------------------------------------------- serving
    def _arch(self, requested: str) -> str:
        if requested and requested != self.cfg.gnn_arch:
            raise ValueError(
                f"this engine holds {self.cfg.gnn_arch!r} params; route "
                f"{requested!r} requests to an engine configured for that arch"
            )
        return requested or self.cfg.gnn_arch

    def _validate_request(self, graph: Graph, features) -> np.ndarray:
        """Admission-time input checks with actionable errors.

        Without these, a bad request surfaces deep in the union path as a
        cryptic concatenate/split shape failure — after other members'
        work was already spent.
        """
        if graph.num_nodes == 0:
            raise ValueError(
                "cannot serve a zero-node graph; drop empty members before "
                "submission"
            )
        f = np.asarray(features, np.float32)
        if f.ndim != 2:
            raise ValueError(
                f"features must be 2-D [num_nodes, feature_dim], got shape "
                f"{tuple(f.shape)}"
            )
        if f.shape[0] != graph.num_nodes:
            raise ValueError(
                f"features have {f.shape[0]} rows but graph {graph.name!r} has "
                f"{graph.num_nodes} nodes"
            )
        want = self.cfg.gnn_layer_dims[0]
        if f.shape[1] != want:
            raise ValueError(
                f"features have {f.shape[1]} columns but {self.cfg.name} "
                f"expects {want} (cfg.d_model)"
            )
        return f

    def _plan_for_batch(
        self, members: Sequence[Graph], arch: str
    ) -> Tuple[Graph, Union[ExecutionPlan, ShardedExecutionPlan], AmpleEngine, bool, float]:
        """Plan-assembly step for a disjoint-union batch — path dispatch.

        The reusable half the continuous-batching loop drives incrementally:
        sharded engines plan the exact union per shard, padded engines
        assemble cached member pieces into a size-class plan, and the default
        engine compiles the exact union (with per-member Degree-Quant tags).
        """
        if self.padded_unions:
            return self._plan_for_padded(members, arch)
        union = disjoint_union(list(members))
        if self.sharded:
            return self._plan_for_sharded(union, arch, members)
        return self._plan_for(union, arch, members)

    @staticmethod
    def _pad_features(features: np.ndarray, num_nodes: int) -> np.ndarray:
        """Zero rows up to the size-class node count (no-op when exact)."""
        if num_nodes <= features.shape[0]:
            return features
        return np.concatenate(
            [features,
             np.zeros((num_nodes - features.shape[0], features.shape[1]),
                      np.float32)],
            axis=0,
        )

    # ------------------------------------------------- out-of-core streaming
    def _stream_eligible(self, engine: AmpleEngine, features: np.ndarray) -> bool:
        """Stream iff a budget is set, the matrix exceeds it, and the plan
        executes on the plain single-device engine (the sharded executor
        gathers per-shard row sets and is served in-memory). Kernel-routed
        engines (``use_kernel``) are excluded: the streamed executors are
        the jnp oracle, and Pallas vs oracle can differ by an int8 rounding
        step — streaming there would break the bitwise guarantee."""
        return (
            self.feature_budget_bytes > 0
            and type(engine) is AmpleEngine
            and not self.engine_cfg.use_kernel
            and features.nbytes > self.feature_budget_bytes
        )

    def _feature_stream(
        self,
        features: np.ndarray,
        *,
        cache_store: bool = True,
        store_key=None,  # caller-held object of any array-like type
    ):
        """Wrap ``features`` in a StreamedFeatures handle (store LRU-cached).

        Repeat traffic holding the same feature array skips the store build
        (chunking + int8 quantization) exactly like repeat structures skip
        the planner. The store is tag-independent — it holds every row in
        both representations — so one store serves any plan over the matrix.

        ``store_key`` is the caller-held array the cache identity hangs on
        when ``features`` itself is derived per call — the padded-union path
        pads a fresh copy each request, so keying on the *original* matrix
        (plus the padded row count) is what lets warm padded requests hit.
        ``cache_store=False`` builds an ephemeral store instead: the batch
        path concatenates a fresh union matrix per call, so id-keyed entries
        could never hit again and would only pin dead matrices in the LRU.
        """
        from repro.memory.feature_store import FeatureStore, default_chunk_rows
        from repro.memory.prefetcher import StreamedFeatures

        rows = self.feature_chunk_rows or default_chunk_rows(
            features.shape[0], features.shape[1], self.feature_budget_bytes
        )
        def wrap(store):
            return StreamedFeatures(
                store,
                self.feature_budget_bytes,
                prefetch_depth=self.stream_prefetch_depth,
                reorder=self.stream_reorder,
                packing=self.stream_packing,
            )

        if not cache_store:
            return wrap(FeatureStore.from_array(features, chunk_rows=rows))
        key_arr = store_key if store_key is not None else features
        key = (id(key_arr), features.shape[0], rows)
        entry = self._stores.get(key)
        if entry is None or entry[0] is not key_arr:
            store = FeatureStore.from_array(features, chunk_rows=rows)
            self._stores[key] = (key_arr, store)
            while len(self._stores) > 4:
                self._stores.popitem(last=False)
        else:
            self._stores.move_to_end(key)
        return wrap(self._stores[key][1])

    def _run(
        self,
        arch: str,
        prepared: Graph,
        engine: AmpleEngine,
        features,
        *,
        cache_store: bool = True,
        store_key=None,
        trace_id: str = "",
    ) -> Tuple[np.ndarray, float]:
        """Execution step: one padded device call over an assembled plan.

        When the feature matrix exceeds ``feature_budget_bytes`` (and the
        plan runs on the single-device engine), features stay host-resident
        and the engine streams them chunk-wise — same outputs, bit for bit;
        telemetry lands in ``stats`` and on the response. ``cache_store``
        is False on the batch path (per-call union matrices never repeat);
        ``store_key`` carries the caller-held array identity when
        ``features`` is a per-call padded copy.
        """
        cfg = dataclasses.replace(self.cfg, gnn_arch=arch)
        self._last_stream = None
        self._last_halo = None
        batch_features = features
        if self._stream_eligible(engine, features):
            sf = self._feature_stream(
                features, cache_store=cache_store, store_key=store_key
            )
            sf.trace_id = trace_id  # prefetcher stamps copy/stall spans
            batch_features = sf
            self._last_stream = sf.stats
        halo_before = None
        if isinstance(engine, ShardedAmpleEngine):
            engine.trace_id = trace_id  # halo spans join this request's trace
            halo_before = dict(engine.halo_stats)
        t0 = time.perf_counter()
        y, _ = gnn_api.gnn_forward(
            self.params, cfg,
            {"graph": prepared, "features": batch_features, "engine": engine},
        )
        y = np.asarray(jax.block_until_ready(y))
        t1 = time.perf_counter()
        run_ms = (t1 - t0) * 1e3
        rec = otrace.get_recorder()
        if rec.enabled:
            # Same stamps as run_ms, so the execute span reconciles exactly.
            rec.add_span(
                "execute", t0, t1, cat="serve", trace_id=trace_id,
                args={"arch": arch, "streamed": self._last_stream is not None},
            )
        if self._last_stream is not None:
            s = self._last_stream
            self.stats["bytes_streamed"] += s.bytes_streamed
            self.stats["chunk_hits"] += s.chunk_hits
            self.stats["chunk_misses"] += s.chunk_misses
            self.stats["prefetched_uploads"] += s.prefetched
            self.stats["stream_fallbacks"] += s.fallbacks
            self.stats["stall_ms"] += s.stall_ms
            self.stats["copy_ms"] += s.copy_ms
        if halo_before is not None:
            # This call's halo traffic = engine accumulator delta (the engine
            # is shared across cached requests; only the delta is ours).
            delta = {
                k: engine.halo_stats.get(k, 0.0) - halo_before.get(k, 0.0)
                for k in ("halo_ms", "halo_wait_ms", "halo_bytes", "halo_exchanges")
            }
            if delta["halo_exchanges"] > 0:
                self._last_halo = delta
                self.stats["halo_exchanges"] += int(delta["halo_exchanges"])
                self.stats["halo_bytes"] += int(delta["halo_bytes"])
                self.stats["halo_ms"] += delta["halo_ms"]
                self.stats["halo_wait_ms"] += delta["halo_wait_ms"]
        return y, run_ms

    def _stream_fields(self) -> Dict[str, object]:
        """Response fields describing the most recent ``_run``'s streaming."""
        s = self._last_stream
        if s is None:
            return {}
        return {
            "streamed": True,
            "bytes_streamed": s.bytes_streamed,
            "chunk_hit_rate": s.hit_rate,
            "prefetch_overlap": s.prefetch_overlap,
            "stall_ms": s.stall_ms,
            "copy_ms": s.copy_ms,
        }

    def _halo_fields(self) -> Dict[str, object]:
        """Response fields describing the most recent ``_run``'s halo traffic.

        ``halo_overlap`` is wall-clock truth, mirroring ``prefetch_overlap``:
        the fraction of fenced halo-fetch time the aggregation did NOT block
        on (``1 - halo_wait_ms / halo_ms``).
        """
        h = self._last_halo
        if h is None:
            return {}
        halo_ms = h["halo_ms"]
        overlap = (
            min(max(1.0 - h["halo_wait_ms"] / halo_ms, 0.0), 1.0)
            if halo_ms > 0.0
            else 0.0
        )
        return {
            "halo_ms": halo_ms,
            "halo_bytes": int(h["halo_bytes"]),
            "halo_overlap": overlap,
        }

    @staticmethod
    def _queue_ms(admitted_at: float, exec_start: float) -> float:
        """Admission→execution wait; 0.0 for requests that never queued.

        Both stamps are ``time.perf_counter()`` — the one clock the whole
        serving stack uses (see ``request_stamp``) — so this subtraction,
        the trace's queue span, and every duration share a timeline.
        """
        if admitted_at <= 0.0:
            return 0.0
        return max(exec_start - admitted_at, 0.0) * 1e3

    def infer(
        self,
        graph: Graph,
        features,
        *,
        arch: str = "",
        admitted_at: float = 0.0,
        trace_id: str = "",
    ) -> GNNResponse:
        """Serve one request; plans come from the LRU cache when warm.

        With padded unions enabled the request is served as a batch of one —
        its member plan piece then pre-warms every future batch containing
        this structure. ``admitted_at`` (a ``time.perf_counter()`` stamp, see
        ``request_stamp``) marks when the request was admitted upstream; the
        response's ``queue_ms`` reports the wait between then and execution
        start.
        """
        arch = self._arch(arch)
        # The store-cache identity is the CALLER's object: validation may
        # convert (float64/jnp inputs), and padding copies — keying on either
        # derived array would rebuild the store on every warm request.
        original = features
        features = self._validate_request(graph, features)
        rec = otrace.get_recorder()
        if rec.enabled and not trace_id:
            trace_id = otrace.new_trace_id()
        exec_start = time.perf_counter()
        queue_ms = self._queue_ms(admitted_at, exec_start)
        if rec.enabled and admitted_at > 0.0:
            rec.add_span("queue", admitted_at, exec_start, cat="serve",
                         trace_id=trace_id)
        if self.padded_unions:
            prepared, plan, engine, hit, plan_ms = self._plan_for_padded([graph], arch)
            features = self._pad_features(features, prepared.num_nodes)
        elif self.sharded:
            prepared, plan, engine, hit, plan_ms = self._plan_for_sharded(graph, arch)
        else:
            prepared, plan, engine, hit, plan_ms = self._plan_for(graph, arch)
        if rec.enabled:
            rec.add_span(
                "plan", exec_start, time.perf_counter(), cat="serve",
                trace_id=trace_id,
                args={"cache_hit": hit, "plan_ms": plan_ms},
            )
        y, run_ms = self._run(
            arch, prepared, engine, features, store_key=original,
            trace_id=trace_id,
        )
        self.stats["requests"] += 1
        if self._last_stream is not None:
            self.stats["streamed_requests"] += 1
        return GNNResponse(
            outputs=y[: graph.num_nodes],
            cache_hit=hit,
            fingerprint=plan.fingerprint,
            plan_ms=plan_ms,
            run_ms=run_ms,
            num_shards=getattr(plan, "num_shards", 1),
            queue_ms=queue_ms,
            trace_id=trace_id,
            **self._stream_fields(),
            **self._halo_fields(),
        )

    def infer_batch(self, requests: Sequence[GNNRequest]) -> List[GNNResponse]:
        """Batch independent small-graph requests into one padded device call.

        All requests must target this engine's arch (group upstream
        otherwise). The disjoint union is block-diagonal and every
        aggregation coefficient depends only on per-node degree, so in float
        precision the union forward equals the per-request forwards stacked
        exactly; outputs are split back by node counts. Under the mixed
        policy, Degree-Quant tags are computed per member graph (identical
        protection to solo serving), while int8 activation scale/zero-point
        remain batch-wide — the usual granularity trade-off of batched
        quantized serving.

        Internally this is ``_plan_for_batch`` (plan assembly) followed by
        ``_run`` (one device call) — the same two steps the continuous-
        batching ``AsyncGNNEngine`` drives per admission window, so a
        micro-batch admitted asynchronously is bitwise-identical to the same
        composition served here.
        """
        if not requests:
            return []
        arch = self._arch(requests[0].arch)
        for r in requests[1:]:
            self._arch(r.arch)  # every request must match this engine's arch
        feats = [self._validate_request(r.graph, r.features) for r in requests]
        rec = otrace.get_recorder()
        exec_start = time.perf_counter()
        queue_waits = [self._queue_ms(r.admitted_at, exec_start) for r in requests]
        batch_tid = requests[0].trace_id
        if rec.enabled:
            if not batch_tid:
                batch_tid = otrace.new_trace_id()
            # Per-member queue spans carry each request's own id; the
            # window-level plan/execute spans carry the lead member's.
            for r in requests:
                if r.admitted_at > 0.0:
                    rec.add_span("queue", r.admitted_at, exec_start,
                                 cat="serve", trace_id=r.trace_id or batch_tid)
        members = [r.graph for r in requests]
        prepared, plan, engine, hit, plan_ms = self._plan_for_batch(members, arch)
        if rec.enabled:
            rec.add_span(
                "plan", exec_start, time.perf_counter(), cat="serve",
                trace_id=batch_tid,
                args={"cache_hit": hit, "plan_ms": plan_ms,
                      "batch": len(requests)},
            )
        features = self._pad_features(np.concatenate(feats, axis=0), prepared.num_nodes)
        y, run_ms = self._run(
            arch, prepared, engine, features, cache_store=False,
            trace_id=batch_tid,
        )
        # Counted only on success, so a failed-and-requeued continuous-batching
        # window doesn't double-count when it retries.
        self.stats["requests"] += len(requests)
        if self._last_stream is not None:
            # Every member of the streamed union call counts, so
            # streamed_requests / requests is the true streamed fraction.
            self.stats["streamed_requests"] += len(requests)
        self.stats["batches"] += 1
        out: List[GNNResponse] = []
        start = 0
        stream_fields = {**self._stream_fields(), **self._halo_fields()}
        scatter_t0 = time.perf_counter()
        for r, q_ms in zip(requests, queue_waits):
            stop = start + r.graph.num_nodes
            out.append(
                GNNResponse(
                    outputs=y[start:stop],
                    cache_hit=hit,
                    fingerprint=plan.fingerprint,
                    plan_ms=plan_ms,
                    run_ms=run_ms,
                    num_shards=getattr(plan, "num_shards", 1),
                    batch_size=len(requests),
                    queue_ms=q_ms,
                    trace_id=r.trace_id or batch_tid,
                    **stream_fields,
                )
            )
            start = stop
        if rec.enabled:
            rec.add_span(
                "scatter", scatter_t0, time.perf_counter(), cat="serve",
                trace_id=batch_tid, args={"batch": len(requests)},
            )
        return out

    # --------------------------------------------------------- persistence
    def save_plan_cache(self, directory: str) -> List[str]:
        """Persist every cached plan (npz via ``checkpoint.plan_store``).

        One file per cache entry, named by the serve-cache key; the prepared
        graph structure rides along so ``load_plan_cache`` can rebuild the
        execution engine without re-running arch preprocessing.
        """
        from repro.checkpoint.plan_store import save_plan

        os.makedirs(directory, exist_ok=True)
        paths = []
        for key, (prepared, plan, _) in self._cache.items():
            path = os.path.join(directory, f"{key}.plan.npz")
            save_plan(path, plan, graph=prepared, extra={"serve_key": key})
            paths.append(path)
        return paths

    def load_plan_cache(self, directory: str) -> int:
        """Warm the plan cache from ``save_plan_cache`` output; returns count.

        A restarted server calls this instead of paying the planner again:
        the first request on a persisted structure reports ``cache_hit=True``
        with ``plan_ms == 0.0``, exactly like in-memory repeat traffic.
        Entries whose file lacks a serve key or graph are skipped.
        """
        from repro.checkpoint.plan_store import load_plan

        loaded = 0
        if not os.path.isdir(directory):
            return 0
        for name in sorted(os.listdir(directory)):
            if not name.endswith(".plan.npz"):
                continue
            rec = load_plan(os.path.join(directory, name))
            key = rec.extra.get("serve_key")
            if key is None or rec.graph is None:
                continue
            if isinstance(rec.plan, ShardedExecutionPlan):
                engine: AmpleEngine = ShardedAmpleEngine(
                    rec.graph, rec.plan, mesh=self.mesh,
                    halo_overlap=self.halo_overlap,
                )
                for sp in rec.plan.shards:
                    self._shard_plans[sp.fingerprint] = sp
            else:
                engine = AmpleEngine(rec.graph, plan=rec.plan)
            self._cache[key] = (rec.graph, rec.plan, engine)
            loaded += 1
        while len(self._cache) > self.plan_cache_size:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1
        self.stats["warm_loads"] += loaded
        return loaded

    # ------------------------------------------------------------- metrics
    def cache_info(self) -> Dict[str, float]:
        """Plan-cache counters plus derived streaming rates.

        ``chunk_hit_rate`` / ``prefetch_overlap`` aggregate over every
        streamed request this engine served (0.0 when nothing streamed).
        ``prefetch_overlap`` is wall-clock: the fraction of measured copy
        time the streams did NOT block on (``1 - stall_ms / copy_ms``).
        """
        accesses = self.stats["chunk_hits"] + self.stats["chunk_misses"]
        copy_ms = self.stats["copy_ms"]
        overlap = (
            min(max(1.0 - self.stats["stall_ms"] / copy_ms, 0.0), 1.0)
            if copy_ms > 0.0
            else 0.0
        )
        halo_ms = self.stats["halo_ms"]
        halo_overlap = (
            min(max(1.0 - self.stats["halo_wait_ms"] / halo_ms, 0.0), 1.0)
            if halo_ms > 0.0
            else 0.0
        )
        return {
            "size": len(self._cache),
            "capacity": self.plan_cache_size,
            **self.stats,
            "chunk_hit_rate": (
                self.stats["chunk_hits"] / accesses if accesses else 0.0
            ),
            "prefetch_overlap": overlap,
            "halo_overlap": halo_overlap,
        }

    def shard_report(self) -> Optional[Dict[str, object]]:
        """Shard economics (edge balance, halo volume) of the most recently
        planned sharded request; None when nothing sharded is cached."""
        for _, _, engine in reversed(list(self._cache.values())):
            if isinstance(engine, ShardedAmpleEngine):
                return engine.shard_report()
        return None
