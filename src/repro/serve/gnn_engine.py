"""Plan-cached GNN serving engine — the GNN analogue of the token ServeEngine.

AMPLE's host programs a graph into nodeslots once and then streams inference;
the expensive part of serving a GNN request on this stack is likewise the
host-side planner (Degree-Quant tagging + edge-tile packing), not the device
call. ``GNNServeEngine`` therefore treats the compiled ``ExecutionPlan`` as
the cacheable artifact:

  * requests are ``(graph, features)``; the engine keys an LRU cache on the
    graph's **structure fingerprint** + engine config + arch, so repeat
    traffic on the same graph skips plan compilation entirely — the serving
    analogue of nodeslot recycling;
  * independent small-graph requests are batched by ``infer_batch`` into one
    disjoint-union graph and served in a single padded device call (the
    union's plan is itself cached under the union fingerprint, so a repeated
    batch mix is also a cache hit);
  * cached plans are bitwise-faithful: a warm request returns exactly the
    output a cold engine would produce for the same graph and features.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.degree_quant import inference_precision_tags
from repro.core.message_passing import AmpleEngine, EngineConfig, ExecutionPlan, compile_plans
from repro.core.scheduler import plan_fingerprint
from repro.graphs.csr import Graph, disjoint_union
from repro.models.gnn import api as gnn_api

__all__ = ["GNNRequest", "GNNResponse", "GNNServeEngine"]


@dataclasses.dataclass(frozen=True)
class GNNRequest:
    """One inference request: a graph, its node features, optional arch."""

    graph: Graph
    features: np.ndarray  # f32[N, D]
    arch: str = ""  # "" -> the engine config's arch


@dataclasses.dataclass(frozen=True)
class GNNResponse:
    outputs: np.ndarray  # f32[N, num_classes]
    cache_hit: bool
    fingerprint: str  # plan-cache key the request resolved to
    plan_ms: float  # host planning time (0.0 on a cache hit)
    run_ms: float  # device execution time


class GNNServeEngine:
    """Serve ``(graph, features)`` requests with an LRU ``ExecutionPlan`` cache.

    Parameters
    ----------
    cfg: a ``family="gnn"`` ModelConfig (arch, dims, precision policy).
    params: model params; initialised from ``key`` when omitted.
    engine_cfg: EngineConfig override; derived from ``cfg`` by default.
    plan_cache_size: max distinct graph structures kept warm (LRU).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params=None,
        *,
        engine_cfg: Optional[EngineConfig] = None,
        plan_cache_size: int = 32,
        key=None,
    ):
        if cfg.family != "gnn":
            raise ValueError(f"GNNServeEngine needs a family='gnn' config, got {cfg.family!r}")
        self.cfg = cfg
        self.engine_cfg = engine_cfg if engine_cfg is not None else gnn_api.engine_config(cfg)
        if params is None:
            params = gnn_api.gnn_init(cfg, key if key is not None else jax.random.PRNGKey(0))
        self.params = params
        self.plan_cache_size = plan_cache_size
        # fingerprint -> (prepared graph, plan, engine); OrderedDict as LRU.
        # The engine rides along so its weight-quant cache survives across
        # requests (params are fixed for this serve engine's lifetime).
        self._cache: "OrderedDict[str, Tuple[Graph, ExecutionPlan, AmpleEngine]]" = OrderedDict()
        self.stats: Dict[str, int] = {
            "requests": 0,
            "batches": 0,
            "cache_hits": 0,
            "cache_misses": 0,
            "planner_calls": 0,
            "evictions": 0,
        }

    # ------------------------------------------------------------ plan cache
    def _cache_key(self, g: Graph, arch: str, members: Optional[Sequence[Graph]]) -> str:
        """Structure hash + engine config + arch — everything that shapes a plan.

        Keyed on the *raw* request graph so arch-specific preprocessing
        (GCN's self-loops) is part of the cached work, not repeated per hit.
        Batched unions also key on the member boundaries, since Degree-Quant
        tags are computed per member graph (the same union structure split
        differently plans differently).
        """
        parts = [repr(self.engine_cfg), arch]
        if members is not None:
            parts.append("bounds:" + ",".join(str(m.num_nodes) for m in members))
        return plan_fingerprint(g, *parts)

    def _plan_for(
        self, g: Graph, arch: str, members: Optional[Sequence[Graph]] = None
    ) -> Tuple[Graph, ExecutionPlan, AmpleEngine, bool, float]:
        key = self._cache_key(g, arch, members)
        hit = key in self._cache
        plan_ms = 0.0
        if hit:
            self._cache.move_to_end(key)
            self.stats["cache_hits"] += 1
        else:
            self.stats["cache_misses"] += 1
            self.stats["planner_calls"] += 1
            cfg = dataclasses.replace(self.cfg, gnn_arch=arch)
            t0 = time.perf_counter()
            prepared = gnn_api.prepare_graph(cfg, g)
            tags = None
            if members is not None and self.engine_cfg.mixed_precision:
                # Tag each member independently: a small graph batched with a
                # hub-heavy one must keep its own Degree-Quant-protected
                # nodes, exactly as if served solo.
                tags = np.concatenate([
                    inference_precision_tags(
                        gnn_api.prepare_graph(cfg, m), self.engine_cfg.dq
                    )
                    for m in members
                ])
            plan = compile_plans(
                prepared, self.engine_cfg, modes=(gnn_api.agg_mode(cfg),),
                precision_tags=tags,
            )
            plan_ms = (time.perf_counter() - t0) * 1e3
            self._cache[key] = (prepared, plan, AmpleEngine(prepared, plan=plan))
            while len(self._cache) > self.plan_cache_size:
                self._cache.popitem(last=False)
                self.stats["evictions"] += 1
        prepared, plan, engine = self._cache[key]
        return prepared, plan, engine, hit, plan_ms

    # -------------------------------------------------------------- serving
    def _arch(self, requested: str) -> str:
        if requested and requested != self.cfg.gnn_arch:
            raise ValueError(
                f"this engine holds {self.cfg.gnn_arch!r} params; route "
                f"{requested!r} requests to an engine configured for that arch"
            )
        return requested or self.cfg.gnn_arch

    def _run(self, arch: str, prepared: Graph, engine: AmpleEngine, features) -> Tuple[np.ndarray, float]:
        cfg = dataclasses.replace(self.cfg, gnn_arch=arch)
        t0 = time.perf_counter()
        y, _ = gnn_api.gnn_forward(
            self.params, cfg, {"graph": prepared, "features": features, "engine": engine}
        )
        y = np.asarray(jax.block_until_ready(y))
        return y, (time.perf_counter() - t0) * 1e3

    def infer(self, graph: Graph, features, *, arch: str = "") -> GNNResponse:
        """Serve one request; plans come from the LRU cache when warm."""
        self.stats["requests"] += 1
        arch = self._arch(arch)
        prepared, plan, engine, hit, plan_ms = self._plan_for(graph, arch)
        y, run_ms = self._run(arch, prepared, engine, features)
        return GNNResponse(
            outputs=y,
            cache_hit=hit,
            fingerprint=plan.fingerprint,
            plan_ms=plan_ms,
            run_ms=run_ms,
        )

    def infer_batch(self, requests: Sequence[GNNRequest]) -> List[GNNResponse]:
        """Batch independent small-graph requests into one padded device call.

        All requests must target this engine's arch (group upstream
        otherwise). The disjoint union is block-diagonal and every
        aggregation coefficient depends only on per-node degree, so in float
        precision the union forward equals the per-request forwards stacked
        exactly; outputs are split back by node counts. Under the mixed
        policy, Degree-Quant tags are computed per member graph (identical
        protection to solo serving), while int8 activation scale/zero-point
        remain batch-wide — the usual granularity trade-off of batched
        quantized serving.
        """
        if not requests:
            return []
        arch = self._arch(requests[0].arch)
        for r in requests[1:]:
            self._arch(r.arch)  # every request must match this engine's arch
        self.stats["requests"] += len(requests)
        self.stats["batches"] += 1
        members = [r.graph for r in requests]
        union = disjoint_union(members)
        features = np.concatenate(
            [np.asarray(r.features, np.float32) for r in requests], axis=0
        )
        prepared, plan, engine, hit, plan_ms = self._plan_for(union, arch, members)
        y, run_ms = self._run(arch, prepared, engine, features)
        out: List[GNNResponse] = []
        start = 0
        for r in requests:
            stop = start + r.graph.num_nodes
            out.append(
                GNNResponse(
                    outputs=y[start:stop],
                    cache_hit=hit,
                    fingerprint=plan.fingerprint,
                    plan_ms=plan_ms,
                    run_ms=run_ms,
                )
            )
            start = stop
        return out

    # ------------------------------------------------------------- metrics
    def cache_info(self) -> Dict[str, int]:
        return {"size": len(self._cache), "capacity": self.plan_cache_size, **self.stats}
