"""jit'd public wrapper: [B,S,H,hd] layout in, Pallas flash kernel inside."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_call

__all__ = ["flash_attention"]


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, interpret: bool | None = None):
    """q [B,S,H,hd], k/v [B,T,KV,hd] -> [B,S,H,hd]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_call(qt, kt, vt, causal=causal, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
