"""Pure-jnp GQA attention oracle for the flash kernel."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


@functools.partial(jax.jit, static_argnames=("causal",))
def attention_ref(q, k, v, *, causal: bool = True):
    """q [B,S,H,hd], k/v [B,T,KV,hd] -> [B,S,H,hd]; mask aligned to seq ends."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        scores = jnp.where((kpos - (t - s)) > qpos, -1e30, scores)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, h, hd)
