"""Pallas TPU kernel: block-tiled flash attention (GQA-aware, causal).

Classic online-softmax tiling: grid (B, H, S/BQ, T/BK) with the KV block axis
innermost as the reduction dimension. VMEM scratch carries the running max m,
normalizer l, and output accumulator across KV steps; the output block is
written once on the last KV step. GQA is folded into the BlockSpec index map —
q-head h reads kv-head h // group, so KV is never materially repeated.

This is the TPU deployment path for attention; the ``chunked`` XLA
implementation in models/lm/attention.py computes the identical recurrence and
serves as the oracle (plus the dry-run lowering path, since Pallas TPU kernels
cannot lower on the CPU dry-run host).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_call"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal,
            block_q, block_k, seq_q, seq_k):
    i = pl.program_id(2)  # q block
    kk = pl.program_id(3)  # kv block (reduction)
    nk = pl.num_programs(3)

    @pl.when(kk == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [BQ, hd]
    k = k_ref[0, 0].astype(jnp.float32)  # [BK, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [BQ, BK]

    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = kk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos >= seq_k  # KV padding
    if causal:
        # align causality to the *end* of both sequences (standard decode rule)
        mask = mask | ((kpos - (seq_k - seq_q)) > qpos)
    s = jnp.where(mask, NEG_INF, s)

    m_prev = m_ref[...]  # [BQ, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_call(
    q: jnp.ndarray,  # [B, H, Sq, hd]
    k: jnp.ndarray,  # [B, KV, Sk, hd]
    v: jnp.ndarray,  # [B, KV, Sk, hd]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    _, kv, sk, _ = k.shape
    group = h // kv
    scale = 1.0 / math.sqrt(hd)
    bq, bk = min(block_q, _rup(sq)), min(block_k, _rup(sk))
    sqp, skp = _ceil(sq, bq) * bq, _ceil(sk, bk) * bk
    if sqp != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    if skp != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal,
            block_q=bq, block_k=bk, seq_q=sq, seq_k=sk,
        ),
        grid=(b, h, sqp // bq, skp // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bb, hh, i, kk: (bb, hh, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, hd), lambda bb, hh, i, kk, g=group: (bb, hh // g, kk, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, hd), lambda bb, hh, i, kk, g=group: (bb, hh // g, kk, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bb, hh, i, kk: (bb, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
    return out[:, :, :sq]


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _rup(x: int, mult: int = 128) -> int:
    return max(mult, _ceil(x, mult) * mult)
