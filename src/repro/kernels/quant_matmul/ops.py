"""jit'd public wrapper for the int8 FTE kernel (auto interpret off-TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.quant_matmul import quant_matmul_kernel_call
from repro.kernels.quant_matmul.repack import (
    RepackedWeight,
    quant_matmul_repacked_call,
    repack_weight,
)

__all__ = [
    "quant_matmul",
    "quant_matmul_repacked",
    "repack_weight",
    "RepackedWeight",
]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_matmul(
    a_q: jnp.ndarray, b_q: jnp.ndarray, *, interpret: bool | None = None
) -> jnp.ndarray:
    """int32 = int8 @ int8; Pallas on TPU, interpret elsewhere."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return quant_matmul_kernel_call(a_q, b_q, interpret=interpret)


def quant_matmul_repacked(
    a_q: jnp.ndarray,
    packed: RepackedWeight,
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """int32 = int8 @ repacked int8 weight — bitwise == ``quant_matmul``
    on the unpacked layout (same blocks, integer accumulation), minus the
    per-call weight pad/transpose."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return quant_matmul_repacked_call(a_q, packed, interpret=interpret)
