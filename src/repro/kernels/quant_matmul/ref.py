"""Pure-jnp oracle for the int8 matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quant_matmul_ref"]


@jax.jit
def quant_matmul_ref(a_q: jnp.ndarray, b_q: jnp.ndarray) -> jnp.ndarray:
    """int32[M, N] = a_q @ b_q, exact integer accumulation."""
    return jnp.dot(
        a_q.astype(jnp.int32), b_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
