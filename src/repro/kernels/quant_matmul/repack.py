"""Load-time int8 weight repacking for the Pallas quant matmul.

``quant_matmul_kernel_call`` pads the weight to its block grid and streams
``(bk, bn)`` blocks through a strided ``BlockSpec`` on **every call** — per
call, the [K, N] operand is re-padded and the DMA engine walks a 2-D stride
pattern. Marlin (GPTQ) solves the same problem on GPU by rewriting the
weight into the kernel's native tile order once at load time
(``gptq_marlin_repack.cu``); this is the TPU analogue:

    int8[K, N]  →  int8[K/bk, N/bn, bk, bn]   (tile-major, zero-padded once)

so each grid step's weight block is one contiguous ``(1, 1, bk, bn)`` slab —
no per-call transpose or padding, and the index map degenerates to a direct
tile lookup. The block sizes are derived exactly as the unpacked kernel
derives them from (K, N), so a repacked weight computes **bitwise-identical
int32** results (integer arithmetic, same block accumulation order).

The engine calls ``repack_weight`` once per weight inside its ``_weight_q``
cache; every subsequent FTE matmul on that weight skips straight to the
kernel.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant_matmul.quant_matmul import _ceil, _rup

__all__ = ["RepackedWeight", "repack_weight", "quant_matmul_repacked_call"]


class RepackedWeight(NamedTuple):
    """A weight laid out in the quant-matmul kernel's preferred tiling."""

    tiles: jnp.ndarray  # int8[K/bk, N/bn, bk, bn]
    k: int  # true (unpadded) K
    n: int  # true (unpadded) N
    block_k: int
    block_n: int


def repack_weight(
    w_q: jnp.ndarray,  # int8[K, N]
    *,
    block_n: int = 256,
    block_k: int = 512,
) -> RepackedWeight:
    """One-time layout transform into the kernel's (bk, bn) tile order.

    Block sizes match ``quant_matmul_kernel_call``'s derivation from (K, N),
    so the repacked kernel walks the identical block decomposition.
    """
    k, n = w_q.shape
    bk, bn = min(block_k, _rup(k)), min(block_n, _rup(n))
    kp, np_ = _ceil(k, bk) * bk, _ceil(n, bn) * bn
    if (kp, np_) != (k, n):
        w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
    tiles = w_q.reshape(kp // bk, bk, np_ // bn, bn).transpose(0, 2, 1, 3)
    return RepackedWeight(tiles=tiles, k=k, n=n, block_k=bk, block_n=bn)


def _kernel(a_ref, b_ref, out_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.int32),
        b_ref[0, 0].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "n", "block_k", "block_n", "block_m", "interpret"),
)
def _repacked_call(
    a_q: jnp.ndarray,  # int8[M, K]
    tiles: jnp.ndarray,  # int8[K/bk, N/bn, bk, bn]
    *,
    k: int,
    n: int,
    block_k: int,
    block_n: int,
    block_m: int,
    interpret: bool,
) -> jnp.ndarray:
    m = a_q.shape[0]
    bk, bn = block_k, block_n
    kp, np_ = tiles.shape[0] * bk, tiles.shape[1] * bn
    bm = min(block_m, _rup(m))
    mp = _ceil(m, bm) * bm
    if (mp, kp) != a_q.shape:
        a_q = jnp.pad(a_q, ((0, mp - m), (0, kp - a_q.shape[1])))
    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # direct tile lookup — the repack already ordered the blocks
            pl.BlockSpec((1, 1, bk, bn), lambda i, j, kk: (kk, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        name="ample_quant_matmul_repacked",
    )(a_q, tiles)
    return out[:m, :n]


def quant_matmul_repacked_call(
    a_q: jnp.ndarray,
    packed: RepackedWeight,
    *,
    block_m: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """int32[M, N] = a_q @ W from the pre-tiled layout; pads only ``a_q``."""
    if a_q.shape[1] != packed.k:
        raise ValueError(
            f"activation K={a_q.shape[1]} does not match repacked weight "
            f"K={packed.k}"
        )
    return _repacked_call(
        a_q,
        packed.tiles,
        k=packed.k,
        n=packed.n,
        block_k=packed.block_k,
        block_n=packed.block_n,
        block_m=block_m,
        interpret=interpret,
    )
