"""Pallas TPU kernel: int8 × int8 → int32 blocked matmul (the int8 FTE stream).

The transformation phase of unprotected (Degree-Quant int8) nodes runs here:
symmetric-quantized activations against per-channel-quantized weights, int32
accumulation, dequant outside. On real TPU the MXU executes int8 at twice the
bf16 rate, which is the throughput half of the paper's mixed-precision win
(the other half — 4× lighter gather traffic — lives in the AGE).

Blocking: grid = (M/BM, N/BN, K/BK), K fastest. A VMEM int32 accumulator is
zeroed at k==0 and flushed to the output on the last K step, so the output
block is written exactly once (standard TPU matmul pipeline; Mosaic overlaps
the HBM streams of A/B blocks with MXU work across grid steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["quant_matmul_kernel_call"]


def _kernel(a_ref, b_ref, out_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.int32),
        b_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def quant_matmul_kernel_call(
    a_q: jnp.ndarray,  # int8[M, K]
    b_q: jnp.ndarray,  # int8[K, N]
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """int32[M, N] = a_q @ b_q with int32 accumulation. Pads to block grid."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (k, k2)
    bm, bn, bk = min(block_m, _rup(m)), min(block_n, _rup(n)), min(block_k, _rup(k))
    mp, np_, kp = _ceil(m, bm) * bm, _ceil(n, bn) * bn, _ceil(k, bk) * bk
    if (mp, kp) != (m, k):
        a_q = jnp.pad(a_q, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b_q = jnp.pad(b_q, ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        name="ample_quant_matmul",
    )(a_q, b_q)
    return out[:m, :n]


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _rup(x: int, mult: int = 128) -> int:
    """Round up to the MXU lane multiple (int8 tiles want 128-aligned dims)."""
    return max(mult, _ceil(x, mult) * mult)
