"""Pure-jnp oracle for the segment-aggregation kernel (no Pallas)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "gather_segment_tiles_ref",
    "aggregate_tiles_ref",
    "attend_tiles_ref",
    "aggregate_tiles_mh_ref",
]


@functools.partial(jax.jit, static_argnames=("segments_per_tile",))
def gather_segment_tiles_ref(
    x: jnp.ndarray,
    gather_idx: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    *,
    segments_per_tile: int,
) -> jnp.ndarray:
    """f32[T, S, D] partial sums: for each tile, Σ_lanes coeff·x[idx] by seg."""

    def per_tile(idx_t, coeff_t, seg_t):
        gathered = x[idx_t] * coeff_t[:, None]  # [E, D]
        return jax.ops.segment_sum(
            gathered, seg_t, num_segments=segments_per_tile
        )

    return jax.vmap(per_tile)(gather_idx, coeff, seg_ids)


@functools.partial(jax.jit, static_argnames=("segments_per_tile", "num_nodes"))
def aggregate_tiles_ref(
    x: jnp.ndarray,
    gather_idx: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    out_node: jnp.ndarray,
    *,
    num_nodes: int,
    segments_per_tile: int,
) -> jnp.ndarray:
    """Full oracle including the partial-response scatter-add combine."""
    parts = gather_segment_tiles_ref(
        x, gather_idx, coeff, seg_ids, segments_per_tile=segments_per_tile
    )
    t, s, d = parts.shape
    out = jnp.zeros((num_nodes + 1, d), x.dtype)
    out = out.at[out_node.reshape(t * s)].add(parts.reshape(t * s, d))
    return out[:num_nodes]


@functools.partial(
    jax.jit,
    static_argnames=("num_nodes", "segments_per_tile", "leaky_slope"),
)
def attend_tiles_ref(
    z: jnp.ndarray,  # f32[N, H, dh]
    gather_idx: jnp.ndarray,  # int32[T, E]
    scores_t: jnp.ndarray,  # f32[T, E, H] raw scores, −inf padding lanes
    coeff: jnp.ndarray,  # f32[T, E]
    seg_ids: jnp.ndarray,  # int32[T, E]
    out_node: jnp.ndarray,  # int32[T, S]
    *,
    num_nodes: int,
    segments_per_tile: int,
    leaky_slope: float,
) -> jnp.ndarray:
    """Pure-jnp mirror of the fused attention kernel: same per-tile
    (m, l, a) decomposition, same cross-tile log-sum-exp combine."""
    from repro.kernels.segment_agg.attn_ops import combine_attention

    s = segments_per_tile

    def per_tile(idx_t, sc_t, cf_t, seg_t):
        sc = jnp.where(sc_t >= 0.0, sc_t, leaky_slope * sc_t)
        m = jax.ops.segment_max(sc, seg_t, num_segments=s)  # [S, H]
        m_fin = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(sc - m_fin[seg_t])
        l = jax.ops.segment_sum(p, seg_t, num_segments=s)
        wa = (p * cf_t[:, None])[:, :, None] * z[idx_t]  # [E, H, dh]
        a = jax.ops.segment_sum(wa, seg_t, num_segments=s)
        return m, l, a

    m, l, a = jax.vmap(per_tile)(gather_idx, scores_t, coeff, seg_ids)
    return combine_attention(
        m, l, a, out_node, num_nodes=num_nodes, dh=z.shape[-1]
    )


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "segments_per_tile")
)
def aggregate_tiles_mh_ref(
    x: jnp.ndarray,  # f32[N, H, dh]
    gather_idx: jnp.ndarray,  # int32[T, E]
    coeff: jnp.ndarray,  # f32[T, E, H]
    seg_ids: jnp.ndarray,  # int32[T, E]
    out_node: jnp.ndarray,  # int32[T, S]
    *,
    num_nodes: int,
    segments_per_tile: int,
) -> jnp.ndarray:
    """Oracle for the multi-head weighted aggregate: f32[num_nodes, H, dh]."""

    def per_tile(idx_t, cf_t, seg_t):
        wa = cf_t[:, :, None] * x[idx_t]  # [E, H, dh]
        return jax.ops.segment_sum(
            wa, seg_t, num_segments=segments_per_tile
        )

    parts = jax.vmap(per_tile)(gather_idx, coeff, seg_ids)  # [T, S, H, dh]
    t, s = parts.shape[:2]
    out = jnp.zeros((num_nodes + 1,) + parts.shape[2:], x.dtype)
    out = out.at[out_node.reshape(t * s)].add(
        parts.reshape((t * s,) + parts.shape[2:])
    )
    return out[:num_nodes]
