"""Pure-jnp oracle for the segment-aggregation kernel (no Pallas)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["gather_segment_tiles_ref", "aggregate_tiles_ref"]


@functools.partial(jax.jit, static_argnames=("segments_per_tile",))
def gather_segment_tiles_ref(
    x: jnp.ndarray,
    gather_idx: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    *,
    segments_per_tile: int,
) -> jnp.ndarray:
    """f32[T, S, D] partial sums: for each tile, Σ_lanes coeff·x[idx] by seg."""

    def per_tile(idx_t, coeff_t, seg_t):
        gathered = x[idx_t] * coeff_t[:, None]  # [E, D]
        return jax.ops.segment_sum(
            gathered, seg_t, num_segments=segments_per_tile
        )

    return jax.vmap(per_tile)(gather_idx, coeff, seg_ids)


@functools.partial(jax.jit, static_argnames=("segments_per_tile", "num_nodes"))
def aggregate_tiles_ref(
    x: jnp.ndarray,
    gather_idx: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    out_node: jnp.ndarray,
    *,
    num_nodes: int,
    segments_per_tile: int,
) -> jnp.ndarray:
    """Full oracle including the partial-response scatter-add combine."""
    parts = gather_segment_tiles_ref(
        x, gather_idx, coeff, seg_ids, segments_per_tile=segments_per_tile
    )
    t, s, d = parts.shape
    out = jnp.zeros((num_nodes + 1, d), x.dtype)
    out = out.at[out_node.reshape(t * s)].add(parts.reshape(t * s, d))
    return out[:num_nodes]
