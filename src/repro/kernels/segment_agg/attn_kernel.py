"""Pallas TPU kernels: fused multi-head graph attention over edge tiles.

One tile scan replaces GAT's four (LeakyReLU → segment-max → exp →
segment-sum → weighted aggregate): each grid step gathers the tile's
neighbour embeddings for **all heads at once** (rows packed ``[N, H·dhp]``),
applies LeakyReLU to the pre-scattered raw scores, reduces a tile-local
softmax triple on-chip, and emits per-tile partials

    m[t, s, h]        — tile-local segment max of the activated scores
    l[t, s, h]        — Σ exp(score − m) over the segment's lanes
    a[t, s, h·dhp]    — Σ coeff·exp(score − m)·x[idx] (numerator partials)

The cross-tile combine (flash-attention-style log-sum-exp rescale at the
partial-response scatter) runs in XLA — see ``attn_ops.attend_tiles``. The
decomposition is exact: rescaling by ``exp(m − M_global)`` makes the combined
(l, a) equal to the globally max-shifted sums, so the fused path computes the
same stable softmax as the four-pass oracle (up to float re-association
across tiles, which is why parity tests use the dense-reference tolerance
rather than bitwise equality).

Gather scaffolding (scalar-prefetched indices driving double-buffered
per-row DMAs) is identical to ``segment_agg.py`` — the AGE mechanisms carry
over; only the on-chip reduction changes. The head axis rides the lane
(last) dimension of the score/accumulator blocks; tile shapes stay static so
heads add zero launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_attention_tiles", "gather_weighted_tiles_mh"]


def _gather(idx_ref, x_hbm, xbuf, sems, *, t, num_tiles, e):
    """Double-buffered row gather; returns the slot holding tile ``t``."""

    def row_copy(tile, lane, slot):
        row = idx_ref[tile, lane]
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(row, 1), :],
            xbuf.at[slot, pl.ds(lane, 1), :],
            sems.at[slot],
        )

    def start_gather(tile, slot):
        def body(i, _):
            row_copy(tile, i, slot).start()
            return 0

        jax.lax.fori_loop(0, e, body, 0)

    def wait_gather(tile, slot):
        def body(i, _):
            row_copy(tile, i, slot).wait()
            return 0

        jax.lax.fori_loop(0, e, body, 0)

    slot = jax.lax.rem(t, 2)

    @pl.when(t == 0)
    def _():
        start_gather(0, 0)

    @pl.when(t + 1 < num_tiles)
    def _():
        start_gather(t + 1, 1 - slot)

    wait_gather(t, slot)
    return slot


def _fused_kernel(
    idx_ref,
    x_hbm,
    scores_ref,
    coeff_ref,
    segs_ref,
    m_ref,
    l_ref,
    a_ref,
    xbuf,
    sems,
    *,
    h: int,
    dhp: int,
    slope: float,
):
    t = pl.program_id(0)
    num_tiles = pl.num_programs(0)
    e = coeff_ref.shape[-1]
    s = m_ref.shape[1]

    slot = _gather(idx_ref, x_hbm, xbuf, sems, t=t, num_tiles=num_tiles, e=e)

    # LeakyReLU on raw scores; padding lanes arrive as −inf and stay −inf
    # (slope > 0), so they contribute exp(−inf − finite) = 0 downstream.
    sc = scores_ref[0]  # [E, H]
    sc = jnp.where(sc >= 0.0, sc, slope * sc)

    seg = segs_ref[0, :]
    onehot = jax.lax.broadcasted_iota(jnp.int32, (s, e), 0) == seg[None, :]
    oh = onehot.astype(jnp.float32)

    # Tile-local segment max per head, then broadcast back to lanes via the
    # MXU (onehotᵀ @ m) — where(isfinite) keeps empty segments from leaking
    # 0·(−inf) NaNs through the matmul.
    masked = jnp.where(onehot[:, :, None], sc[None, :, :], -jnp.inf)
    m = jnp.max(masked, axis=1)  # [S, H]
    m_fin = jnp.where(jnp.isfinite(m), m, 0.0)
    m_lane = jnp.dot(oh.transpose(), m_fin, preferred_element_type=jnp.float32)

    p = jnp.exp(sc - m_lane)  # [E, H]
    l_ref[0] = jnp.dot(oh, p, preferred_element_type=jnp.float32)

    # Numerator partials: static lane coeff multiplies post-softmax (the
    # oracle's aggregate semantics — the denominator stays Σ exp, unscaled).
    w = p * coeff_ref[0][:, None]  # [E, H]
    xb = xbuf[slot].reshape(e, h, dhp)
    wa = (w[:, :, None] * xb).reshape(e, h * dhp)
    a_ref[0] = jnp.dot(oh, wa, preferred_element_type=jnp.float32)
    m_ref[0] = m


def _mh_kernel(
    idx_ref, x_hbm, coeff_ref, segs_ref, parts_ref, xbuf, sems, *, h: int, dhp: int
):
    t = pl.program_id(0)
    num_tiles = pl.num_programs(0)
    e = segs_ref.shape[-1]
    s = parts_ref.shape[1]

    slot = _gather(idx_ref, x_hbm, xbuf, sems, t=t, num_tiles=num_tiles, e=e)

    seg = segs_ref[0, :]
    oh = (
        jax.lax.broadcasted_iota(jnp.int32, (s, e), 0) == seg[None, :]
    ).astype(jnp.float32)
    w = coeff_ref[0]  # [E, H]
    xb = xbuf[slot].reshape(e, h, dhp)
    wa = (w[:, :, None] * xb).reshape(e, h * dhp)
    parts_ref[0] = jnp.dot(oh, wa, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("segments_per_tile", "leaky_slope", "interpret"),
)
def fused_attention_tiles(
    x: jnp.ndarray,  # f32[N, H·dhp] (head-packed, dh padded to dhp)
    gather_idx: jnp.ndarray,  # int32[T, E]
    scores_t: jnp.ndarray,  # f32[T, E, H] raw scores, −inf padding lanes
    coeff: jnp.ndarray,  # f32[T, E] static lane coeff
    seg_ids: jnp.ndarray,  # int32[T, E]
    *,
    segments_per_tile: int,
    leaky_slope: float,
    interpret: bool = True,
):
    """One fused pass → per-tile softmax partials (m, l, a).

    Returns ``(m f32[T, S, H], l f32[T, S, H], a f32[T, S, H·dhp])``; the
    caller owns the cross-tile log-sum-exp combine and the dhp→dh unpad.
    """
    t, e, h = scores_t.shape
    s = segments_per_tile
    d = x.shape[1]
    dhp = d // h

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # x stays in HBM
            pl.BlockSpec((1, e, h), lambda tt, idx: (tt, 0, 0)),  # scores
            pl.BlockSpec((1, e), lambda tt, idx: (tt, 0)),  # coeff
            pl.BlockSpec((1, e), lambda tt, idx: (tt, 0)),  # seg_ids
        ],
        out_specs=(
            pl.BlockSpec((1, s, h), lambda tt, idx: (tt, 0, 0)),  # m
            pl.BlockSpec((1, s, h), lambda tt, idx: (tt, 0, 0)),  # l
            pl.BlockSpec((1, s, d), lambda tt, idx: (tt, 0, 0)),  # a
        ),
        scratch_shapes=[
            pltpu.VMEM((2, e, d), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, h=h, dhp=dhp, slope=leaky_slope),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((t, s, h), jnp.float32),
            jax.ShapeDtypeStruct((t, s, h), jnp.float32),
            jax.ShapeDtypeStruct((t, s, d), jnp.float32),
        ),
        interpret=interpret,
        name="ample_fused_attention",
    )(gather_idx, x, scores_t, coeff, seg_ids)


@functools.partial(
    jax.jit, static_argnames=("segments_per_tile", "interpret")
)
def gather_weighted_tiles_mh(
    x: jnp.ndarray,  # f32[N, H·dhp]
    gather_idx: jnp.ndarray,  # int32[T, E]
    coeff: jnp.ndarray,  # f32[T, E, H] per-head lane coefficients
    seg_ids: jnp.ndarray,  # int32[T, E]
    *,
    segments_per_tile: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Multi-head weighted segment reduce: f32[T, S, H·dhp] partials."""
    t, e, h = coeff.shape
    s = segments_per_tile
    d = x.shape[1]
    dhp = d // h

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, e, h), lambda tt, idx: (tt, 0, 0)),  # coeff
            pl.BlockSpec((1, e), lambda tt, idx: (tt, 0)),  # seg_ids
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda tt, idx: (tt, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, e, d), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mh_kernel, h=h, dhp=dhp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, s, d), jnp.float32),
        interpret=interpret,
        name="ample_gather_segment_agg_mh",
    )(gather_idx, x, coeff, seg_ids)
