"""Public wrappers for the fused multi-head attention kernel.

``attend_tiles`` is the one-launch GAT layer: raw (pre-LeakyReLU) scores in
tile layout, head-stacked embeddings in, softmax-normalized aggregates out.
The Pallas kernel emits per-tile softmax partials (tile-local max ``m``,
exp-sum ``l``, weighted numerator ``a``); the cross-tile combine here is the
flash-attention identity at the partial-response scatter:

    M[n]     = max over tiles of m                      (scatter-max)
    L[n]     = Σ l · exp(m − M[n])                      (rescaled scatter-add)
    A[n]     = Σ a · exp(m − M[n])
    out[n]   = A[n] / L[n]

which equals the globally max-shifted softmax aggregate exactly (up to the
float re-association of summing tiles in a different grouping than the
oracle's two global passes).

``aggregate_tiles_mh`` is the multi-head analogue of ``ops.aggregate_tiles``
for already-normalized per-head coefficients. Both fall back to interpret
mode automatically off-TPU. Head packing pads dh up to a 128-lane multiple
so each DMA'd row is MXU/VPU aligned; the pad is sliced off after combine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_agg.attn_kernel import (
    fused_attention_tiles,
    gather_weighted_tiles_mh,
)

__all__ = ["attend_tiles", "aggregate_tiles_mh", "combine_attention", "pack_heads"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dhp(dh: int) -> int:
    return max(128, ((dh + 127) // 128) * 128)


def pack_heads(z: jnp.ndarray) -> jnp.ndarray:
    """f32[N, H, dh] → f32[N, H·dhp] with dh zero-padded to a 128 multiple."""
    n, h, dh = z.shape
    dhp = _dhp(dh)
    if dhp != dh:
        z = jnp.pad(z, ((0, 0), (0, 0), (0, dhp - dh)))
    return z.reshape(n, h * dhp)


def combine_attention(
    m: jnp.ndarray,  # f32[T, S, H]
    l: jnp.ndarray,  # f32[T, S, H]
    a: jnp.ndarray,  # f32[T, S, H, dhp]
    out_node: jnp.ndarray,  # int32[T, S]
    *,
    num_nodes: int,
    dh: int,
) -> jnp.ndarray:
    """Cross-tile log-sum-exp combine → f32[num_nodes, H, dh]."""
    t, s, h = m.shape
    flat = out_node.reshape(t * s)
    mf = m.reshape(t * s, h)
    big_m = jnp.full((num_nodes + 1, h), -jnp.inf).at[flat].max(mf)
    big_m = jnp.where(jnp.isfinite(big_m), big_m, 0.0)
    # Empty-segment partials carry m = −inf → scale 0, so they vanish here.
    scale = jnp.exp(mf - big_m[flat])
    big_l = (
        jnp.zeros((num_nodes + 1, h)).at[flat].add(l.reshape(t * s, h) * scale)
    )
    big_a = (
        jnp.zeros((num_nodes + 1, h, a.shape[-1]))
        .at[flat]
        .add(a.reshape(t * s, h, -1) * scale[:, :, None])
    )
    denom = jnp.where(big_l > 0, big_l, 1.0)
    return (big_a / denom[:, :, None])[:num_nodes, :, :dh]


@functools.partial(
    jax.jit,
    static_argnames=("num_nodes", "segments_per_tile", "leaky_slope", "interpret"),
)
def attend_tiles(
    z: jnp.ndarray,  # f32[N, H, dh]
    gather_idx: jnp.ndarray,  # int32[T, E]
    scores_t: jnp.ndarray,  # f32[T, E, H] raw scores, −inf on padding lanes
    coeff: jnp.ndarray,  # f32[T, E] static lane coeff
    seg_ids: jnp.ndarray,  # int32[T, E]
    out_node: jnp.ndarray,  # int32[T, S]
    *,
    num_nodes: int,
    segments_per_tile: int,
    leaky_slope: float,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused GAT layer: softmax(LeakyReLU(scores)) aggregate, one launch.

    Returns f32[num_nodes, H, dh].
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, h, dh = z.shape
    xp = pack_heads(z)
    m, l, a = fused_attention_tiles(
        xp,
        gather_idx,
        scores_t,
        coeff,
        seg_ids,
        segments_per_tile=segments_per_tile,
        leaky_slope=leaky_slope,
        interpret=interpret,
    )
    t, s, _ = m.shape
    return combine_attention(
        m,
        l,
        a.reshape(t, s, h, -1),
        out_node,
        num_nodes=num_nodes,
        dh=dh,
    )


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "segments_per_tile", "interpret")
)
def aggregate_tiles_mh(
    x: jnp.ndarray,  # f32[N, H, dh]
    gather_idx: jnp.ndarray,  # int32[T, E]
    coeff: jnp.ndarray,  # f32[T, E, H] per-head lane coefficients
    seg_ids: jnp.ndarray,  # int32[T, E]
    out_node: jnp.ndarray,  # int32[T, S]
    *,
    num_nodes: int,
    segments_per_tile: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Multi-head event-driven aggregation → f32[num_nodes, H, dh]."""
    if interpret is None:
        interpret = not _on_tpu()
    n, h, dh = x.shape
    xp = pack_heads(x)
    parts = gather_weighted_tiles_mh(
        xp,
        gather_idx,
        coeff,
        seg_ids,
        segments_per_tile=segments_per_tile,
        interpret=interpret,
    )
    t, s, d = parts.shape
    out = jnp.zeros((num_nodes + 1, d), x.dtype)
    out = out.at[out_node.reshape(t * s)].add(parts.reshape(t * s, d))
    return out[:num_nodes].reshape(num_nodes, h, -1)[:, :, :dh]
