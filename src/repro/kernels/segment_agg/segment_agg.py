"""Pallas TPU kernel: event-driven gather + segment-reduce (the AGE).

This kernel is the hardware heart of the reproduction — it implements, in TPU
terms, all three of AMPLE's circuit mechanisms at once:

* **Address Queue → Message Queue** (Figure 3): the tile's neighbour indices
  arrive via *scalar prefetch* (SMEM, available before the grid step runs) and
  drive per-row async DMAs from HBM into a VMEM message buffer.
* **Fetch-Tag prefetch / partial response** (§3.3): the gather for tile t+1 is
  *started* before tile t is reduced, into the alternate half of a
  double-buffered VMEM scratch — memory latency hides behind compute exactly
  as the Feature Bank hides it behind aggregation.
* **Aggregation NoC → MXU** (§3.2): the per-tile segment reduction is cast as
  a one-hot × messages matmul, P[s,e] = coeff[e]·(seg[e]==s), so the MXU does
  the permutation-invariant sum at full throughput instead of a lane-serial
  scatter.

Tile shapes are static (from the ExecutionPlan), so the kernel is a fixed
pipeline; the irregularity lives entirely in the prefetched index stream.

Layout:
  grid = (D // BD, T)   — t varies fastest, so the double buffer alternates
                          across consecutive tiles within one feature block.
  x         : ANY (HBM) f32[N, D_pad]          (full array, DMA'd row-wise)
  gather_idx: scalar-prefetch int32[T, E]
  coeff     : VMEM f32[1, E] per step
  seg_ids   : VMEM int32[1, E] per step
  parts     : VMEM out f32[1, S, BD] per step
  scratch   : xbuf f32[2, E, BD], sem DMA[2]
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_segment_tiles", "DEFAULT_BLOCK_D"]

DEFAULT_BLOCK_D = 512


def _kernel(idx_ref, x_hbm, coeff_ref, segs_ref, parts_ref, xbuf, sems, *, bd: int):
    j = pl.program_id(0)  # feature block
    t = pl.program_id(1)  # tile (fastest)
    num_tiles = pl.num_programs(1)
    e = coeff_ref.shape[-1]
    s = parts_ref.shape[1]
    d0 = j * bd

    def row_copy(tile, lane, slot):
        row = idx_ref[tile, lane]
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(row, 1), pl.ds(d0, bd)],
            xbuf.at[slot, pl.ds(lane, 1), :],
            sems.at[slot],
        )

    def start_gather(tile, slot):
        def body(i, _):
            row_copy(tile, i, slot).start()
            return 0

        jax.lax.fori_loop(0, e, body, 0)

    def wait_gather(tile, slot):
        def body(i, _):
            row_copy(tile, i, slot).wait()
            return 0

        jax.lax.fori_loop(0, e, body, 0)

    slot = jax.lax.rem(t, 2)

    # Warm-up: first tile of this feature block fetches synchronously.
    @pl.when(t == 0)
    def _():
        start_gather(0, 0)

    # Fetch-tag prefetch: next tile's messages start flowing now.
    @pl.when(t + 1 < num_tiles)
    def _():
        start_gather(t + 1, 1 - slot)

    wait_gather(t, slot)

    # Segment reduce on the MXU: P[s, e] = coeff[e] * (seg_ids[e] == s).
    seg = segs_ref[0, :]
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (s, e), 0)
    p = jnp.where(s_iota == seg[None, :], coeff_ref[0, :][None, :], 0.0)
    parts_ref[0] = jnp.dot(p, xbuf[slot], preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("segments_per_tile", "block_d", "interpret")
)
def gather_segment_tiles(
    x: jnp.ndarray,  # f32[N, D]
    gather_idx: jnp.ndarray,  # int32[T, E]
    coeff: jnp.ndarray,  # f32[T, E]
    seg_ids: jnp.ndarray,  # int32[T, E]
    *,
    segments_per_tile: int,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns per-tile partial sums f32[T, S, D]."""
    n, d = x.shape
    t, e = gather_idx.shape
    s = segments_per_tile
    d_pad = max(block_d, ((d + 127) // 128) * 128)
    bd = min(block_d, d_pad)
    d_pad = ((d_pad + bd - 1) // bd) * bd
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, d_pad - d)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(d_pad // bd, t),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # x stays in HBM
            # index maps receive the scalar-prefetch ref as a trailing arg
            pl.BlockSpec((1, e), lambda j, tt, idx: (tt, 0)),  # coeff
            pl.BlockSpec((1, e), lambda j, tt, idx: (tt, 0)),  # seg_ids
        ],
        out_specs=pl.BlockSpec((1, s, bd), lambda j, tt, idx: (tt, 0, j)),
        scratch_shapes=[
            pltpu.VMEM((2, e, bd), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    parts = pl.pallas_call(
        functools.partial(_kernel, bd=bd),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, s, d_pad), jnp.float32),
        interpret=interpret,
        name="ample_gather_segment_agg",
    )(gather_idx, x, coeff, seg_ids)
    return parts[:, :, :d]
