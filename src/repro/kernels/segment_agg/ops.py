"""jit'd public wrapper for the AGE kernel: tiles in, node aggregates out.

The Pallas kernel produces per-tile partial sums; the partial-response combine
(scatter-add of split-node partials) runs in XLA, which on TPU lowers to an
efficient dynamic-update stream. Falls back to interpret mode automatically
off-TPU so the same call site works everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_agg.segment_agg import (
    DEFAULT_BLOCK_D,
    gather_segment_tiles,
)

__all__ = ["aggregate_tiles"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("num_nodes", "segments_per_tile", "block_d", "interpret"),
)
def aggregate_tiles(
    x: jnp.ndarray,
    gather_idx: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    out_node: jnp.ndarray,
    *,
    num_nodes: int,
    segments_per_tile: int,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Event-driven aggregation via the Pallas AGE kernel. f32[num_nodes, D]."""
    if interpret is None:
        interpret = not _on_tpu()
    parts = gather_segment_tiles(
        x,
        gather_idx,
        coeff,
        seg_ids,
        segments_per_tile=segments_per_tile,
        block_d=block_d,
        interpret=interpret,
    )
    t, s, d = parts.shape
    out = jnp.zeros((num_nodes + 1, d), x.dtype)
    out = out.at[out_node.reshape(t * s)].add(parts.reshape(t * s, d))
    return out[:num_nodes]
