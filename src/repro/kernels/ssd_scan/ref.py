"""Pure-jnp oracle for the SSD intra-chunk kernel (mirrors mamba_apply)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_intra_chunk_ref"]


@jax.jit
def ssd_intra_chunk_ref(cc, bc, xdt, acum):
    """cc/bc [B,NC,Q,N]; xdt [B,NC,H,Q,P]; acum [B,NC,H,Q] -> [B,NC,H,Q,P]."""
    q = cc.shape[2]
    li = acum[..., :, None] - acum[..., None, :]  # [B,NC,H,Q,Q]
    iota = jnp.arange(q)
    causal = iota[:, None] >= iota[None, :]
    lmat = jnp.where(causal, jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # [B,NC,Q,Q]
    return jnp.einsum("bcij,bchij,bchjp->bchip", cb, lmat, xdt)
