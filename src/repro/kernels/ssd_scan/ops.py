"""jit'd public wrapper for the SSD intra-chunk kernel (interpret off-TPU)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_intra_chunk_call

__all__ = ["ssd_intra_chunk"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(cc, bc, xdt, acum, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_intra_chunk_call(cc, bc, xdt, acum, interpret=interpret)
