"""Pallas TPU kernel: SSD intra-chunk block (Mamba2's compute hot-spot).

The chunked SSD algorithm (models/lm/mamba.py) spends its FLOPs in the
intra-chunk "attention-like" term

    Y_diag[c] = ( (C_c B_cᵀ) ∘ L_c ) @ X_c·dt_c,   L_c[i,j] = e^{a_i - a_j}·1[i≥j]

This kernel fuses the three steps — CBᵀ matmul, decay-mask multiply, and the
value matmul — per (batch, chunk, head) grid cell, keeping the [Q, Q] score
block in VMEM (never HBM). The inter-chunk recurrence stays in XLA (a scan
with tiny state). Grid: (B, NC, H); blocks: C/B tiles [Q, N] shared across
heads (G=1 as in the 370m config), X·dt and the log-decay vector per head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_intra_chunk_call"]


def _kernel(cc_ref, bc_ref, xdt_ref, acum_ref, out_ref):
    q = cc_ref.shape[2]
    cc = cc_ref[0, 0].astype(jnp.float32)        # [Q, N]
    bc = bc_ref[0, 0].astype(jnp.float32)        # [Q, N]
    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)   # [Q, P]
    a = acum_ref[0, 0, 0].astype(jnp.float32)    # [Q]
    cb = jnp.dot(cc, bc.T, preferred_element_type=jnp.float32)  # [Q, Q]
    li = a[:, None] - a[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(iota_i >= iota_j, jnp.exp(li), 0.0)
    out_ref[0, 0, 0] = jnp.dot(cb * lmat, xdt, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_call(
    cc: jnp.ndarray,    # f32[B, NC, Q, N]
    bc: jnp.ndarray,    # f32[B, NC, Q, N]
    xdt: jnp.ndarray,   # f32[B, NC, H, Q, P]  (dt already folded in)
    acum: jnp.ndarray,  # f32[B, NC, H, Q]     (cumulative log-decay)
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    b, nc, q, n = cc.shape
    h, p = xdt.shape[2], xdt.shape[4]
    grid = (b, nc, h)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, n), lambda bb, c, hh: (bb, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bb, c, hh: (bb, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, p), lambda bb, c, hh: (bb, c, hh, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bb, c, hh: (bb, c, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q, p), lambda bb, c, hh: (bb, c, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc, h, q, p), jnp.float32),
        interpret=interpret,
        name="ssd_intra_chunk",
    )(cc, bc, xdt, acum)
