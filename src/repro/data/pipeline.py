"""Deterministic synthetic token pipeline: seeded, shardable, restartable.

A real deployment swaps `synthetic_batches` for a file-backed reader; the
contract is the generator protocol: (step -> batch) pure in (seed, step), so
restart-from-checkpoint replays identical data without persisted reader state
— the simplest fault-tolerant data-pipeline design.
Targets are a fixed affine-permutation sequence model so loss measurably
drops: next = (a*tok + b) mod V with per-stream (a, b).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

__all__ = ["synthetic_batch", "synthetic_batches"]


def synthetic_batch(
    *, seed: int, step: int, batch: int, seq: int, vocab: int,
    family: str = "dense", d_model: int = 0,
) -> Dict[str, np.ndarray]:
    # the affine map is a function of SEED ONLY (stationary, learnable);
    # starting tokens vary per step so batches differ.
    rng_task = np.random.default_rng(np.random.SeedSequence([seed]))
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    a = int(2 * rng_task.integers(1, max(vocab // 2, 2)) + 1)  # odd => invertible
    b = int(rng_task.integers(0, vocab))
    t0 = rng.integers(0, vocab, (batch, 1))
    toks = np.zeros((batch, seq + 1), np.int64)
    toks[:, 0:1] = t0
    for i in range(seq):
        toks[:, i + 1 : i + 2] = (a * toks[:, i : i + 1] + b) % vocab
    out = {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }
    if family in ("audio",):  # enc-dec: synthetic frontend embeddings
        out["src_embeds"] = rng.standard_normal((batch, seq, d_model)).astype(
            np.float32
        )
        out["tgt_tokens"] = out.pop("tokens")
    if family in ("vlm",) and d_model:
        out["embeds"] = rng.standard_normal((batch, seq, d_model)).astype(np.float32)
        out.pop("tokens")
    return out


def synthetic_batches(start_step: int = 0, **kw) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(step=step, **kw)
        step += 1
