"""Collective matmul: overlap TP collectives with MXU work (beyond-paper opt).

XLA schedules the TP all-gather/reduce-scatter around each sharded matmul
back-to-back: AG, then dot. The *collective matmul* (Wang et al., ASPLOS'23;
used by MaxText/Megatron) decomposes the collective into a ring of
``ppermute`` steps and multiplies each arriving chunk immediately — the
transfer of chunk i+1 rides under the matmul of chunk i, hiding up to
(n-1)/n of the collective term behind compute.

Expressed with ``shard_map`` so the schedule is explicit rather than left to
the XLA latency-hiding scheduler. These are the §Perf iteration levers for
collective-bound cells; numerics are validated against plain sharded matmuls
in tests on a faked multi-device backend.

``allgather_matmul``      y[M, N/n]  = (AG_rows x)[M, K] @ w[K, N/n]
                          (x arrives row-sharded — the SP residual layout)
``reduce_scatter_matmul`` y[M/n, N]  = RS_rows(Σ_k x[M, K/n] @ w[K/n, N])
                          (the down-projection / row-parallel side)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import pcast_varying, shard_map

__all__ = ["allgather_matmul", "reduce_scatter_matmul"]


def allgather_matmul(x, w, mesh, *, axis: str = "model"):
    """Ring-pipelined ``all_gather(x, rows) @ w``.

    x: [M, K] sharded on rows over ``axis`` (local [M/n, K]);
    w: [K, N] sharded on cols over ``axis`` (local [K, N/n]);
    y: [M, N] sharded on cols (local [M, N/n]).

    At ring step s, device d holds the x block that originated at device
    (d + s) mod n; it multiplies it against its local w and writes the
    product into the matching row band of y while the block moves on.
    """
    n = mesh.shape[axis]

    def local(x_loc, w_loc):
        idx = jax.lax.axis_index(axis)
        m_loc = x_loc.shape[0]

        def step(s, carry):
            y, blk = carry
            src = jax.lax.rem(idx + s, n)  # owner of the block we hold
            band = jnp.einsum("mk,kn->mn", blk, w_loc)
            y = jax.lax.dynamic_update_slice_in_dim(y, band, src * m_loc, axis=0)
            blk = jax.lax.ppermute(
                blk, axis, [(i, (i - 1) % n) for i in range(n)]
            )
            return y, blk

        y0 = pcast_varying(
            jnp.zeros((m_loc * n, w_loc.shape[-1]), x_loc.dtype), (axis,)
        )
        y, _ = jax.lax.fori_loop(0, n, step, (y0, x_loc))
        return y

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis),
    )
    return fn(x, w)


def reduce_scatter_matmul(x, w, mesh, *, axis: str = "model"):
    """Ring-pipelined ``reduce_scatter_rows(x @ w)`` for K-sharded operands.

    x: [M, K] sharded on K (local [M, K/n]); w: [K, N] sharded on K rows
    (local [K/n, N]); y: [M, N] sharded on rows (local [M/n, N]).

    The local partial product is computed one M-band at a time in ring order
    (receive-accumulate-forward), so each band's transfer overlaps the next
    band's matmul. After n steps device d holds Σ_j x_j[band_d] @ w_j.
    """
    n = mesh.shape[axis]

    def local(x_loc, w_loc):
        idx = jax.lax.axis_index(axis)
        m = x_loc.shape[0]
        chunk = m // n

        def step(s, acc):
            acc = jax.lax.ppermute(
                acc, axis, [(i, (i + 1) % n) for i in range(n)]
            )
            c = jax.lax.rem(idx - s - 1 + 2 * n, n)  # band index this step
            blk = jax.lax.dynamic_slice_in_dim(x_loc, c * chunk, chunk, axis=0)
            return acc + jnp.einsum("mk,kn->mn", blk, w_loc)

        acc0 = pcast_varying(
            jnp.zeros((chunk, w_loc.shape[-1]), x_loc.dtype), (axis,)
        )
        return jax.lax.fori_loop(0, n, step, acc0)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None),
    )
    return fn(x, w)
