"""Gradient compression for slow inter-pod links: top-k + EF, int8 + SR.

Data parallelism spans pods over DCN-class links (launch/mesh.py), so the
gradient all-reduce is the one collective that crosses the slow domain. Two
standard compressors, both with **error feedback** (the residual of what
compression dropped is added back next step — provably preserves SGD
convergence):

* ``TopKCompressor``  — keep the k largest-|g| entries per tensor. On the
  wire this is (values, indices): 2·k·4 bytes vs n·4, a n/(2k) reduction.
* ``Int8Compressor``  — per-tensor symmetric int8 with *stochastic rounding*
  (unbiased: E[q] = g), 4× reduction with no index overhead.

``compress_decompress`` returns the gradients as the receiving end would see
them — in SPMD the all-reduce happens over the compressed representation; the
roundtrip here is the numerics contract the tests verify (compression error
is bounded and EF drives the accumulated bias to zero).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["TopKCompressor", "Int8Compressor", "wire_bytes_ratio"]


@dataclasses.dataclass(frozen=True)
class TopKCompressor:
    """Keep top ``ratio`` fraction of entries per leaf (by magnitude)."""

    ratio: float = 0.01

    def init_state(self, grads) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress_decompress(self, grads, state: Optional[Any]) -> Tuple[Any, Any]:
        if state is None:
            state = self.init_state(grads)

        def one(g, err):
            g32 = g.astype(jnp.float32) + err  # error feedback
            flat = g32.reshape(-1)
            k = max(1, int(flat.shape[0] * self.ratio))
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            mask = jnp.abs(flat) >= thresh
            sent = jnp.where(mask, flat, 0.0)
            new_err = (flat - sent).reshape(g.shape)
            return sent.reshape(g.shape).astype(g.dtype), new_err

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(state)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """Per-tensor symmetric int8 with stochastic rounding + error feedback."""

    seed: int = 0

    def init_state(self, grads) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress_decompress(self, grads, state: Optional[Any]) -> Tuple[Any, Any]:
        if state is None:
            state = self.init_state(grads)
        key = jax.random.PRNGKey(self.seed)

        def one(i, g, err):
            g32 = g.astype(jnp.float32) + err
            scale = jnp.maximum(jnp.max(jnp.abs(g32)) / 127.0, 1e-12)
            x = g32 / scale
            lo = jnp.floor(x)
            p = x - lo  # stochastic rounding: E[q] = x
            u = jax.random.uniform(jax.random.fold_in(key, i), x.shape)
            q = jnp.clip(lo + (u < p), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq.astype(g.dtype), g32 - deq

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(state)
        outs = [one(i, g, e) for i, (g, e) in enumerate(zip(flat_g, flat_e))]
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )


def wire_bytes_ratio(compressor) -> float:
    """Bytes-on-wire ratio vs raw f32 all-reduce (for the roofline DP term)."""
    if isinstance(compressor, TopKCompressor):
        return 2.0 * compressor.ratio  # values + indices
    if isinstance(compressor, Int8Compressor):
        return 0.25
    return 1.0
