"""Version compatibility for the manual-sharding API surface.

The distributed modules are written against the modern spelling
(``jax.shard_map``, ``jax.lax.pcast(..., to="varying")``). On the pinned
CPU toolchain (jax 0.4.x) those live under ``jax.experimental.shard_map``
and ``pcast`` does not exist — there, replication checking is disabled
instead, which makes the "mark as varying" cast unnecessary.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "pcast_varying"]


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` when available, else the jax 0.4 experimental one."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def pcast_varying(x, axes):
    """Mark ``x`` as varying over ``axes`` (no-op where pcast is absent)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to="varying")
    return x
