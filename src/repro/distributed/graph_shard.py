"""Sharded GNN layer execution — the cluster-level Feature Bank.

``ShardedAmpleEngine`` executes a ``ShardedExecutionPlan``: each shard owns an
edge-balanced node block (contiguous, or a min-cut assignment carried by
``Partition.order``); before aggregating, it fetches the embeddings of its
remote ("halo") neighbours — the distributed analogue of AMPLE's Feature Bank
fetching off-chip rows — then runs its own event-driven mixed-precision
aggregation over its local subgraph and writes exactly its owned output rows.
Per-node transformations (FTE) are row-parallel and stay on the regular
mixed-precision path.

Two execution backends, numerically interchangeable:

* **host loop** (default) — one shard at a time on the local device. Works on
  a single-device CPU, and is what the serving engine uses; the halo gather is
  an explicit ``x[halo_ids]`` row fetch. With ``halo_overlap`` the gather runs
  on a worker thread while the shard's *interior* tiles (no halo sources —
  ``scheduler.split_plan_by_halo``) aggregate in flight; the boundary tiles
  then continue from the interior accumulator, bitwise-identical to the
  unsplit scan. ``halo_ms``/``halo_wait_ms`` are wall-clock truth: the fetch
  is fenced and timestamped on the worker, the consumer measures its actual
  blocking wait — the same accounting contract as the out-of-core
  ``prefetch_overlap``.
* **shard_map** — SPMD over a 1-D ``("shard",)`` device mesh with one device
  per shard (CPU host-device simulation, as in ``test_distributed``). Owned
  rows live sharded; the halo exchange is a ``lax.all_gather`` of the owned
  blocks followed by a (owner, row) gather, and each device scans its own
  padded edge tiles. Runtime per-edge coefficients (GAT attention) ride along
  as a padded per-shard operand ``[K, e_max(, H)]`` scattered through the
  tiles' ``edge_ids`` — bitwise-equal to the host loop. Under
  ``halo_overlap`` the tile scan is split interior/boundary inside the SPMD
  body with the all-gather issued first, so the compiler is free to overlap
  the collective with the interior scan.

Activation quantization uses a *global* scale/zero-point (calibrated over the
full embedding matrix, exactly as the unsharded engine does), so every shard
quantizes identically and sharded output matches unsharded output to float
accumulation order.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    aggregate_edge_tiles,
    aggregate_mixed_precision,
    to_device_plan,
)
from repro.core.message_passing import (
    AmpleEngine,
    ShardedExecutionPlan,
    compile_sharded_plans,
)
from repro.core import scheduler as sched
from repro.core.quantization import QuantParams, dequantize, quantize
from repro.distributed.compat import shard_map
from repro.graphs.csr import Graph
from repro.observe import trace as otrace

__all__ = ["ShardedAmpleEngine", "sharded_aggregate", "build_mesh_state"]


# One worker is enough: the host loop is serialized per shard, and a single
# thread lets shard k+1's halo fetch overlap shard k's boundary compute.
_HALO_POOL: Optional[ThreadPoolExecutor] = None


def _halo_pool() -> ThreadPoolExecutor:
    global _HALO_POOL
    if _HALO_POOL is None:
        _HALO_POOL = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="halo"
        )
    return _HALO_POOL


def _note_halo(stats: Optional[Dict[str, float]], **delta: float) -> None:
    if stats is None:
        return
    for k, v in delta.items():
        stats[k] = stats.get(k, 0.0) + v


# ---------------------------------------------------------------------------
# Host-loop backend: one shard at a time on the local device
# ---------------------------------------------------------------------------


def _shard_state_entry(state: Dict, sp, mode: str, *, with_edge_ids: bool):
    """The per-shard device cache entry (local_ids, plans, dplans).

    One fill/upgrade rule for every consumer of the ``("host", fingerprint,
    mode)`` key: built on first use, and upgraded in place with the
    ``edge_ids`` indirection map when a runtime-coefficient pass needs it
    after static-coeff traffic populated the entry without one.
    """
    key = ("host", sp.fingerprint, mode)
    entry = state.get(key)
    if entry is None:
        plans = sp.plan.mode_plans.get(mode)
        if plans is None:
            raise KeyError(
                f"shard {sp.shard.index} was compiled for modes "
                f"{sp.plan.modes}, not {mode!r}; recompile the sharded "
                f"plan with this mode"
            )
        entry = (
            jnp.asarray(sp.shard.local_ids, jnp.int32),
            plans,
            {
                tag: to_device_plan(p, with_edge_ids=with_edge_ids)
                for tag, p in plans.items()
            },
        )
        state[key] = entry
    elif with_edge_ids and any(d.edge_ids is None for d in entry[2].values()):
        entry = (
            entry[0],
            entry[1],
            {tag: to_device_plan(p) for tag, p in entry[1].items()},
        )
        state[key] = entry
    return entry


def _local_edge_coeff(state: Dict, sp, edge_coeff: jnp.ndarray) -> jnp.ndarray:
    """This shard's slice of a global per-edge vector, on device.

    Contiguous partitions slice ``edge_range``; min-cut partitions gather
    through the shard's cached ``edge_idx`` map (global CSR positions in
    local edge order).
    """
    if sp.shard.edge_range is not None:
        e_lo, e_hi = sp.shard.edge_range
        return jax.lax.slice_in_dim(edge_coeff, e_lo, e_hi)
    key = ("edge_idx", sp.fingerprint)
    idx = state.get(key)
    if idx is None:
        idx = jnp.asarray(sp.shard.edge_idx, jnp.int32)
        state[key] = idx
    return edge_coeff[idx]


def _shard_split_entry(state: Dict, sp, mode: str, *, with_edge_ids: bool):
    """Interior/boundary split artifacts for the overlapped halo exchange.

    Per (shard, mode): owned/halo gather ids and the two plan halves per
    precision tag (empty halves omitted), with device mirrors. Built once,
    reused across requests like the unsplit entry.
    """
    key = ("split", sp.fingerprint, mode, bool(with_edge_ids))
    entry = state.get(key)
    if entry is None:
        _, plans, _ = _shard_state_entry(
            state, sp, mode, with_edge_ids=with_edge_ids
        )
        owned = sp.num_owned
        plans_int: Dict[str, sched.EdgeTilePlan] = {}
        plans_bnd: Dict[str, sched.EdgeTilePlan] = {}
        for tag, p in plans.items():
            p_int, p_bnd = sched.split_plan_by_halo(p, owned)
            if p_int.num_tiles:
                plans_int[tag] = p_int
            if p_bnd.num_tiles:
                plans_bnd[tag] = p_bnd
        entry = {
            "owned": jnp.asarray(sp.shard.local_ids[:owned], jnp.int32),
            "halo": jnp.asarray(sp.shard.local_ids[owned:], jnp.int32),
            "plans_int": plans_int,
            "plans_bnd": plans_bnd,
            "d_int": {
                t: to_device_plan(p, with_edge_ids=with_edge_ids)
                for t, p in plans_int.items()
            },
            "d_bnd": {
                t: to_device_plan(p, with_edge_ids=with_edge_ids)
                for t, p in plans_bnd.items()
            },
        }
        state[key] = entry
    elif with_edge_ids and any(
        d.edge_ids is None
        for d in list(entry["d_int"].values()) + list(entry["d_bnd"].values())
    ):
        entry = dict(
            entry,
            d_int={t: to_device_plan(p) for t, p in entry["plans_int"].items()},
            d_bnd={t: to_device_plan(p) for t, p in entry["plans_bnd"].items()},
        )
        state[key] = entry
    return entry


def _unshuffle(state: Dict, splan: ShardedExecutionPlan, stacked: jnp.ndarray):
    """Map shard-block-ordered rows back to global node order.

    Contiguous partitions concatenate back verbatim; permuted (min-cut)
    partitions apply the cached inverse permutation.
    """
    part = splan.partition
    if part.order is None:
        return stacked
    key = ("inv_order", splan.partition_fp)
    inv = state.get(key)
    if inv is None:
        inv = jnp.asarray(part._position, jnp.int32)
        state[key] = inv
    return stacked[inv]


def sharded_aggregate(
    x: jnp.ndarray,
    splan: ShardedExecutionPlan,
    *,
    mode: str,
    qp: Optional[QuantParams] = None,
    use_kernel: bool = False,
    device_state: Optional[Dict] = None,
    edge_coeff: Optional[jnp.ndarray] = None,
    overlap: bool = False,
    halo_stats: Optional[Dict[str, float]] = None,
    trace_id: str = "",
) -> jnp.ndarray:
    """Aggregate ``x`` shard by shard; returns the full [N, D] result.

    Per shard: gather owned + halo rows into local index space, run the
    shard's event-driven plan, keep the owned output rows. ``qp`` must be the
    globally calibrated activation scale/zp when the plan is mixed-precision
    (pass None for float-only plans). ``device_state`` caches per-shard
    uploaded artifacts across calls (the engine owns one). ``edge_coeff`` is
    a *global* runtime per-edge coefficient vector (f32[E] — or f32[E, H]
    with ``x`` f32[N, H, dh] for head-vectorized attention); each shard takes
    its local slice — ``edge_range`` when contiguous, the ``edge_idx`` gather
    otherwise — and scatters it through its local ``edge_ids`` map.

    ``overlap=True`` runs the split interior/boundary schedule: the halo row
    fetch is fenced on a worker thread while interior tiles aggregate, then
    boundary tiles continue from the interior accumulator
    (bitwise-identical to the unsplit scan — see
    ``scheduler.split_plan_by_halo``). ``halo_stats`` accumulates
    ``halo_ms`` / ``halo_wait_ms`` / ``halo_bytes`` / ``halo_exchanges``;
    ``halo_gather`` and ``halo_wait`` spans land on the trace when recording.
    The kernel path has no continuation hook, so ``use_kernel`` falls back
    to the unsplit schedule.
    """
    parts = []
    state = device_state if device_state is not None else {}
    with_eids = edge_coeff is not None
    rec = otrace.get_recorder()
    for sp in splan.shards:
        local_ids, plans, dplans = _shard_state_entry(
            state, sp, mode, with_edge_ids=with_eids
        )
        local_coeff = None
        if edge_coeff is not None:
            local_coeff = _local_edge_coeff(state, sp, edge_coeff)
        split_ok = (
            overlap
            and not use_kernel
            and sp.halo_size > 0
            and not ("int8" in plans and qp is None)
        )
        if not split_ok:
            x_local = x[local_ids]
            m = aggregate_mixed_precision(
                x_local,
                plans,
                num_nodes=sp.shard.num_local,
                use_kernel=use_kernel,
                qp=qp,
                device_plans=dplans,
                edge_coeff=local_coeff,
            )
            parts.append(m[: sp.num_owned])
            continue

        split = _shard_split_entry(state, sp, mode, with_edge_ids=with_eids)
        halo_ids = split["halo"]

        def fetch(halo_ids=halo_ids):
            t0 = time.perf_counter()
            h = x[halo_ids]
            h.block_until_ready()
            t1 = time.perf_counter()
            return h, t0, t1

        fut = _halo_pool().submit(fetch)
        x_owned = x[split["owned"]]
        zeros_h = jnp.zeros((sp.halo_size,) + x.shape[1:], x.dtype)
        x_int = jnp.concatenate([x_owned, zeros_h], axis=0)
        n_local = sp.shard.num_local
        partials: Dict[str, jnp.ndarray] = {}
        for tag in ("float", "int8"):
            p_int = split["plans_int"].get(tag)
            if tag not in plans or p_int is None:
                continue
            xin = (
                dequantize(quantize(x_int, qp), qp) if tag == "int8" else x_int
            )
            partials[tag] = aggregate_edge_tiles(
                xin,
                split["d_int"][tag],
                num_nodes=n_local,
                segments_per_tile=p_int.segments_per_tile,
                edge_coeff=local_coeff,
            )
        w0 = time.perf_counter()
        halo_buf, t0, t1 = fut.result()
        w1 = time.perf_counter()
        if rec.enabled:
            rec.add_span(
                "halo_gather", t0, t1, cat="halo", lane="halo",
                trace_id=trace_id, args={"shard": sp.shard.index},
            )
            rec.add_span(
                "halo_wait", w0, w1, cat="halo",
                trace_id=trace_id, args={"shard": sp.shard.index},
            )
        _note_halo(
            halo_stats,
            halo_ms=(t1 - t0) * 1e3,
            halo_wait_ms=(w1 - w0) * 1e3,
            halo_bytes=float(halo_buf.nbytes),
            halo_exchanges=1.0,
        )
        x_loc = jnp.concatenate([x_owned, halo_buf], axis=0)
        m = jnp.zeros((n_local,) + x.shape[1:], jnp.float32)
        for tag in ("float", "int8"):
            if tag not in plans:
                continue
            res = partials.get(tag)
            p_bnd = split["plans_bnd"].get(tag)
            if p_bnd is not None:
                xin = (
                    dequantize(quantize(x_loc, qp), qp)
                    if tag == "int8"
                    else x_loc
                )
                res = aggregate_edge_tiles(
                    xin,
                    split["d_bnd"][tag],
                    num_nodes=n_local,
                    segments_per_tile=p_bnd.segments_per_tile,
                    edge_coeff=local_coeff,
                    out_init=res,
                )
            if res is not None:
                m = m + res
        parts.append(m[: sp.num_owned])
    if not parts:
        return jnp.zeros_like(x)
    return _unshuffle(state, splan, jnp.concatenate(parts, axis=0))


# ---------------------------------------------------------------------------
# shard_map backend: one device per shard, all-gather halo exchange
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _MeshState:
    """Shape-uniform (padded, stacked) device mirror of a ShardedExecutionPlan.

    ``groups`` holds one tile-array dict per execution phase: a single full
    group normally, or (interior, boundary) halves when the state was built
    with ``overlap=True``. Each tiles tuple is (gather, coeff, seg, out[,
    edge_ids]) — the edge-id stack rides along only when the state carries
    the runtime-coefficient operand.
    """

    p_max: int  # padded owned rows per shard
    h_max: int  # padded halo rows per shard
    e_max: int  # padded local edges per shard (runtime-coeff operand width)
    seg: int  # segments per tile
    owned: Tuple[int, ...]  # real owned count per shard
    pad_gather: np.ndarray  # int64[K * p_max] global row feeding each padded row
    halo_owner: np.ndarray  # int32[K, h_max]
    halo_idx: np.ndarray  # int32[K, h_max] row within the owner's padded block
    edge_gather: Optional[np.ndarray]  # int64[K, e_max] global edge per slot
    groups: Tuple[Dict[str, Tuple[np.ndarray, ...]], ...]
    with_edge_ids: bool
    overlap: bool

    @property
    def tags(self) -> Tuple[str, ...]:
        return tuple(sorted({t for g in self.groups for t in g}))


def build_mesh_state(
    splan: ShardedExecutionPlan,
    mode: str,
    *,
    with_edge_ids: bool = False,
    overlap: bool = False,
) -> _MeshState:
    """Pad per-shard plans to a common shape for SPMD execution.

    The padded local index space per shard is ``[0, p_max)`` owned rows
    (shard's own block) followed by ``[p_max, p_max + h_max)`` halo rows;
    tile gather indices are remapped from the compact local space accordingly.
    The scatter sentinel becomes row ``p_max + h_max`` (a scratch row sliced
    off on return). Padding tiles carry coeff 0 and sentinel outputs, so they
    aggregate nothing — lane waste, not wrong answers.

    ``with_edge_ids`` additionally stacks each tile's local edge ids (-1 on
    padding lanes) and the per-shard ``edge_gather`` map (global edge id per
    padded local edge slot, sentinel = E), so a runtime per-edge operand can
    be sliced host-side into ``[K, e_max(, H)]`` and scattered on device.
    ``overlap=True`` splits every shard plan into interior/boundary halves
    (run granularity — bitwise-safe) and emits two tile groups.
    """
    K = splan.num_shards
    part = splan.partition
    p_max = max((s.num_owned for s in splan.shards), default=1) or 1
    h_max = max((s.halo_size for s in splan.shards), default=0)
    l_pad = p_max + h_max

    pad_gather = np.zeros(K * p_max, np.int64)
    halo_owner = np.zeros((K, max(h_max, 1)), np.int32)
    halo_idx = np.zeros((K, max(h_max, 1)), np.int32)
    for k, sp in enumerate(splan.shards):
        pad_gather[k * p_max : k * p_max + sp.num_owned] = sp.shard.owned
        if sp.halo_size:
            halo_owner[k, : sp.halo_size] = part.owner_of(sp.shard.halo)
            halo_idx[k, : sp.halo_size] = part.rank_of(sp.shard.halo)

    e_max = max((s.shard.num_edges for s in splan.shards), default=1) or 1
    edge_gather = None
    if with_edge_ids:
        edge_gather = np.full((K, e_max), splan.num_edges, np.int64)
        for k, sp in enumerate(splan.shards):
            if sp.shard.edge_range is not None:
                e_lo, e_hi = sp.shard.edge_range
                edge_gather[k, : e_hi - e_lo] = np.arange(e_lo, e_hi)
            else:
                edge_gather[k, : sp.shard.num_edges] = sp.shard.edge_idx

    tags = sorted({t for s in splan.shards for t in s.plan.mode_plans[mode]})
    E = splan.cfg.edges_per_tile
    seg = None

    # per shard and tag: the plan halves to stack (one group, or two)
    n_groups = 2 if overlap else 1
    shard_tag_plans = [
        [dict() for _ in range(n_groups)] for _ in range(K)
    ]
    for k, sp in enumerate(splan.shards):
        for tag, p in sp.plan.mode_plans[mode].items():
            if seg is None:
                seg = p.segments_per_tile
            elif p.segments_per_tile != seg:
                raise ValueError("segments_per_tile must be uniform across tags")
            if overlap:
                p_int, p_bnd = sched.split_plan_by_halo(p, sp.num_owned)
                shard_tag_plans[k][0][tag] = p_int
                shard_tag_plans[k][1][tag] = p_bnd
            else:
                shard_tag_plans[k][0][tag] = p

    groups = []
    for gi_group in range(n_groups):
        tag_tiles: Dict[str, Tuple[np.ndarray, ...]] = {}
        for tag in tags:
            per_shard = [shard_tag_plans[k][gi_group].get(tag) for k in range(K)]
            t_max = max(
                (p.num_tiles for p in per_shard if p is not None), default=0
            )
            if t_max == 0:
                continue  # group contributes nothing for this tag
            gi = np.zeros((K, t_max, E), np.int32)
            cf = np.zeros((K, t_max, E), np.float32)
            si = np.full((K, t_max, E), (seg or E) - 1, np.int32)
            on = np.full((K, t_max, seg or E), l_pad, np.int32)
            ei = np.full((K, t_max, E), -1, np.int32)
            for k, (sp, p) in enumerate(zip(splan.shards, per_shard)):
                if p is None or p.num_tiles == 0:
                    continue
                owned = sp.num_owned
                # compact local space -> padded local space
                g_remap = np.where(
                    p.gather_idx < owned,
                    p.gather_idx,
                    p.gather_idx - owned + p_max,
                )
                o_remap = np.where(
                    p.out_node < owned,
                    p.out_node,
                    np.where(
                        p.out_node >= sp.shard.num_local,  # sentinel
                        l_pad,
                        p.out_node - owned + p_max,
                    ),
                )
                t = p.num_tiles
                gi[k, :t] = np.minimum(g_remap, max(l_pad - 1, 0))
                cf[k, :t] = p.coeff
                si[k, :t] = p.seg_ids
                on[k, :t] = o_remap
                if with_edge_ids:
                    ei[k, :t] = p.edge_ids
            tiles = (gi, cf, si, on) + ((ei,) if with_edge_ids else ())
            tag_tiles[tag] = tiles
        groups.append(tag_tiles)

    return _MeshState(
        p_max=p_max,
        h_max=h_max,
        e_max=e_max,
        seg=seg if seg is not None else E,
        owned=tuple(s.num_owned for s in splan.shards),
        pad_gather=pad_gather,
        halo_owner=halo_owner,
        halo_idx=halo_idx,
        edge_gather=edge_gather,
        groups=tuple(groups),
        with_edge_ids=with_edge_ids,
        overlap=overlap,
    )


def _make_shard_map_fn(
    state: _MeshState,
    mesh,
    *,
    x_ndim: int = 2,
    coeff_ndim: Optional[int] = None,
):
    """Build the jitted SPMD program for one mesh state.

    ``coeff_ndim`` is the rank of the global runtime-coefficient vector
    (1 for f32[E], 2 for f32[E, H]); None means no runtime operand.
    ``x_ndim`` distinguishes [N, D] from the multi-head [N, H, dh] layout —
    both run the same per-tile arithmetic as ``aggregate_edge_tiles``
    (coefficients broadcast over trailing dims), which is what keeps the
    mesh backend bitwise-equal to the host loop.
    """
    from jax.sharding import PartitionSpec as P

    seg, p_max, h_max, e_max = state.seg, state.p_max, state.h_max, state.e_max
    l_pad = p_max + h_max
    with_eids = state.with_edge_ids
    with_coeff = coeff_ndim is not None
    na = 5 if with_eids else 4
    tags = state.tags
    group_tags = tuple(
        tuple(t for t in tags if t in g) for g in state.groups
    )

    def body(xpad, howner, hidx, scale, zp, *rest):
        idx = 0
        ecoeff = None
        if with_coeff:
            ecoeff = rest[0][0]  # [e_max(, H)] this shard's padded slice
            idx = 1
        it = iter(rest[idx:])
        groups_t = []
        for gtags in group_tags:
            groups_t.append(
                {tag: tuple(next(it)[0] for _ in range(na)) for tag in gtags}
            )

        gathered = jax.lax.all_gather(xpad, "shard")  # [K, p_max, …]
        halo = gathered[howner[0], hidx[0]][:h_max]  # [h_max, …]
        xl_full = jnp.concatenate([xpad, halo], axis=0)  # [l_pad, …]
        qp = QuantParams(scale=scale, zero_point=zp)

        def xin_for(tag, xl):
            return dequantize(quantize(xl, qp), qp) if tag == "int8" else xl

        def run(tiles, xbuf, out):
            if with_eids:
                gi, cf, si, on, ei = tiles
            else:
                gi, cf, si, on = tiles
                ei = None
            if with_coeff:
                # identical precompute to aggregate_edge_tiles: pad slot at
                # e_max reads 0, then static coeff × runtime coeff.
                cl = jnp.concatenate(
                    [
                        ecoeff,
                        jnp.zeros((1,) + ecoeff.shape[1:], ecoeff.dtype),
                    ]
                )
                rc = cl[jnp.where(ei < 0, e_max, ei)]
                cf = cf[..., None] * rc if rc.ndim == 3 else cf * rc

            def step(out, t):
                g_, c_, s_, o_ = t
                gath = xbuf[g_]  # [E, …]
                c_r = c_.reshape(c_.shape + (1,) * (gath.ndim - c_.ndim))
                partial = jax.ops.segment_sum(
                    gath * c_r, s_, num_segments=seg
                )
                return out.at[o_].add(partial), None

            out, _ = jax.lax.scan(step, out, (gi, cf, si, on))
            return out

        tail = xpad.shape[1:]
        m = jnp.zeros((l_pad + 1,) + tail, jnp.float32)
        if state.overlap and len(groups_t) == 2:
            # interior first on [owned | zeros]: no data dependency on the
            # all-gather, so the collective overlaps the interior scan;
            # boundary continues from the interior accumulator (bitwise ==
            # the unsplit scan — run-granularity split).
            xl_int = jnp.concatenate(
                [xpad, jnp.zeros((h_max,) + tail, xpad.dtype)], axis=0
            )
            for tag in tags:
                acc = jnp.zeros((l_pad + 1,) + tail, jnp.float32)
                if tag in groups_t[0]:
                    acc = run(groups_t[0][tag], xin_for(tag, xl_int), acc)
                if tag in groups_t[1]:
                    acc = run(groups_t[1][tag], xin_for(tag, xl_full), acc)
                m = m + acc
        else:
            for tag in tags:
                acc = jnp.zeros((l_pad + 1,) + tail, jnp.float32)
                if tag in groups_t[0]:
                    acc = run(groups_t[0][tag], xin_for(tag, xl_full), acc)
                m = m + acc
        return m[:p_max]

    n_tile_arrays = sum(na * len(g) for g in group_tags)
    x_spec = P("shard", *([None] * (x_ndim - 1)))
    in_specs = [
        x_spec,  # xpad [K * p_max, …]
        P("shard", None),  # halo owner [K, h_max]
        P("shard", None),  # halo idx [K, h_max]
        P(),  # scale
        P(),  # zero point
    ]
    if with_coeff:
        in_specs.append(P("shard", *([None] * coeff_ndim)))
    in_specs.extend([P("shard", None, None)] * n_tile_arrays)
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=x_spec,
    )
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# Engine facade
# ---------------------------------------------------------------------------


class ShardedAmpleEngine(AmpleEngine):
    """AmpleEngine over a partitioned graph: sharded AGE, row-parallel FTE.

    Drop-in for ``AmpleEngine`` wherever the model apply functions use it
    (``aggregate`` / ``transform`` / ``edge_softmax``), so gcn/gin/sage/gat
    run sharded without change. Construct from a compiled
    ``ShardedExecutionPlan``:

        splan = compile_sharded_plans(g, cfg, num_shards=4, modes=("gcn",))
        eng = ShardedAmpleEngine(g, splan)              # host loop
        eng = ShardedAmpleEngine(g, splan, mesh=mesh)   # shard_map SPMD

    ``mesh`` must be a 1-D ``("shard",)`` mesh with exactly one device per
    shard; without one, shards execute as a host loop (single-device
    simulation — identical numerics, no SPMD). ``halo_overlap=True`` enables
    the split interior/boundary schedule on both backends (bitwise-identical
    outputs); wall-clock halo accounting accumulates in ``halo_stats`` on
    the host loop (the mesh backend's exchange happens inside the SPMD
    program, so only ``halo_bytes`` is accounted there).
    """

    def __init__(
        self,
        g: Graph,
        plan: ShardedExecutionPlan,
        *,
        mesh=None,
        halo_overlap: bool = False,
    ):
        if plan.graph_fp != sched.graph_fingerprint(g):
            raise ValueError(
                f"sharded plan was compiled for a different graph structure "
                f"({plan.num_nodes} nodes, {plan.num_edges} edges vs "
                f"{g.num_nodes}, {g.num_edges}; fingerprints differ)"
            )
        if mesh is not None:
            if tuple(mesh.axis_names) != ("shard",):
                raise ValueError(f"mesh axes must be ('shard',), got {mesh.axis_names}")
            if mesh.devices.size != plan.num_shards:
                raise ValueError(
                    f"mesh has {mesh.devices.size} devices but the plan has "
                    f"{plan.num_shards} shards"
                )
        if halo_overlap and plan.cfg.use_kernel:
            raise ValueError(
                "halo_overlap needs the jnp aggregation path (the fused "
                "kernel has no continuation hook): clear gnn_use_kernel or "
                "gnn_halo_overlap"
            )
        self.graph = g
        self.cfg = plan.cfg
        self.plan = plan
        self.sharded_plan = plan
        self.mesh = mesh
        self.halo_overlap = bool(halo_overlap)
        self.precision_tags = plan.precision_tags
        self.node_groups = dict(plan.node_groups)
        self._plans = {}
        self._init_runtime_state()
        self._shard_state: Dict = {}
        self._mesh_exec: Dict[tuple, tuple] = {}
        #: wall-clock halo accounting, drained by the serving layer:
        #: halo_ms (fenced fetch), halo_wait_ms (consumer stall),
        #: halo_bytes, halo_exchanges.
        self.halo_stats: Dict[str, float] = {}
        #: set per request by the serving layer so halo spans join the trace
        self.trace_id: str = ""

    def plans(self, mode: str):
        raise NotImplementedError(
            "a sharded engine holds one plan per shard, not a global plan; "
            "use sharded_plan.shards[k].plan.mode_plans[mode]"
        )

    # ----------------------------------------------------------------- AGE
    def aggregate(
        self,
        x: jnp.ndarray,
        *,
        mode: str = "sum",
        edge_coeff: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        splan = self.sharded_plan
        if edge_coeff is not None:
            edge_coeff = jnp.asarray(edge_coeff, jnp.float32)
            e = self.graph.num_edges
            if not (
                edge_coeff.shape == (e,)
                or (edge_coeff.ndim == 2 and edge_coeff.shape[0] == e)
            ):
                raise ValueError(
                    f"edge_coeff must be [{e}] or [{e}, H], got "
                    f"{tuple(edge_coeff.shape)}"
                )
            if edge_coeff.ndim == 2 and (
                x.ndim != 3 or x.shape[1] != edge_coeff.shape[1]
            ):
                raise ValueError(
                    f"multi-head edge_coeff {tuple(edge_coeff.shape)} needs "
                    f"x shaped [N, {edge_coeff.shape[1]}, dh], got "
                    f"{tuple(x.shape)}"
                )
            for sp in splan.shards:
                self._require_edge_ids(
                    (mode, sp.shard.index), sp.plan.mode_plans.get(mode, {})
                )
        has_int8 = self.cfg.mixed_precision and any(
            "int8" in s.plan.mode_plans.get(mode, {}) for s in splan.shards
        )
        qp = self._activation_qp(lambda: x, "agg") if has_int8 else None
        if self.mesh is not None:
            return self._aggregate_shard_map(x, mode, qp, edge_coeff)
        return sharded_aggregate(
            x,
            splan,
            mode=mode,
            qp=qp,
            use_kernel=self.cfg.use_kernel,
            device_state=self._shard_state,
            edge_coeff=edge_coeff,
            overlap=self.halo_overlap,
            halo_stats=self.halo_stats,
            trace_id=self.trace_id,
        )

    # ------------------------------------------------ runtime coefficients
    def edge_softmax(
        self, scores: jnp.ndarray, *, mode: str = "runtime"
    ) -> jnp.ndarray:
        """Destination-segment softmax of per-edge scores, sharded: f32[E(, H)].

        Each destination node (and each edge) belongs to exactly one shard,
        so the segment-max and denominator passes run per shard over its
        local tiles and the owned rows map back to the global node order
        (through the partition's inverse permutation when non-contiguous);
        the exp-shift and final normalisation happen in global edge space.
        Matches the single-plan ``AmpleEngine.edge_softmax`` up to float
        accumulation order. ``scores`` f32[E, H] runs all heads in the same
        per-shard passes.
        """
        from repro.core.aggregation import (
            edge_segment_sum_tiles,
            segment_max_edge_tiles,
        )

        scores = jnp.asarray(scores, jnp.float32)
        e = self.graph.num_edges
        if not (
            scores.shape == (e,)
            or (scores.ndim == 2 and scores.shape[0] == e)
        ):
            raise ValueError(
                f"scores must be [{e}] or [{e}, H], got "
                f"{tuple(scores.shape)}"
            )
        splan = self.sharded_plan
        for sp in splan.shards:
            self._require_edge_ids(
                (mode, sp.shard.index), sp.plan.mode_plans.get(mode, {})
            )

        def owned_pass(fn, vec, init):
            parts = []
            for sp in splan.shards:
                local = _local_edge_coeff(self._shard_state, sp, vec)
                plans = sp.plan.mode_plans.get(mode)
                if plans is None:
                    raise KeyError(
                        f"shard {sp.shard.index} was compiled for modes "
                        f"{sp.plan.modes}, not {mode!r}"
                    )
                acc = jnp.full(
                    (sp.shard.num_local,) + vec.shape[1:], init, jnp.float32
                )
                for tag, p in plans.items():
                    dplan = self._softmax_dplan(sp, mode, tag, p)
                    res = fn(
                        local,
                        dplan,
                        num_nodes=sp.shard.num_local,
                        segments_per_tile=p.segments_per_tile,
                    )
                    acc = (
                        jnp.maximum(acc, res)
                        if init == -jnp.inf
                        else acc + res
                    )
                parts.append(acc[: sp.num_owned])
            return _unshuffle(
                self._shard_state, splan, jnp.concatenate(parts, axis=0)
            )

        node_max = owned_pass(segment_max_edge_tiles, scores, -jnp.inf)
        node_max = jnp.where(jnp.isfinite(node_max), node_max, 0.0)
        dst = self.edge_endpoints()[1]
        ex = jnp.exp(scores - node_max[dst])
        denom = owned_pass(edge_segment_sum_tiles, ex, 0.0)
        denom = jnp.where(denom > 0, denom, 1.0)
        return ex / denom[dst]

    def attention_aggregate(
        self,
        scores: jnp.ndarray,
        z: jnp.ndarray,
        *,
        mode: str = "runtime",
        leaky_slope: float = 0.2,
    ) -> jnp.ndarray:
        """Sharded GAT attention on raw scores f32[E, H] / z f32[N, H, dh].

        Always the oracle decomposition (head-vectorized softmax, then the
        [E, H] weighted aggregate) — a shard's softmax partials are complete
        because every in-edge lives in its destination's shard, but the
        per-shard tile plans index local node space, so the single-launch
        fused kernel stays a single-plan fast path. Under ``use_kernel`` the
        weighted aggregate still runs the multi-head Pallas kernel per shard.
        On a mesh, the weighted aggregate runs the SPMD program with the
        attention matrix as the runtime operand.
        """
        scores = jnp.asarray(scores, jnp.float32)
        z = jnp.asarray(z, jnp.float32)
        e, n = self.graph.num_edges, self.graph.num_nodes
        if scores.ndim != 2 or scores.shape[0] != e:
            raise ValueError(
                f"scores must be [{e}, H], got {tuple(scores.shape)}"
            )
        if z.ndim != 3 or z.shape[0] != n or z.shape[1] != scores.shape[1]:
            raise ValueError(
                f"z must be [{n}, {scores.shape[1]}, dh], got "
                f"{tuple(z.shape)}"
            )
        act = jax.nn.leaky_relu(scores, leaky_slope)
        alpha = self.edge_softmax(act, mode=mode)
        return self.aggregate(z, mode=mode, edge_coeff=alpha)

    def _softmax_dplan(self, sp, mode: str, tag: str, plan):
        """Per-shard device plan mirror, shared with sharded_aggregate.

        The softmax passes scatter through ``edge_ids``, so an entry cached
        by static-coeff traffic (uploaded without the map) is upgraded here.
        """
        entry = _shard_state_entry(
            self._shard_state, sp, mode, with_edge_ids=True
        )
        return entry[2][tag]

    def _aggregate_shard_map(
        self,
        x: jnp.ndarray,
        mode: str,
        qp,
        edge_coeff: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """SPMD execution: one jitted program per (mode, operand layout).

        The runtime per-edge operand is sliced host-side into the padded
        per-shard stack ``[K, e_max(, H)]`` through the mesh state's
        ``edge_gather`` (padding slots read 0), then scattered through each
        tile's ``edge_ids`` on device — the same two-hop indirection the
        host loop uses, so outputs are bitwise-equal to it.
        """
        with_coeff = edge_coeff is not None
        key = (mode, with_coeff, x.ndim)
        if key not in self._mesh_exec:
            state = build_mesh_state(
                self.sharded_plan,
                mode,
                with_edge_ids=with_coeff,
                overlap=self.halo_overlap,
            )
            fn = _make_shard_map_fn(
                state,
                self.mesh,
                x_ndim=x.ndim,
                coeff_ndim=(edge_coeff.ndim if with_coeff else None),
            )
            tile_args = tuple(
                jnp.asarray(a)
                for g in state.groups
                for tag in state.tags
                if tag in g
                for a in g[tag]
            )
            self._mesh_exec[key] = (state, fn, tile_args)
        state, fn, tile_args = self._mesh_exec[key]
        if qp is None:  # float-only plans still feed the qp slots
            qp = QuantParams(
                scale=jnp.ones((), jnp.float32), zero_point=jnp.zeros((), jnp.float32)
            )
        xpad = x[jnp.asarray(state.pad_gather)]  # [K * p_max, …]
        args = [
            xpad,
            jnp.asarray(state.halo_owner),
            jnp.asarray(state.halo_idx),
            qp.scale,
            qp.zero_point,
        ]
        if with_coeff:
            padded = jnp.concatenate(
                [
                    edge_coeff,
                    jnp.zeros((1,) + edge_coeff.shape[1:], edge_coeff.dtype),
                ]
            )
            args.append(padded[jnp.asarray(state.edge_gather)])
        out = fn(*args, *tile_args)
        parts = [
            out[k * state.p_max : k * state.p_max + owned]
            for k, owned in enumerate(state.owned)
        ]
        if not parts:
            return jnp.zeros_like(x)
        halo_rows = sum(s.halo_size for s in self.sharded_plan.shards)
        _note_halo(
            self.halo_stats,
            halo_bytes=float(
                halo_rows * x.dtype.itemsize * int(np.prod(x.shape[1:]))
            ),
            halo_exchanges=1.0,
        )
        return _unshuffle(
            self._shard_state, self.sharded_plan, jnp.concatenate(parts, axis=0)
        )

    # ------------------------------------------------------------- metrics
    def shard_report(self) -> Dict[str, object]:
        """Cluster-level lane economics: work balance + halo traffic."""
        splan = self.sharded_plan
        return {
            "num_shards": splan.num_shards,
            "partitioner": splan.partition.kind,
            "edge_balance": splan.edge_balance,
            "halo_total": splan.halo_total,
            "halo_per_shard": [s.halo_size for s in splan.shards],
            "edges_per_shard": [s.num_edges for s in splan.shards],
            "owned_per_shard": [s.num_owned for s in splan.shards],
        }


def make_sharded_engine(
    g: Graph,
    cfg=None,
    *,
    num_shards: Optional[int] = None,
    partition=None,
    partitioner: str = "edges",
    modes=("sum",),
    mesh=None,
    halo_overlap: bool = False,
) -> ShardedAmpleEngine:
    """Compile + wrap in one call (the non-serving convenience path)."""
    splan = compile_sharded_plans(
        g,
        cfg,
        num_shards=num_shards,
        partition=partition,
        partitioner=partitioner,
        modes=modes,
    )
    return ShardedAmpleEngine(g, splan, mesh=mesh, halo_overlap=halo_overlap)
