"""Sharded GNN layer execution — the cluster-level Feature Bank.

``ShardedAmpleEngine`` executes a ``ShardedExecutionPlan``: each shard owns a
contiguous, edge-balanced node range; before aggregating, it fetches the
embeddings of its remote ("halo") neighbours — the distributed analogue of
AMPLE's Feature Bank fetching off-chip rows — then runs its own event-driven
mixed-precision aggregation over its local subgraph and writes exactly its
owned output rows. Per-node transformations (FTE) are row-parallel and stay on
the regular mixed-precision path.

Two execution backends, numerically interchangeable:

* **host loop** (default) — one shard at a time on the local device. Works on
  a single-device CPU, and is what the serving engine uses; the halo gather is
  an explicit ``x[local_ids]`` row fetch.
* **shard_map** — SPMD over a 1-D ``("shard",)`` device mesh with one device
  per shard (CPU host-device simulation, as in ``test_distributed``). Owned
  rows live sharded; the halo exchange is a ``lax.all_gather`` of the owned
  blocks followed by a (owner, row) gather, and each device scans its own
  padded edge tiles. Per-shard plans are padded to a common tile count so the
  SPMD program is shape-uniform — the same trick the scheduler uses to make
  skewed degree distributions dense.

Activation quantization uses a *global* scale/zero-point (calibrated over the
full embedding matrix, exactly as the unsharded engine does), so every shard
quantizes identically and sharded output matches unsharded output to float
accumulation order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_mixed_precision, to_device_plan
from repro.core.message_passing import (
    AmpleEngine,
    ShardedExecutionPlan,
    compile_sharded_plans,
)
from repro.core import scheduler as sched
from repro.core.quantization import QuantParams, dequantize, quantize
from repro.distributed.compat import shard_map
from repro.graphs.csr import Graph

__all__ = ["ShardedAmpleEngine", "sharded_aggregate", "build_mesh_state"]


# ---------------------------------------------------------------------------
# Host-loop backend: one shard at a time on the local device
# ---------------------------------------------------------------------------


def _shard_state_entry(state: Dict, sp, mode: str, *, with_edge_ids: bool):
    """The per-shard device cache entry (local_ids, plans, dplans).

    One fill/upgrade rule for every consumer of the ``("host", fingerprint,
    mode)`` key: built on first use, and upgraded in place with the
    ``edge_ids`` indirection map when a runtime-coefficient pass needs it
    after static-coeff traffic populated the entry without one.
    """
    key = ("host", sp.fingerprint, mode)
    entry = state.get(key)
    if entry is None:
        plans = sp.plan.mode_plans.get(mode)
        if plans is None:
            raise KeyError(
                f"shard {sp.shard.index} was compiled for modes "
                f"{sp.plan.modes}, not {mode!r}; recompile the sharded "
                f"plan with this mode"
            )
        entry = (
            jnp.asarray(sp.shard.local_ids, jnp.int32),
            plans,
            {
                tag: to_device_plan(p, with_edge_ids=with_edge_ids)
                for tag, p in plans.items()
            },
        )
        state[key] = entry
    elif with_edge_ids and any(d.edge_ids is None for d in entry[2].values()):
        entry = (
            entry[0],
            entry[1],
            {tag: to_device_plan(p) for tag, p in entry[1].items()},
        )
        state[key] = entry
    return entry


def sharded_aggregate(
    x: jnp.ndarray,
    splan: ShardedExecutionPlan,
    *,
    mode: str,
    qp: Optional[QuantParams] = None,
    use_kernel: bool = False,
    device_state: Optional[Dict] = None,
    edge_coeff: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Aggregate ``x`` shard by shard; returns the full [N, D] result.

    Per shard: gather owned + halo rows into local index space, run the
    shard's event-driven plan, keep the owned output rows. ``qp`` must be the
    globally calibrated activation scale/zp when the plan is mixed-precision
    (pass None for float-only plans). ``device_state`` caches per-shard
    uploaded artifacts across calls (the engine owns one). ``edge_coeff`` is
    a *global* runtime per-edge coefficient vector (f32[E] — or f32[E, H]
    with ``x`` f32[N, H, dh] for head-vectorized attention); each shard
    slices its contiguous ``edge_range`` — halo-sourced edges live in their
    destination's shard, so the slice carries their runtime coefficients too
    — and scatters the slice through its local ``edge_ids`` map.
    """
    parts = []
    state = device_state if device_state is not None else {}
    with_eids = edge_coeff is not None
    for sp in splan.shards:
        local_ids, plans, dplans = _shard_state_entry(
            state, sp, mode, with_edge_ids=with_eids
        )
        x_local = x[local_ids]
        local_coeff = None
        if edge_coeff is not None:
            e_lo, e_hi = sp.shard.edge_range
            local_coeff = jax.lax.slice_in_dim(edge_coeff, e_lo, e_hi)
        m = aggregate_mixed_precision(
            x_local,
            plans,
            num_nodes=sp.shard.num_local,
            use_kernel=use_kernel,
            qp=qp,
            device_plans=dplans,
            edge_coeff=local_coeff,
        )
        parts.append(m[: sp.num_owned])
    return jnp.concatenate(parts, axis=0) if parts else jnp.zeros_like(x)


# ---------------------------------------------------------------------------
# shard_map backend: one device per shard, all-gather halo exchange
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _MeshState:
    """Shape-uniform (padded, stacked) device mirror of a ShardedExecutionPlan."""

    p_max: int  # padded owned rows per shard
    h_max: int  # padded halo rows per shard
    seg: int  # segments per tile
    owned: Tuple[int, ...]  # real owned count per shard
    pad_gather: np.ndarray  # int64[K * p_max] global row feeding each padded row
    halo_owner: np.ndarray  # int32[K, h_max]
    halo_idx: np.ndarray  # int32[K, h_max] row within the owner's padded block
    tag_tiles: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]


def build_mesh_state(splan: ShardedExecutionPlan, mode: str) -> _MeshState:
    """Pad per-shard plans to a common shape for SPMD execution.

    The padded local index space per shard is ``[0, p_max)`` owned rows
    (shard's own block) followed by ``[p_max, p_max + h_max)`` halo rows;
    tile gather indices are remapped from the compact local space accordingly.
    The scatter sentinel becomes row ``p_max + h_max`` (a scratch row sliced
    off on return). Padding tiles carry coeff 0 and sentinel outputs, so they
    aggregate nothing — lane waste, not wrong answers.
    """
    K = splan.num_shards
    p_max = max((s.num_owned for s in splan.shards), default=1) or 1
    h_max = max((s.halo_size for s in splan.shards), default=0)
    l_pad = p_max + h_max
    starts = splan.partition.starts

    pad_gather = np.zeros(K * p_max, np.int64)
    halo_owner = np.zeros((K, max(h_max, 1)), np.int32)
    halo_idx = np.zeros((K, max(h_max, 1)), np.int32)
    for k, sp in enumerate(splan.shards):
        lo, hi = sp.shard.lo, sp.shard.hi
        pad_gather[k * p_max : k * p_max + (hi - lo)] = np.arange(lo, hi)
        if sp.halo_size:
            owner = np.searchsorted(starts, sp.shard.halo, side="right") - 1
            halo_owner[k, : sp.halo_size] = owner
            halo_idx[k, : sp.halo_size] = sp.shard.halo - starts[owner]

    tags = sorted({t for s in splan.shards for t in s.plan.mode_plans[mode]})
    E = splan.cfg.edges_per_tile
    seg = None
    tag_tiles: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = {}
    for tag in tags:
        per_shard = [s.plan.mode_plans[mode].get(tag) for s in splan.shards]
        seg_t = next(p.segments_per_tile for p in per_shard if p is not None)
        seg = seg_t if seg is None else seg
        if seg_t != seg:
            raise ValueError("segments_per_tile must be uniform across tags")
        t_max = max((p.num_tiles for p in per_shard if p is not None), default=1)
        gi = np.zeros((K, t_max, E), np.int32)
        cf = np.zeros((K, t_max, E), np.float32)
        si = np.full((K, t_max, E), seg - 1, np.int32)
        on = np.full((K, t_max, seg), l_pad, np.int32)
        for k, (sp, p) in enumerate(zip(splan.shards, per_shard)):
            if p is None:
                continue
            owned = sp.num_owned
            # compact local space -> padded local space
            g_remap = np.where(
                p.gather_idx < owned, p.gather_idx, p.gather_idx - owned + p_max
            )
            o_remap = np.where(
                p.out_node < owned,
                p.out_node,
                np.where(
                    p.out_node >= sp.shard.num_local,  # sentinel
                    l_pad,
                    p.out_node - owned + p_max,
                ),
            )
            t = p.num_tiles
            gi[k, :t] = np.minimum(g_remap, max(l_pad - 1, 0))
            cf[k, :t] = p.coeff
            si[k, :t] = p.seg_ids
            on[k, :t] = o_remap
        tag_tiles[tag] = (gi, cf, si, on)
    return _MeshState(
        p_max=p_max,
        h_max=h_max,
        seg=seg if seg is not None else E,
        owned=tuple(s.num_owned for s in splan.shards),
        pad_gather=pad_gather,
        halo_owner=halo_owner,
        halo_idx=halo_idx,
        tag_tiles=tag_tiles,
    )


def _make_shard_map_fn(state: _MeshState, mesh, tags: Tuple[str, ...]):
    from jax.sharding import PartitionSpec as P

    seg, p_max, h_max = state.seg, state.p_max, state.h_max
    l_pad = p_max + h_max

    def _agg(tiles, xbuf):
        gi, cf, si, on = tiles
        out = jnp.zeros((l_pad + 1, xbuf.shape[1]), jnp.float32)

        def step(out, t):
            g_, c_, s_, o_ = t
            gathered = xbuf[g_] * c_[:, None]
            partial = jax.ops.segment_sum(gathered, s_, num_segments=seg)
            return out.at[o_].add(partial), None

        out, _ = jax.lax.scan(step, out, tiles)
        return out

    def body(xpad, howner, hidx, scale, zp, *tile_arrays):
        # xpad: this device's owned block [p_max, D]; halo maps [1, h_max].
        gathered = jax.lax.all_gather(xpad, "shard")  # [K, p_max, D]
        halo = gathered[howner[0], hidx[0]][: h_max]  # [h_max, D]
        xl = jnp.concatenate([xpad, halo], axis=0)  # [l_pad, D]
        m = jnp.zeros((l_pad + 1, xpad.shape[1]), jnp.float32)
        it = iter(tile_arrays)
        for tag in tags:
            tiles = tuple(a[0] for a in (next(it), next(it), next(it), next(it)))
            if tag == "int8":
                qp = QuantParams(scale=scale, zero_point=zp)
                xin = dequantize(quantize(xl, qp), qp)
            else:
                xin = xl
            m = m + _agg(tiles, xin)
        return m[:p_max]

    n_tile_arrays = 4 * len(tags)
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P("shard", None),  # xpad [K * p_max, D]
            P("shard", None),  # halo owner [K, h_max]
            P("shard", None),  # halo idx [K, h_max]
            P(),  # scale
            P(),  # zero point
            *([P("shard", None, None)] * n_tile_arrays),
        ),
        out_specs=P("shard", None),
    )
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# Engine facade
# ---------------------------------------------------------------------------


class ShardedAmpleEngine(AmpleEngine):
    """AmpleEngine over a partitioned graph: sharded AGE, row-parallel FTE.

    Drop-in for ``AmpleEngine`` wherever the model apply functions use it
    (``aggregate`` / ``transform`` / ``edge_softmax``), so gcn/gin/sage/gat
    run sharded without change. Construct from a compiled
    ``ShardedExecutionPlan``:

        splan = compile_sharded_plans(g, cfg, num_shards=4, modes=("gcn",))
        eng = ShardedAmpleEngine(g, splan)              # host loop
        eng = ShardedAmpleEngine(g, splan, mesh=mesh)   # shard_map SPMD

    ``mesh`` must be a 1-D ``("shard",)`` mesh with exactly one device per
    shard; without one, shards execute as a host loop (single-device
    simulation — identical numerics, no SPMD).
    """

    def __init__(self, g: Graph, plan: ShardedExecutionPlan, *, mesh=None):
        if plan.graph_fp != sched.graph_fingerprint(g):
            raise ValueError(
                f"sharded plan was compiled for a different graph structure "
                f"({plan.num_nodes} nodes, {plan.num_edges} edges vs "
                f"{g.num_nodes}, {g.num_edges}; fingerprints differ)"
            )
        if mesh is not None:
            if tuple(mesh.axis_names) != ("shard",):
                raise ValueError(f"mesh axes must be ('shard',), got {mesh.axis_names}")
            if mesh.devices.size != plan.num_shards:
                raise ValueError(
                    f"mesh has {mesh.devices.size} devices but the plan has "
                    f"{plan.num_shards} shards"
                )
        self.graph = g
        self.cfg = plan.cfg
        self.plan = plan
        self.sharded_plan = plan
        self.mesh = mesh
        self.precision_tags = plan.precision_tags
        self.node_groups = dict(plan.node_groups)
        self._plans = {}
        self._init_runtime_state()
        self._shard_state: Dict = {}
        self._mesh_exec: Dict[str, tuple] = {}

    def plans(self, mode: str):
        raise NotImplementedError(
            "a sharded engine holds one plan per shard, not a global plan; "
            "use sharded_plan.shards[k].plan.mode_plans[mode]"
        )

    # ----------------------------------------------------------------- AGE
    def aggregate(
        self,
        x: jnp.ndarray,
        *,
        mode: str = "sum",
        edge_coeff: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        splan = self.sharded_plan
        if edge_coeff is not None:
            edge_coeff = jnp.asarray(edge_coeff, jnp.float32)
            e = self.graph.num_edges
            if not (
                edge_coeff.shape == (e,)
                or (edge_coeff.ndim == 2 and edge_coeff.shape[0] == e)
            ):
                raise ValueError(
                    f"edge_coeff must be [{e}] or [{e}, H], got "
                    f"{tuple(edge_coeff.shape)}"
                )
            if edge_coeff.ndim == 2 and (
                x.ndim != 3 or x.shape[1] != edge_coeff.shape[1]
            ):
                raise ValueError(
                    f"multi-head edge_coeff {tuple(edge_coeff.shape)} needs "
                    f"x shaped [N, {edge_coeff.shape[1]}, dh], got "
                    f"{tuple(x.shape)}"
                )
            if self.mesh is not None:
                raise NotImplementedError(
                    "runtime edge coefficients run on the host-loop sharded "
                    "backend; the shard_map SPMD program does not yet carry "
                    "a per-edge operand"
                )
        if edge_coeff is not None:
            for sp in splan.shards:
                self._require_edge_ids(
                    (mode, sp.shard.index), sp.plan.mode_plans.get(mode, {})
                )
        has_int8 = self.cfg.mixed_precision and any(
            "int8" in s.plan.mode_plans.get(mode, {}) for s in splan.shards
        )
        qp = self._activation_qp(lambda: x, "agg") if has_int8 else None
        if self.mesh is not None:
            return self._aggregate_shard_map(x, mode, qp)
        return sharded_aggregate(
            x,
            splan,
            mode=mode,
            qp=qp,
            use_kernel=self.cfg.use_kernel,
            device_state=self._shard_state,
            edge_coeff=edge_coeff,
        )

    # ------------------------------------------------ runtime coefficients
    def edge_softmax(
        self, scores: jnp.ndarray, *, mode: str = "runtime"
    ) -> jnp.ndarray:
        """Destination-segment softmax of per-edge scores, sharded: f32[E(, H)].

        Each destination node (and each edge) belongs to exactly one shard,
        so the segment-max and denominator passes run per shard over its
        local tiles and the owned rows concatenate back to the global node
        order; the exp-shift and final normalisation happen in global edge
        space. Matches the single-plan ``AmpleEngine.edge_softmax`` up to
        float accumulation order. ``scores`` f32[E, H] runs all heads in the
        same per-shard passes.
        """
        from repro.core.aggregation import (
            edge_segment_sum_tiles,
            segment_max_edge_tiles,
        )

        scores = jnp.asarray(scores, jnp.float32)
        e = self.graph.num_edges
        if not (
            scores.shape == (e,)
            or (scores.ndim == 2 and scores.shape[0] == e)
        ):
            raise ValueError(
                f"scores must be [{e}] or [{e}, H], got "
                f"{tuple(scores.shape)}"
            )
        splan = self.sharded_plan
        for sp in splan.shards:
            self._require_edge_ids(
                (mode, sp.shard.index), sp.plan.mode_plans.get(mode, {})
            )

        def owned_pass(fn, vec, init):
            parts = []
            for sp in splan.shards:
                e_lo, e_hi = sp.shard.edge_range
                local = jax.lax.slice_in_dim(vec, e_lo, e_hi)
                plans = sp.plan.mode_plans.get(mode)
                if plans is None:
                    raise KeyError(
                        f"shard {sp.shard.index} was compiled for modes "
                        f"{sp.plan.modes}, not {mode!r}"
                    )
                acc = jnp.full(
                    (sp.shard.num_local,) + vec.shape[1:], init, jnp.float32
                )
                for tag, p in plans.items():
                    dplan = self._softmax_dplan(sp, mode, tag, p)
                    res = fn(
                        local,
                        dplan,
                        num_nodes=sp.shard.num_local,
                        segments_per_tile=p.segments_per_tile,
                    )
                    acc = (
                        jnp.maximum(acc, res)
                        if init == -jnp.inf
                        else acc + res
                    )
                parts.append(acc[: sp.num_owned])
            return jnp.concatenate(parts, axis=0)

        node_max = owned_pass(segment_max_edge_tiles, scores, -jnp.inf)
        node_max = jnp.where(jnp.isfinite(node_max), node_max, 0.0)
        dst = self.edge_endpoints()[1]
        ex = jnp.exp(scores - node_max[dst])
        denom = owned_pass(edge_segment_sum_tiles, ex, 0.0)
        denom = jnp.where(denom > 0, denom, 1.0)
        return ex / denom[dst]

    def attention_aggregate(
        self,
        scores: jnp.ndarray,
        z: jnp.ndarray,
        *,
        mode: str = "runtime",
        leaky_slope: float = 0.2,
    ) -> jnp.ndarray:
        """Sharded GAT attention on raw scores f32[E, H] / z f32[N, H, dh].

        Always the oracle decomposition (head-vectorized softmax, then the
        [E, H] weighted aggregate) — a shard's softmax partials are complete
        because every in-edge lives in its destination's shard, but the
        per-shard tile plans index local node space, so the single-launch
        fused kernel stays a single-plan fast path. Under ``use_kernel`` the
        weighted aggregate still runs the multi-head Pallas kernel per shard.
        """
        scores = jnp.asarray(scores, jnp.float32)
        z = jnp.asarray(z, jnp.float32)
        e, n = self.graph.num_edges, self.graph.num_nodes
        if scores.ndim != 2 or scores.shape[0] != e:
            raise ValueError(
                f"scores must be [{e}, H], got {tuple(scores.shape)}"
            )
        if z.ndim != 3 or z.shape[0] != n or z.shape[1] != scores.shape[1]:
            raise ValueError(
                f"z must be [{n}, {scores.shape[1]}, dh], got "
                f"{tuple(z.shape)}"
            )
        act = jax.nn.leaky_relu(scores, leaky_slope)
        alpha = self.edge_softmax(act, mode=mode)
        return self.aggregate(z, mode=mode, edge_coeff=alpha)

    def _softmax_dplan(self, sp, mode: str, tag: str, plan):
        """Per-shard device plan mirror, shared with sharded_aggregate.

        The softmax passes scatter through ``edge_ids``, so an entry cached
        by static-coeff traffic (uploaded without the map) is upgraded here.
        """
        entry = _shard_state_entry(
            self._shard_state, sp, mode, with_edge_ids=True
        )
        return entry[2][tag]

    def _aggregate_shard_map(self, x: jnp.ndarray, mode: str, qp) -> jnp.ndarray:
        if mode not in self._mesh_exec:
            state = build_mesh_state(self.sharded_plan, mode)
            tags = tuple(sorted(state.tag_tiles))
            fn = _make_shard_map_fn(state, self.mesh, tags)
            tile_args = tuple(
                jnp.asarray(a) for tag in tags for a in state.tag_tiles[tag]
            )
            self._mesh_exec[mode] = (state, fn, tile_args)
        state, fn, tile_args = self._mesh_exec[mode]
        if qp is None:  # float-only plans still feed the qp slots
            qp = QuantParams(
                scale=jnp.ones((), jnp.float32), zero_point=jnp.zeros((), jnp.float32)
            )
        xpad = x[jnp.asarray(state.pad_gather)]  # [K * p_max, D]
        out = fn(
            xpad,
            jnp.asarray(state.halo_owner),
            jnp.asarray(state.halo_idx),
            qp.scale,
            qp.zero_point,
            *tile_args,
        )
        parts = [
            out[k * state.p_max : k * state.p_max + owned]
            for k, owned in enumerate(state.owned)
        ]
        return jnp.concatenate(parts, axis=0) if parts else jnp.zeros_like(x)

    # ------------------------------------------------------------- metrics
    def shard_report(self) -> Dict[str, object]:
        """Cluster-level lane economics: work balance + halo traffic."""
        splan = self.sharded_plan
        return {
            "num_shards": splan.num_shards,
            "edge_balance": splan.edge_balance,
            "halo_total": splan.halo_total,
            "halo_per_shard": [s.halo_size for s in splan.shards],
            "edges_per_shard": [s.num_edges for s in splan.shards],
            "owned_per_shard": [s.num_owned for s in splan.shards],
        }


def make_sharded_engine(
    g: Graph,
    cfg=None,
    *,
    num_shards: Optional[int] = None,
    partition=None,
    modes=("sum",),
    mesh=None,
) -> ShardedAmpleEngine:
    """Compile + wrap in one call (the non-serving convenience path)."""
    splan = compile_sharded_plans(
        g, cfg, num_shards=num_shards, partition=partition, modes=modes
    )
    return ShardedAmpleEngine(g, splan, mesh=mesh)
