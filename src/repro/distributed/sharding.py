"""GSPMD sharding rules: params, optimizer state, activations, caches.

The layout follows the standard large-model hierarchy on a
(pod, data, model) mesh:

* batch over ("pod","data") — DP spans the slow inter-pod links (gradient
  all-reduce is latency-tolerant and compressible);
* attention heads / FFN hidden / vocab / experts over "model" (TP/EP inside
  the fast ICI domain);
* residual-stream activations sequence-sharded over "model" between blocks
  (Megatron-SP): the per-block all-gather/reduce-scatter pair XLA inserts is
  cheaper than holding replicated [B,S,D] residuals at 32k sequence length;
* decode KV caches sequence-sharded over "model" (long-context serving).

Rules are *name-based* over the parameter tree (leaf path suffix), with
automatic left-padding of specs for stacked-unit leading axes, so the same
table covers every architecture family. Non-divisible cases fall back
explicitly: projections shard on flat (H*hd) axes when head counts don't
divide, non-EP experts replicate over "model" with FSDP over "data"
(granite), and vocab is padded at init. A divisibility guard drops any axis
that doesn't divide its dim, so every arch lowers on every mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import data_axes, mesh_tp

__all__ = [
    "ShardingPolicy",
    "make_policy",
    "param_shardings",
    "state_shardings",
    "batch_shardings",
    "cache_shardings",
    "replicated",
]


# --------------------------------------------------------------- activations
@dataclasses.dataclass
class ShardingPolicy:
    """Activation constraints threaded through model forward functions.

    mode="tp"   — Megatron-style tensor parallel over "model" + FSDP+DP over
                  "data" (the ≥20B-parameter regime).
    mode="fsdp" — NO tensor parallelism: both mesh axes act as data/ZeRO-3
                  axes; activations shard batch over "data"/"pod" and sequence
                  over "model"; weights gather per layer. Measured to flip
                  small/mid models from collective-bound to compute-bound
                  (§Perf cells B/C) — TP all-reduces of activations are
                  replaced by weight all-gathers, which are tiny for ≤20B.
    """

    mesh: Any
    seq_shard: bool = False  # sequence-shard residuals over "model" (SP; §Perf lever)
    mode: str = "tp"

    def _c(self, x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def _batch_axes(self, b: int):
        """Largest axis combo that divides the batch dim evenly."""
        dp = data_axes(self.mesh)
        if self.mode == "fsdp":
            for axes in (dp + ("model",), dp, dp[-1:]):
                n = 1
                for a in axes:
                    n *= self.mesh.shape[a]
                if b % n == 0:
                    return axes
            return None
        n = 1
        for a in dp:
            n *= self.mesh.shape[a]
        return dp if b % n == 0 else None

    def res(self, x):
        dp = data_axes(self.mesh)
        if x.ndim != 3:
            return x
        b, sq = x.shape[0], x.shape[1]
        if self.mode == "fsdp":
            ba = self._batch_axes(b)
            seq_ax = None
            if (ba is None or "model" not in (ba if ba else ())) and                sq % mesh_tp(self.mesh) == 0 and sq > 1:
                seq_ax = "model"
            return self._c(x, P(ba, seq_ax, None))
        if self.seq_shard and sq % mesh_tp(self.mesh) == 0 and sq > 1:
            return self._c(x, P(dp, "model", None))
        return self._c(x, P(dp, None, None))

    def logits(self, x):
        dp = data_axes(self.mesh)
        if self.mode == "fsdp":
            if x.ndim == 3:
                ba = self._batch_axes(x.shape[0])
                seq_ax = "model" if (ba is None or "model" not in ba) and                     x.shape[1] % mesh_tp(self.mesh) == 0 and x.shape[1] > 1 else None
                return self._c(x, P(ba, seq_ax, None))
            return self._c(x, P(self._batch_axes(x.shape[0]), None))
        if x.ndim == 3:
            return self._c(x, P(dp, None, "model"))
        return self._c(x, P(dp, "model"))

    def qkv(self, q, k, v):
        """Attention-internal layout (§Perf iteration 2): queries shard their
        SEQUENCE dim over "model" (context parallelism) — every shard computes
        attention for S/tp query rows against replicated K/V. No redundant
        compute, no per-block all-reduces; the residual constraint re-gathers
        afterwards. Decode (S=1) keeps batch-only sharding."""
        tp = mesh_tp(self.mesh)
        dp = data_axes(self.mesh)
        if self.mode == "fsdp":
            ba = self._batch_axes(q.shape[0]) or dp
            ba = tuple(a for a in ba if a != "model")
        else:
            ba = dp
        if q.ndim == 4 and q.shape[1] % tp == 0 and q.shape[1] > 1:
            q = self._c(q, P(ba, "model", None, None))
        elif q.ndim == 4:
            q = self._c(q, P(ba, None, None, None))
        if k.ndim == 4:
            k = self._c(k, P(ba, None, None, None))
            v = self._c(v, P(ba, None, None, None))
        return q, k, v

    def moe_groups(self, t: int) -> int:
        """Dispatch groups = one local nodeslot pool per token shard."""
        dp = 1
        for a in data_axes(self.mesh):
            dp *= self.mesh.shape[a]
        if self.mode == "fsdp":
            full = dp * mesh_tp(self.mesh)
            if t % full == 0:
                return full
        return dp if t % dp == 0 else 1

    def ebuf(self, xin):
        """MoE dispatch buffer [G, E, C, D] entering the experts: groups stay
        on their data shards, experts shard over "model" (EP) — the reshard
        from the group-local scatter layout is a [G, E] block all-to-all."""
        if xin.ndim != 4:
            return xin
        g, e, c, _ = xin.shape
        dp = data_axes(self.mesh)
        full = self._dp_size() * mesh_tp(self.mesh)
        if self.mode == "fsdp" and g % full == 0 and g > 1:
            return self._c(xin, P(dp + ("model",), None, None, None))
        g_ax = dp if g % self._dp_size() == 0 and g > 1 else None
        e_ax = "model" if e % mesh_tp(self.mesh) == 0 else None
        if g_ax is None and e_ax is None:
            return xin
        return self._c(xin, P(g_ax, e_ax, None, None))

    def ebuf_out(self, y):
        """Expert outputs: same layout as ebuf (combine happens group-local)."""
        return self.ebuf(y)

    def _dp_size(self) -> int:
        n = 1
        for a in data_axes(self.mesh):
            n *= self.mesh.shape[a]
        return n


class _NoPolicy:
    def res(self, x):
        return x

    def logits(self, x):
        return x

    def qkv(self, q, k, v):
        return q, k, v

    def ebuf(self, xin):
        return xin

    def ebuf_out(self, y):
        return y

    def moe_groups(self, t):
        return 1


def make_policy(mesh, *, seq_shard: bool = False, mode: str = "tp") -> ShardingPolicy:
    return ShardingPolicy(mesh=mesh, seq_shard=seq_shard, mode=mode)


def replicated(mesh):
    return NamedSharding(mesh, P())


# -------------------------------------------------------------------- params
def _rule_for(path: str, cfg: ModelConfig, tp: int) -> Optional[Tuple]:
    """Partition spec for a parameter leaf, by name (None = replicate).

    Specs are written for the *unstacked* shape; leading unit axes are padded
    by the caller. "model" is the TP/EP axis; "data" entries are the FSDP
    (ZeRO-3) placement — ALWAYS on a dimension such that XLA resolves the use
    as a weight all-gather over "data", never as an all-reduce of
    activation-sized partial products: i.e. on the weight's input/contraction
    dim for column-parallel matrices and on the output dim for row-parallel
    ones. (The weight AG is O(weight); the wrong choice costs O(activation)
    per use — measured 20 GB all-reduces per MoE unit before this rule.)
    The caller strips "data" entries when fsdp is off or the leaf is small.
    """
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""
    ep = cfg.num_experts > 0 and cfg.num_experts % tp == 0
    ff_div = cfg.d_ff % tp == 0

    if parent == "experts" or "/experts/" in path:
        # stacked expert FFN [E, D, F] / [E, F, D]; FSDP on the contraction dim.
        # Non-EP fallback (E % tp != 0, e.g. granite's 40 experts): REPLICATE
        # over "model" with FSDP over "data" — TP-on-FFN for 512-wide experts
        # was measured to force an [E,C,D]-sized all-reduce per layer (44.6 s
        # collective term on prefill_32k); replicated tiny experts cost only
        # a per-unit weight all-gather. §Perf cell A iteration 1.
        if name in ("w_gate", "w_up", "w_in"):
            return ("model", "data", None) if ep else (None, "data", None)
        if name in ("w_down", "w_out"):
            return ("model", "data", None) if ep else (None, None, "data")
        return None
    if name == "router":
        return None
    if name == "embed":
        return ("model", "data")
    if name == "lm_head":
        return ("data", "model")
    if name in ("wq", "wk", "wv"):
        return ("data", "model")
    if name == "wo":
        return ("model", "data")
    if name == "bq":
        return ("model",)
    if name in ("bk", "bv"):
        return ("model",)
    # MLP
    if name in ("w_gate", "w_up", "w_in"):
        return ("data", "model") if ff_div else ("data", None)
    if name in ("w_down", "w_out"):
        return ("model", "data") if ff_div else (None, "data")
    if name in ("b_gate", "b_up", "b_in"):
        return ("model",) if ff_div else None
    # Mamba
    di_div = cfg.ssm_state > 0 and cfg.d_inner % tp == 0
    h_div = cfg.ssm_state > 0 and cfg.ssm_heads % tp == 0
    if name in ("wx", "wz"):
        return ("data", "model") if di_div else ("data", None)
    if name == "out_proj":
        return ("model", "data") if di_div else (None, "data")
    if name == "wdt":
        return (None, "model") if h_div else None
    if parent == "conv_x" and name == "w":
        return (None, "model") if di_div else None
    if parent == "conv_x" and name == "b":
        return ("model",) if di_div else None
    if name in ("A_log", "D", "dt_bias"):
        return ("model",) if h_div else None
    if parent == "norm_scale" and name == "scale":
        return ("model",) if di_div else None
    return None  # norms, small biases, B/C projections: replicate


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


FSDP_MIN_ELEMENTS = 1 << 20  # leaves below this stay replicated over "data"


def param_shardings(cfg: ModelConfig, params_shape, mesh, *, fsdp: bool = True,
                    mode: str = "tp") -> Any:
    """NamedSharding pytree matching ``params_shape`` (shapes or arrays).

    With ``fsdp=True`` (§Perf iteration 1 / ZeRO-3), every large leaf
    additionally shards one spare dimension over "data": parameters and AdamW
    moments then scale with the FULL chip count, not just the model axis —
    the only way 400B-class models fit v5e HBM. XLA inserts the per-layer
    weight all-gather (fwd) / gradient reduce-scatter (bwd) this implies.
    """
    tp = mesh_tp(mesh)

    def assign(path, leaf):
        spec = _rule_for(_path_str(path), cfg, tp)
        nd = len(leaf.shape)
        if spec is None:
            spec = ()
        spec = tuple(spec)
        if mode == "fsdp":  # no TP: FSDP dim spans both axes, model dims free
            spec = tuple(
                ("data", "model") if ax == "data" else (None if ax == "model" else ax)
                for ax in spec
            )
        if len(spec) < nd:  # stacked unit/layer leading axes -> replicate them
            spec = (None,) * (nd - len(spec)) + spec
        elif len(spec) > nd:
            spec = (None,) * nd
        size = 1
        for d in leaf.shape:
            size *= int(d)
        if not fsdp or size < FSDP_MIN_ELEMENTS or "data" not in mesh.axis_names:
            spec = tuple(None if ax == "data" else ax for ax in spec)
        # divisibility guard: drop axes that do not divide evenly
        def ok(dim, ax):
            if ax is None:
                return None
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if dim % n == 0:
                return ax
            # fsdp pair: fall back to the single "data" axis if that divides
            if isinstance(ax, tuple) and dim % mesh.shape[ax[0]] == 0:
                return ax[0]
            return None

        spec = tuple(ok(dim, ax) for dim, ax in zip(leaf.shape, spec))
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def state_shardings(cfg: ModelConfig, state_shape: Dict, mesh, *, mode: str = "tp") -> Dict:
    """Train-state shardings: params rules for params and AdamW moments."""
    ps = param_shardings(cfg, state_shape["params"], mesh, mode=mode)
    out = {
        "params": ps,
        "opt": type(state_shape["opt"])(
            step=replicated(mesh),
            m=param_shardings(cfg, state_shape["opt"].m, mesh, mode=mode),
            v=param_shardings(cfg, state_shape["opt"].v, mesh, mode=mode),
        ),
        "step": replicated(mesh),
    }
    if "compress" in state_shape:
        out["compress"] = param_shardings(cfg, state_shape["compress"], mesh, mode=mode)
    return out


# --------------------------------------------------------------------- batch
def batch_shardings(cfg: ModelConfig, batch_shape: Dict, mesh, *, mode: str = "tp") -> Dict:
    dp = data_axes(mesh)
    pol = ShardingPolicy(mesh=mesh, mode=mode)
    out = {}
    for k, v in batch_shape.items():
        nd = len(v.shape)
        lead = pol._batch_axes(v.shape[0])
        if mode == "fsdp" and lead is not None and "model" in lead and nd >= 2:
            pass  # batch fully covers the mesh; no seq sharding needed
        if nd == 1:
            out[k] = NamedSharding(mesh, P(lead))
        elif nd == 2:
            out[k] = NamedSharding(mesh, P(lead, None))
        elif nd == 3:  # embeds
            out[k] = NamedSharding(mesh, P(lead, None, None))
        else:
            out[k] = NamedSharding(mesh, P(lead, *([None] * (nd - 1))))
    return out


def _dp_size(mesh) -> int:
    return int(jnp.prod(jnp.asarray([mesh.shape[a] for a in data_axes(mesh)])))


# --------------------------------------------------------------------- cache
def cache_shardings(cfg: ModelConfig, cache_shape, mesh, *, batch: int):
    """Decode-cache shardings.

    KV caches [U, B, L, KV, hd]: batch over DP when divisible; the sequence
    axis L shards over "model" (sequence-parallel KV — the only way a 32k+
    cache fits at high batch, and the long_500k requirement). Mamba states
    shard d_inner/heads over "model".
    """
    dp = data_axes(mesh)
    tp = mesh_tp(mesh)
    bdiv = batch % _dp_size(mesh) == 0
    b_ax = dp if bdiv else None

    def assign(path, leaf):
        p = _path_str(path)
        name = p.split("/")[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "cross_k", "cross_v") and nd == 5:
            u, b, l, kv, hd = leaf.shape
            l_ax = "model" if l % tp == 0 else None
            return NamedSharding(mesh, P(None, b_ax, l_ax, None, None))
        if name in ("k_scale", "v_scale") and nd == 4:  # int8 KV scales
            l_ax = "model" if leaf.shape[2] % tp == 0 else None
            return NamedSharding(mesh, P(None, b_ax, l_ax, None))
        if name == "ssm" and nd == 5:  # [U, B, H, P, N]
            h_ax = "model" if leaf.shape[2] % tp == 0 else None
            return NamedSharding(mesh, P(None, b_ax, h_ax, None, None))
        if name.startswith("conv_") and nd == 4:  # [U, B, K-1, C]
            c_ax = "model" if leaf.shape[3] % tp == 0 else None
            return NamedSharding(mesh, P(None, b_ax, None, c_ax))
        return NamedSharding(mesh, P(*((None,) * nd)))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)
