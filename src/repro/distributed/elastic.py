"""Elastic scaling + straggler policy: what happens when hosts die mid-run.

On a 1000+-node deployment, node failure is routine. The recovery contract:

1. Health layer marks hosts dead (out of scope — injected here as a mask).
2. ``elastic_plan`` maps the surviving chip count onto the largest valid
   (data × model) mesh that preserves the model-parallel degree (TP cannot
   shrink without resharding weights *math*; DP can shrink freely) and
   recomputes the per-shard batch so the GLOBAL batch (and thus the training
   trajectory) is preserved exactly via gradient accumulation.
3. Checkpoint restore re-device_puts leaves against the new mesh
   (checkpoint/checkpoint.py stores unsharded leaves precisely for this).

Straggler mitigation is configuration, not code, at this layer: DP spans the
pod axis, so a slow host delays only its gradient contribution; with
``drop_stragglers`` the all-reduce group is rebuilt without hosts whose last
heartbeat exceeds the deadline (gradient contribution of a dropped shard is
replayed next step via the data pipeline's deterministic (seed, step)
contract). For *irregular* workloads (the paper's GNN case), the event-driven
ExecutionPlan is itself the straggler mitigation — work is balanced by edge
count, not node count (graphs/partition.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["ElasticPlan", "elastic_plan", "rebalance_batch"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data_parallel: int  # surviving DP degree
    model_parallel: int  # unchanged TP degree
    per_shard_batch: int  # examples per DP shard per micro-step
    grad_accum: int  # micro-steps to preserve the global batch
    dropped_hosts: Tuple[int, ...]
    global_batch: int

    @property
    def chips_used(self) -> int:
        return self.data_parallel * self.model_parallel


def elastic_plan(
    *,
    alive_chips: int,
    model_parallel: int,
    global_batch: int,
    max_per_shard_batch: int = 64,
    dropped_hosts: Tuple[int, ...] = (),
) -> ElasticPlan:
    """Largest valid mesh ≤ alive_chips with TP preserved, batch preserved.

    Raises if fewer than one TP group survives (the job cannot continue and
    must wait for repair — checkpoint restore handles the rest).
    """
    if alive_chips < model_parallel:
        raise RuntimeError(
            f"only {alive_chips} chips alive < one model-parallel group "
            f"({model_parallel}); cannot continue"
        )
    dp_max = alive_chips // model_parallel
    # exact-batch guarantee: use the LARGEST dp ≤ dp_max that divides the
    # global batch (surplus DP groups idle — preserving the training
    # trajectory beats using every chip with a changed batch)
    dp = max(d for d in range(1, dp_max + 1) if global_batch % d == 0)
    micro = global_batch // dp  # examples per shard per step, to be split
    per_shard = max(
        d for d in range(1, min(max_per_shard_batch, micro) + 1) if micro % d == 0
    )
    accum = micro // per_shard
    return ElasticPlan(
        data_parallel=dp,
        model_parallel=model_parallel,
        per_shard_batch=per_shard,
        grad_accum=accum,
        dropped_hosts=tuple(dropped_hosts),
        global_batch=global_batch,
    )


def rebalance_batch(
    global_batch: int, shard_weights: List[float]
) -> List[int]:
    """Weighted batch split (straggler-aware DP): faster shards get more.

    Largest-remainder apportionment: exact sum, monotone in weight — used when
    heterogeneous hosts (or partially-degraded ones) should keep contributing
    rather than being dropped.
    """
    total_w = sum(shard_weights)
    if total_w <= 0:
        raise ValueError("all shard weights are zero")
    quotas = [global_batch * w / total_w for w in shard_weights]
    base = [int(q) for q in quotas]
    rem = global_batch - sum(base)
    order = sorted(
        range(len(quotas)), key=lambda i: quotas[i] - base[i], reverse=True
    )
    for i in order[:rem]:
        base[i] += 1
    return base
