"""Out-of-core memory subsystem: host-resident features + plan-driven prefetch.

AMPLE's third pillar — "a prefetcher for data and instructions is implemented
to optimize off-chip memory access" (§3.3) — lives here for the TPU repro:

* ``feature_store``  — a chunked, host-resident :class:`FeatureStore` holding
  node features off-device in two representations (f32 for the float gather
  stream, int8 under the aggregation scale for the int8 stream), optionally
  ``np.memmap``-backed so host RSS stays bounded too;
* ``prefetcher``     — a :class:`ChunkPrefetcher` executing a scheduler
  ``ChunkSchedule`` against a fixed-budget device chunk cache (reuse-distance
  eviction, double-buffered chunk uploads overlapping the running tile), and
  the streamed aggregation/transform executors that are bitwise-identical to
  the in-memory engine paths.
"""
from repro.memory.feature_store import FeatureStore, default_chunk_rows
from repro.memory.prefetcher import (
    ChunkPrefetcher,
    StreamStats,
    StreamedFeatures,
    aggregate_streamed,
    scale_add_streamed,
)

__all__ = [
    "FeatureStore",
    "default_chunk_rows",
    "ChunkPrefetcher",
    "StreamStats",
    "StreamedFeatures",
    "aggregate_streamed",
    "scale_add_streamed",
]
