"""Plan-driven chunk prefetcher + streamed executors (out-of-core serving).

``ChunkPrefetcher`` executes a ``core.scheduler.ChunkSchedule`` against a
fixed-budget device chunk cache:

* **budget** — the cache is ``num_slots`` shape-stable slots of
  ``chunk_rows`` feature rows; ``num_slots = budget_bytes // chunk_bytes``
  (min 1). A tile whose working set exceeds the cache is served in *waves*:
  each wave pins at most ``num_slots`` chunks, gathers its lanes into the
  tile's gather buffer by masked select, and hands the slots back — so any
  budget down to a single chunk completes, it just streams more bytes
  (thrashing is visible in telemetry, exactly the trade-off the
  ``bench_outofcore`` sweep measures).
* **reuse-distance eviction** — the schedule is known ahead of time, so
  eviction is Belady-optimal: the resident chunk with the farthest next use
  goes first.
* **overlapped staging** — with ``prefetch_depth > 0`` a background host
  worker runs an exact *shadow copy* of the cache state machine a few tiles
  ahead of the consumer, gathering upcoming chunks (and sparse row residues)
  and fencing their device copies off the critical path. The consumer takes
  staged copies by key; every copy carries wall-clock start/stop timestamps
  (``jax.block_until_ready`` fenced), so ``StreamStats.copy_ms`` is the true
  cost of the copies and ``stall_ms`` the time the consumer actually blocked
  — ``prefetch_overlap = 1 - stall/copy`` is measured, not inferred. Slot
  decisions are made by the deterministic host state machine alone, so
  outputs are bitwise-identical with staging on or off.
* **sparse residue** — a visit whose chunk loses the Belady comparison (its
  next use is farther than every resident chunk's) bypasses the cache: only
  the rows the tile actually gathers move, as a padded row block scattered
  into the gather buffer — same values as a full-chunk upload, a small
  fraction of the bytes. Thrashing budgets stop streaming whole chunks to
  serve a handful of lanes (the reddit 1/8-budget pathology).

Bitwise contract: the streamed executors reproduce the in-memory engine
paths bit for bit. Gathered rows are exact copies of the dense rows (f32
chunks are row slices; int8 chunks match ``quantization.quantize`` under the
store's aggregation scale), tiles execute with the same per-tile op sequence
as the ``aggregate_edge_tiles`` scan body, and the schedule's reordering
permutes whole runs only, preserving every output row's scatter-add order
(see ``scheduler.tile_runs``). The FTE stream exploits exactness instead:
int8 matmuls accumulate in int32 (associativity-free), so chunk-blocked
execution equals the monolithic matmul, while the small float-protected
block is gathered and transformed in one piece.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from functools import partial
from typing import (
    Callable,
    Dict,
    FrozenSet,
    List,
    Mapping,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core.quantization import INT8_MAX, QuantParams
from repro.core.transformation import transform_dense
from repro.memory.feature_store import FeatureStore
from repro.observe import trace as otrace

__all__ = [
    "StreamStats",
    "StreamedFeatures",
    "DeviceTileStream",
    "make_device_tile_stream",
    "ChunkPrefetcher",
    "aggregate_streamed",
    "transform_streamed",
    "scale_add_streamed",
]

_INF = np.iinfo(np.int64).max


class DeviceTileStream(NamedTuple):
    """Device-resident per-tile plan arrays for the streamed executor.

    The instruction stream of one (plan, chunking) pair: coefficient /
    segment / scatter arrays plus the within-chunk lane offsets, uploaded
    once and indexed per tile on device. An engine caches one of these per
    (mode, tag, chunk_rows, reorder), so warm streamed requests move feature
    chunks only — zero plan bytes (regression-tested via
    ``StreamStats.instr_bytes``).
    """

    coeff: jnp.ndarray  # f32[T, E]
    seg_ids: jnp.ndarray  # int32[T, E]
    out_node: jnp.ndarray  # int32[T, S]
    lane_off: jnp.ndarray  # int32[T, E] row offset within the lane's chunk
    nbytes: int  # host->device bytes the upload cost (charged once, by owner)


def make_device_tile_stream(
    plan: "sched.EdgeTilePlan", schedule: "sched.ChunkSchedule"
) -> DeviceTileStream:
    """Upload one plan's tile arrays (+ the schedule's lane offsets)."""
    nbytes = (
        plan.coeff.nbytes
        + plan.seg_ids.nbytes
        + plan.out_node.nbytes
        + schedule.lane_off.nbytes
    )
    return DeviceTileStream(
        coeff=jnp.asarray(plan.coeff, jnp.float32),
        seg_ids=jnp.asarray(plan.seg_ids, jnp.int32),
        out_node=jnp.asarray(plan.out_node, jnp.int32),
        lane_off=jnp.asarray(schedule.lane_off, jnp.int32),
        nbytes=int(nbytes),
    )


@dataclasses.dataclass
class StreamStats:
    """Telemetry of one (or several merged) streamed executions.

    ``accesses = chunk_hits + chunk_misses`` counts tile→chunk visits;
    ``uploads = chunk_misses + prefetched`` counts non-hit servings (full
    chunk copies plus sparse-residue visits; a prefetched chunk's later
    visit is a hit). ``stall_ms``/``copy_ms`` are wall-clock: every feature
    copy is timestamped and device-fenced, and ``stall_ms`` accumulates only
    the time the consuming thread actually blocked, so
    ``prefetch_overlap = 1 - stall/copy`` reports how much of the copy cost
    was hidden behind compute. Both stay 0 on the synchronous path
    (``prefetch_depth == 0`` or ``async_stage=False``), where no overlap
    claim is made.
    """

    bytes_streamed: int = 0  # feature bytes moved host->device
    instr_bytes: int = 0  # per-tile plan arrays (the instruction stream)
    chunk_hits: int = 0
    chunk_misses: int = 0  # demand servings (visit found chunk absent)
    prefetched: int = 0  # uploads issued ahead of their first visit
    evictions: int = 0
    waves: int = 0
    tiles: int = 0
    fallbacks: int = 0  # dense materializations (budget violated, loud)
    fallback_bytes: int = 0
    sparse_rows: int = 0  # rows served as sparse residue (cache bypassed)
    stall_ms: float = 0.0  # consumer wall time blocked on feature copies
    copy_ms: float = 0.0  # wall time of the copies themselves (fenced)

    @property
    def accesses(self) -> int:
        return self.chunk_hits + self.chunk_misses

    @property
    def uploads(self) -> int:
        return self.chunk_misses + self.prefetched

    @property
    def hit_rate(self) -> float:
        return self.chunk_hits / self.accesses if self.accesses else 0.0

    @property
    def prefetch_overlap(self) -> float:
        """Wall-clock fraction of copy time hidden behind compute."""
        if self.copy_ms <= 0.0:
            return 0.0
        return min(max(1.0 - self.stall_ms / self.copy_ms, 0.0), 1.0)

    def merge(self, other: "StreamStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self) -> Dict[str, float]:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d["hit_rate"] = self.hit_rate
        d["prefetch_overlap"] = self.prefetch_overlap
        return d


class StreamedFeatures:
    """Handle standing in for a dense feature matrix on the streamed path.

    Carries the host store, the device feature budget and the telemetry the
    serving layer reads back. The engine's ``aggregate``/``transform`` accept
    it wherever they accept a dense array; arithmetic consumers use
    :func:`scale_add_streamed`.
    """

    def __init__(
        self,
        store: FeatureStore,
        budget_bytes: int,
        *,
        prefetch_depth: int = 1,
        reorder: bool = True,
        packing: bool = False,
        async_stage: bool = True,
    ):
        self.store = store
        self.budget_bytes = int(budget_bytes)
        self.prefetch_depth = int(prefetch_depth)
        self.reorder = bool(reorder)
        # packing: serve through chunk-packed tile plans
        # (scheduler.pack_tiles_by_chunk) instead of only reordering runs.
        self.packing = bool(packing)
        # async_stage: overlap host gathers/uploads with compute via the
        # staging worker (wall-clock stall/copy telemetry); False keeps the
        # fully synchronous path (same outputs bit for bit).
        self.async_stage = bool(async_stage)
        self.stats = StreamStats()
        # Per-request correlation id (observe.trace): stamped by the serving
        # engine before the forward pass, read by the prefetchers so every
        # copy/stall span carries the request it served.
        self.trace_id = ""

    @property
    def shape(self) -> Tuple[int, int]:
        return self.store.shape

    @property
    def ndim(self) -> int:
        return 2

    @property
    def nbytes(self) -> int:
        return self.store.nbytes

    def agg_qp(self) -> QuantParams:
        """The aggregation-stream QuantParams — bitwise-equal to
        ``compute_scale_zp(dense_x, symmetric=True)``."""
        scale = jnp.asarray(self.store.agg_scale, jnp.float32)
        return QuantParams(scale=scale, zero_point=jnp.zeros_like(scale))


# --------------------------------------------------------------- device ops
@partial(jax.jit, donate_argnums=(0,))
def _upload_slot(buf: jnp.ndarray, chunk: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice(buf, chunk[None], (slot, 0, 0))


@partial(jax.jit, donate_argnums=(0,))
def _gather_wave(
    gathered: jnp.ndarray,
    buf: jnp.ndarray,
    slot_idx: jnp.ndarray,
    off: jnp.ndarray,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    rows = buf[slot_idx, off]
    return jnp.where(mask[:, None], rows, gathered)


@partial(jax.jit, static_argnames=("segments_per_tile",), donate_argnums=(0,))
def _tile_step_f32(
    out: jnp.ndarray,
    gathered: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    out_node: jnp.ndarray,
    *,
    segments_per_tile: int,
) -> jnp.ndarray:
    partial_sums = jax.ops.segment_sum(
        gathered * coeff[:, None], seg_ids, num_segments=segments_per_tile
    )
    return out.at[out_node].add(partial_sums)


@partial(jax.jit, static_argnames=("segments_per_tile",), donate_argnums=(0,))
def _tile_step_i8(
    out: jnp.ndarray,
    gathered_q: jnp.ndarray,
    scale: jnp.ndarray,
    zero_point: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    out_node: jnp.ndarray,
    *,
    segments_per_tile: int,
) -> jnp.ndarray:
    # On-chip dequant after the 1-byte gather — same elementwise chain as the
    # in-memory path's whole-matrix dequantize followed by gather.
    gathered = ((gathered_q.astype(jnp.float32) - zero_point) * scale).astype(
        jnp.float32
    )
    partial_sums = jax.ops.segment_sum(
        gathered * coeff[:, None], seg_ids, num_segments=segments_per_tile
    )
    return out.at[out_node].add(partial_sums)


@partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(
    gathered: jnp.ndarray, rows: jnp.ndarray, lanes: jnp.ndarray
) -> jnp.ndarray:
    # Sparse residue: rows gathered host-side land directly on their lanes.
    # Padding entries carry an out-of-bounds lane index and are dropped.
    return gathered.at[lanes].set(rows, mode="drop")


# --------------------------------------------------------- cache state model
class _TileMoves(NamedTuple):
    hits: Tuple[int, ...]  # chunks already resident (pinned for the wave)
    uploads: Tuple[Tuple[int, int], ...]  # (chunk, slot) admitted this tile
    sparse: Tuple[int, ...]  # chunks served as row residue (not admitted)


class _CacheState:
    """Pure host model of the chunk cache: slot map + Belady cursors.

    Every decision is a deterministic function of (schedule, visit order),
    which is what makes staging exact: the worker advances a ``clone()`` of
    this state a few tiles ahead and the real execution replays the same
    moves. It is also why staged and unstaged runs are bitwise-identical —
    slot assignment never depends on timing.
    """

    __slots__ = (
        "num_slots", "positions", "cursor", "slot_of", "chunk_in", "free",
        "evictions",
    )

    def __init__(self, num_slots: int, positions: Dict[int, np.ndarray]):
        self.num_slots = int(num_slots)
        self.positions = positions  # shared, read-only
        self.cursor = {c: 0 for c in positions}
        self.slot_of: Dict[int, int] = {}
        self.chunk_in: List[int] = [-1] * self.num_slots
        self.free: List[int] = list(range(self.num_slots))
        self.evictions = 0

    def clone(self) -> "_CacheState":
        st = object.__new__(_CacheState)
        st.num_slots = self.num_slots
        st.positions = self.positions
        st.cursor = dict(self.cursor)
        st.slot_of = dict(self.slot_of)
        st.chunk_in = list(self.chunk_in)
        st.free = list(self.free)
        st.evictions = self.evictions
        return st

    def next_use(self, c: int) -> int:
        p = self.positions.get(c)
        if p is None:
            return _INF
        k = self.cursor[c]
        return int(p[k]) if k < p.size else _INF

    def _next_use_after(self, c: int) -> int:
        """Next visit position strictly after the one being served now."""
        p = self.positions.get(c)
        if p is None:
            return _INF
        k = self.cursor[c] + 1
        return int(p[k]) if k < p.size else _INF

    def _evict(self, pinned: set, *, min_use: int) -> Optional[int]:
        """Free the resident chunk with the farthest next use (Belady).

        A victim is taken only when its next use is strictly beyond
        ``min_use`` — callers pass the incoming chunk's next use, so an
        admission never displaces hotter data. Returns None when no
        admissible victim exists.
        """
        victim, victim_use = -1, min_use
        for slot, c in enumerate(self.chunk_in):
            if c < 0 or c in pinned:
                continue
            use = self.next_use(c)
            if use > victim_use:
                victim, victim_use = slot, use
        if victim < 0:
            return None
        del self.slot_of[self.chunk_in[victim]]
        self.chunk_in[victim] = -1
        self.evictions += 1
        return victim

    def _admit(self, c: int, slot: int) -> None:
        self.slot_of[c] = slot
        self.chunk_in[slot] = c

    def decide_tile(self, chunks: Sequence[int]) -> _TileMoves:
        """Serve one tile's chunk visits; commits slot/cursor state.

        Missing chunks are admitted into free slots, else over a Belady
        victim whose next use is strictly beyond the chunk's *own* next use
        after this visit (true Belady: if the incoming chunk is the
        farthest-future of all, admitting it would be the wrong eviction) —
        losers are served as sparse residue instead of thrashing a slot.
        """
        hits: List[int] = []
        uploads: List[Tuple[int, int]] = []
        sparse: List[int] = []
        pinned: set = set()
        for c in chunks:
            c = int(c)
            if c in self.slot_of:
                hits.append(c)
                pinned.add(c)
        for c in chunks:
            c = int(c)
            if c in pinned:
                continue
            if self.free:
                slot: Optional[int] = self.free.pop()
            else:
                slot = self._evict(pinned, min_use=self._next_use_after(c))
            if slot is None:
                sparse.append(c)
            else:
                self._admit(c, slot)
                uploads.append((c, slot))
                pinned.add(c)
        for c in chunks:
            c = int(c)
            if c in self.cursor:
                self.cursor[c] += 1
        return _TileMoves(tuple(hits), tuple(uploads), tuple(sparse))

    def prefetch_moves(
        self,
        pos: int,
        order: np.ndarray,
        tile_chunks: Sequence[np.ndarray],
        depth: int,
    ) -> List[Tuple[int, int]]:
        """Admissions for the next ``depth`` tiles' chunks; commits state.

        Free slots first, else a Belady-conditional eviction (victim's next
        use strictly beyond the prefetched chunk's); stops at the first
        chunk no slot will take.
        """
        moves: List[Tuple[int, int]] = []
        if depth <= 0:
            return moves
        for p in range(pos + 1, min(pos + 1 + depth, order.size)):
            for c in tile_chunks[int(order[p])]:
                c = int(c)
                if c in self.slot_of:
                    continue
                if self.free:
                    slot: Optional[int] = self.free.pop()
                else:
                    slot = self._evict(set(), min_use=self.next_use(c))
                    if slot is None:
                        return moves
                self._admit(c, slot)
                moves.append((c, slot))
        return moves


# ------------------------------------------------------------ staging worker
class _StagedItem:
    __slots__ = ("event", "value", "build_ms")

    def __init__(self):
        self.event = threading.Event()
        self.value = None
        self.build_ms = 0.0


class _StageWorker:
    """Background host thread building keyed feature copies ahead of use.

    Each request's build (host gather + device put) is timed and fenced
    with ``jax.block_until_ready`` inside the worker, so a consumed item's
    ``build_ms`` is the true wall cost of that copy and the consumer's
    event wait is the true stall. Items are one-shot: ``take`` removes the
    key, so a chunk uploaded, evicted, and staged again later gets a fresh
    build.
    """

    def __init__(self, build_fn: Callable[[tuple], object]):
        self._build = build_fn
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._items: Dict[tuple, _StagedItem] = {}
        self._lock = threading.Lock()
        self._dead = False
        self._thread = threading.Thread(
            target=self._run, name="chunk-stage", daemon=True
        )
        self._thread.start()

    @property
    def outstanding(self) -> int:
        with self._lock:
            return len(self._items)

    def request(self, key: tuple) -> bool:
        """Enqueue a build for ``key``; False if already staged/in flight."""
        with self._lock:
            if key in self._items:
                return False
            self._items[key] = _StagedItem()
        self._q.put(key)
        return True

    def take(self, key: tuple):
        """Blocking claim: ``(value, build_ms, wait_ms)`` or None."""
        with self._lock:
            item = self._items.get(key)
        if item is None:
            return None
        t0 = time.perf_counter()
        item.event.wait()
        wait_ms = (time.perf_counter() - t0) * 1e3
        with self._lock:
            self._items.pop(key, None)
        if item.value is None:
            return None
        return item.value, item.build_ms, wait_ms

    def _run(self) -> None:
        while True:
            key = self._q.get()
            if key is None:
                return
            with self._lock:
                item = self._items.get(key)
            if item is None or self._dead:
                if item is not None:
                    item.event.set()
                continue
            t0 = time.perf_counter()
            try:
                item.value = self._build(key)
            except Exception:  # consumer falls back to the inline build
                item.value = None
            item.build_ms = (time.perf_counter() - t0) * 1e3
            item.event.set()

    def stop(self) -> None:
        self._dead = True
        self._q.put(None)
        self._thread.join()


# ------------------------------------------------------------- chunk cache
class ChunkPrefetcher:
    """Fixed-budget device chunk cache executing one plan stream.

    One instance serves one precision stream of one aggregation call; the
    float and int8 streams run sequentially, so each gets the full budget.
    ``stream`` selects the representation: ``"f32"`` gathers raw rows,
    ``"i8"`` gathers 1-byte rows quantized under the store's agg scale.
    """

    def __init__(
        self,
        store: FeatureStore,
        schedule: sched.ChunkSchedule,
        *,
        stream: str,
        budget_bytes: int,
        prefetch_depth: int = 1,
        stats: Optional[StreamStats] = None,
        quant_scale=None,
        tiles: Optional[DeviceTileStream] = None,
        async_stage: bool = True,
        trace_id: str = "",
    ):
        if schedule.chunk_rows != store.chunk_rows:
            raise ValueError(
                f"schedule chunk_rows {schedule.chunk_rows} != store "
                f"{store.chunk_rows}"
            )
        if stream not in ("f32", "i8"):
            raise ValueError(f"unknown stream {stream!r}")
        self.store = store
        self.schedule = schedule
        self.stream = stream
        # The int8 stream must be quantized under the SAME scale it is later
        # dequantized with. A warm engine's static slot calibration may carry
        # an earlier request's scale; when it differs from this store's own,
        # chunks are re-quantized host-side on upload (bitwise-equal to
        # quantize(x, slot_qp) on the dense matrix) instead of using the
        # store's precomputed int8 representation.
        self.quant_scale = (
            np.float32(store.agg_scale) if quant_scale is None else np.float32(quant_scale)
        )
        self.prefetch_depth = max(int(prefetch_depth), 0)
        self.async_stage = bool(async_stage)
        self.stats = stats if stats is not None else StreamStats()
        self.trace_id = trace_id  # request id stamped on copy/stall spans
        # Device-cached instruction stream (owner charged its upload once);
        # None = upload per-tile plan slices per call (the uncached path,
        # used by direct ChunkPrefetcher construction).
        self.tiles = tiles
        self.chunk_bytes = (
            store.chunk_bytes_f32 if stream == "f32" else store.chunk_bytes_i8
        )
        slots = max(int(budget_bytes) // self.chunk_bytes, 1)
        self.num_slots = int(min(slots, max(schedule.num_chunks, 1)))
        dtype = jnp.float32 if stream == "f32" else jnp.int8
        self._buf = jnp.zeros(
            (self.num_slots, store.chunk_rows, store.dim), dtype
        )
        # Belady bookkeeping: per-chunk sorted visit positions + a cursor,
        # held by the deterministic cache state machine (the staging worker
        # simulates a clone of it a few tiles ahead).
        positions: Dict[int, List[int]] = {}
        for pos, t in enumerate(schedule.order):
            for c in schedule.tile_chunks[int(t)]:
                positions.setdefault(int(c), []).append(pos)
        self._state = _CacheState(
            self.num_slots,
            {c: np.asarray(p, np.int64) for c, p in positions.items()},
        )
        self._worker: Optional[_StageWorker] = None
        # Predicted sparse chunk set per schedule position (written by the
        # shadow pass, read by the worker's build and validated on consume).
        self._sparse_sets: Dict[int, FrozenSet[int]] = {}

    # -------------------------------------------------------------- metrics
    def stats_dict(self) -> Dict[str, float]:
        """Telemetry snapshot (counters + wall-clock stall/copy/overlap)."""
        return self.stats.as_dict()

    # ------------------------------------------------------------ plumbing
    def _host_chunk(self, c: int) -> np.ndarray:
        if self.stream == "f32":
            return self.store.chunk_f32(c)
        if self.quant_scale == self.store.agg_scale:
            return self.store.chunk_i8(c)  # precomputed under the same scale
        return FeatureStore._quantize_block(self.store.chunk_f32(c), self.quant_scale)

    def _host_rows(self, c: int, offs: np.ndarray) -> np.ndarray:
        """Row gather from one chunk in the stream's representation —
        bitwise the rows a full-chunk upload would have served (the int8
        re-quantization is elementwise, so a row subset quantizes
        identically to the same rows of the whole chunk)."""
        if self.stream == "f32":
            return self.store.chunk_f32(c)[offs]
        if self.quant_scale == self.store.agg_scale:
            return self.store.chunk_i8(c)[offs]
        return FeatureStore._quantize_block(
            self.store.chunk_f32(c)[offs], self.quant_scale
        )

    def _host_sparse(self, t: int, chunks: FrozenSet[int]):
        """Stage one tile's sparse residue: (lanes, rows, real row count).

        Gathers exactly the lanes whose source chunk was not admitted, pads
        the row count to a power-of-two bucket (stable device shapes) with
        out-of-bounds lane indices that the scatter drops, and fences the
        device copies so the caller's timestamps bound the true copy cost.
        """
        lane_chunk = self.schedule.lane_chunk[t]
        lane_off = self.schedule.lane_off[t]
        cs = np.fromiter(chunks, np.int64, len(chunks))
        sel = np.flatnonzero(np.isin(lane_chunk, cs))
        k = int(sel.size)
        kp = 1 << max(k - 1, 0).bit_length() if k else 1
        dtype = np.float32 if self.stream == "f32" else np.int8
        rows = np.zeros((kp, self.store.dim), dtype)
        sel_chunk = lane_chunk[sel]
        for c in sorted(chunks):
            m = np.flatnonzero(sel_chunk == c)
            if m.size:
                rows[m] = self._host_rows(int(c), lane_off[sel[m]])
        lanes = np.full(kp, lane_chunk.size, np.int32)  # OOB pad -> dropped
        lanes[:k] = sel
        staged = (jnp.asarray(lanes), jnp.asarray(rows), k)
        jax.block_until_ready(staged[:2])
        return staged

    def _build_staged(self, key: tuple):
        """Worker-side build: fenced device copies keyed like the consumer
        will claim them. The copy span is recorded here, on the staging
        thread's own timeline (lane "copy"), at the stamps the copy really
        occupied — which is what lets an exported trace show copies
        overlapping the consumer's compute."""
        t0 = time.perf_counter()
        if key[0] == "chunk":
            val = jax.block_until_ready(jnp.asarray(self._host_chunk(key[1])))
            name = "copy:chunk"
        else:
            _, pos, t = key
            val = self._host_sparse(t, self._sparse_sets.get(pos, frozenset()))
            name = "copy:rows"
        rec = otrace.get_recorder()
        if rec.enabled:
            rec.add_span(
                name, t0, time.perf_counter(), cat="stream", lane="copy",
                trace_id=self.trace_id, args={"stream": self.stream},
            )
        return val

    def _upload(self, c: int, slot: int, *, prefetch: bool) -> None:
        """Device copy of one admitted chunk (slot already committed by the
        state machine). Staged copies are claimed by key; unstaged ones are
        built inline and count fully as stall (the consumer blocked for the
        whole copy)."""
        rec = otrace.get_recorder()
        staged = (
            self._worker.take(("chunk", c)) if self._worker is not None else None
        )
        if staged is not None:
            dev, build_ms, wait_ms = staged
            self.stats.copy_ms += build_ms
            self.stats.stall_ms += wait_ms
            if rec.enabled and wait_ms > 0.0:
                # The wait just ended: reconstruct [t1 - wait, t1] from the
                # same measurement stall_ms accumulated.
                t1 = time.perf_counter()
                rec.add_span("stall", t1 - wait_ms / 1e3, t1, cat="stream",
                             trace_id=self.trace_id, args={"chunk": int(c)})
        elif self._worker is not None:
            t0 = time.perf_counter()
            dev = jax.block_until_ready(jnp.asarray(self._host_chunk(c)))
            t1 = time.perf_counter()
            dt = (t1 - t0) * 1e3
            self.stats.copy_ms += dt
            self.stats.stall_ms += dt
            if rec.enabled:
                # Unstaged inline build: one interval is both the copy and
                # the stall (the consumer blocked for the whole copy). Its
                # copy span gets its own lane — the staging thread may be
                # mid-copy on "copy" at the same instant.
                rec.add_span("copy:chunk", t0, t1, cat="stream",
                             lane="copy-inline", trace_id=self.trace_id,
                             args={"stream": self.stream, "inline": True})
                rec.add_span("stall", t0, t1, cat="stream",
                             trace_id=self.trace_id, args={"chunk": int(c)})
        else:  # synchronous path: untimed, no overlap claim
            dev = jnp.asarray(self._host_chunk(c))
        self._buf = _upload_slot(self._buf, dev, jnp.int32(slot))
        self.stats.bytes_streamed += self.chunk_bytes
        if prefetch:
            self.stats.prefetched += 1
        else:
            self.stats.chunk_misses += 1

    def _sparse_pass(
        self, pos: int, t: int, sparse: Tuple[int, ...], gathered: jnp.ndarray
    ) -> jnp.ndarray:
        """Scatter the tile's non-admitted chunks' rows onto their lanes."""
        chunks = frozenset(sparse)
        rec = otrace.get_recorder()
        staged = None
        if self._worker is not None and self._sparse_sets.get(pos) == chunks:
            staged = self._worker.take(("rows", pos, t))
        if staged is not None:
            (lanes_dev, rows_dev, k), build_ms, wait_ms = staged
            self.stats.copy_ms += build_ms
            self.stats.stall_ms += wait_ms
            if rec.enabled and wait_ms > 0.0:
                t1 = time.perf_counter()
                rec.add_span("stall", t1 - wait_ms / 1e3, t1, cat="stream",
                             trace_id=self.trace_id, args={"tile": int(t)})
        elif self._worker is not None:
            t0 = time.perf_counter()
            lanes_dev, rows_dev, k = self._host_sparse(t, chunks)
            t1 = time.perf_counter()
            dt = (t1 - t0) * 1e3
            self.stats.copy_ms += dt
            self.stats.stall_ms += dt
            if rec.enabled:
                rec.add_span("copy:rows", t0, t1, cat="stream",
                             lane="copy-inline", trace_id=self.trace_id,
                             args={"stream": self.stream, "inline": True})
                rec.add_span("stall", t0, t1, cat="stream",
                             trace_id=self.trace_id, args={"tile": int(t)})
        else:
            lanes_dev, rows_dev, k = self._host_sparse(t, chunks)
        self.stats.bytes_streamed += int(rows_dev.nbytes)
        self.stats.sparse_rows += k
        self.stats.chunk_misses += len(sparse)
        return _scatter_rows(gathered, rows_dev, lanes_dev)

    def _stage_ahead(
        self, shadow: _CacheState, shadow_pos: int, pos: int
    ) -> int:
        """Advance the shadow state machine so tiles up to ``pos + depth``
        have their demand uploads, prefetches and sparse residues staged.
        The shadow replays exactly the decisions the real state will make
        (both are deterministic), so every request key matches a future
        consume. Pauses when too many items are outstanding."""
        order = self.schedule.order
        cap = 2 * (self.prefetch_depth + 1) + self.num_slots + 8
        while shadow_pos < order.size and shadow_pos <= pos + self.prefetch_depth:
            if self._worker.outstanding >= cap:
                break
            t = int(order[shadow_pos])
            mv = shadow.decide_tile(self.schedule.tile_chunks[t])
            for c, _slot in mv.uploads:
                self._worker.request(("chunk", c))
            if mv.sparse:
                self._sparse_sets[shadow_pos] = frozenset(mv.sparse)
                self._worker.request(("rows", shadow_pos, t))
            for c, _slot in shadow.prefetch_moves(
                shadow_pos, order, self.schedule.tile_chunks, self.prefetch_depth
            ):
                self._worker.request(("chunk", c))
            shadow_pos += 1
        return shadow_pos

    # ----------------------------------------------------------- execution
    def aggregate(
        self,
        plan: sched.EdgeTilePlan,
        *,
        qp: Optional[QuantParams] = None,
    ) -> jnp.ndarray:
        """Stream one plan's tiles through the cache; returns f32[N, D].

        Bitwise-identical to ``aggregate_edge_tiles`` on the dense matrix
        (f32 stream) / on the dequantized matrix (i8 stream): same gathered
        values (resident chunks by masked select, sparse residues by row
        scatter onto disjoint lanes), same per-tile op sequence, per-row
        scatter order preserved by the run-respecting schedule. Staging
        changes when copies happen, never what the device computes.
        """
        if self.stream == "i8" and qp is None:
            raise ValueError("int8 stream needs the aggregation QuantParams")
        S = plan.segments_per_tile
        n = plan.num_nodes
        lanes = plan.gather_idx.shape[1]
        out = jnp.zeros((n + 1, self.store.dim), jnp.float32)
        lane_bytes = plan.gather_idx[0].nbytes + plan.coeff[0].nbytes + (
            plan.seg_ids[0].nbytes + plan.out_node[0].nbytes
        )
        order = self.schedule.order
        state = self._state
        shadow: Optional[_CacheState] = None
        shadow_pos = 0
        if self.async_stage and self.prefetch_depth > 0 and order.size > 1:
            self._worker = _StageWorker(self._build_staged)
            shadow = state.clone()
        rec = otrace.get_recorder()
        agg_t0 = time.perf_counter() if rec.enabled else 0.0
        try:
            for pos, t in enumerate(order):
                t = int(t)
                if shadow is not None:
                    shadow_pos = self._stage_ahead(shadow, shadow_pos, pos)
                # (chunk, offset) lane splits are plan-static — precomputed
                # on the schedule at plan time, not re-derived per request.
                lane_chunk = self.schedule.lane_chunk[t]
                lane_off = (
                    self.tiles.lane_off[t]
                    if self.tiles is not None
                    else jnp.asarray(self.schedule.lane_off[t], jnp.int32)
                )
                gathered = jnp.zeros(
                    (lanes,) + (self.store.dim,),
                    jnp.float32 if self.stream == "f32" else jnp.int8,
                )
                self.stats.tiles += 1
                ev0 = state.evictions
                moves = state.decide_tile(self.schedule.tile_chunks[t])
                self.stats.evictions += state.evictions - ev0
                self.stats.chunk_hits += len(moves.hits)
                for c, slot in moves.uploads:
                    self._upload(c, slot, prefetch=False)
                wave = moves.hits + tuple(c for c, _ in moves.uploads)
                if wave:
                    slot_lut = np.zeros(self.schedule.num_chunks, np.int32)
                    in_wave = np.zeros(self.schedule.num_chunks, bool)
                    for c in wave:
                        slot_lut[c] = state.slot_of[c]
                        in_wave[c] = True
                    mask = in_wave[lane_chunk]
                    slot_idx = jnp.asarray(slot_lut[lane_chunk], jnp.int32)
                    gathered = _gather_wave(
                        gathered, self._buf, slot_idx, lane_off, jnp.asarray(mask)
                    )
                    self.stats.waves += 1
                if moves.sparse:
                    gathered = self._sparse_pass(pos, t, moves.sparse, gathered)
                if self.tiles is not None:
                    # Device-resident instruction stream: indexing a cached
                    # array is a device-side slice, not an upload — warm
                    # requests move zero plan bytes.
                    coeff = self.tiles.coeff[t]
                    seg_ids = self.tiles.seg_ids[t]
                    out_node = self.tiles.out_node[t]
                else:
                    coeff = jnp.asarray(plan.coeff[t])
                    seg_ids = jnp.asarray(plan.seg_ids[t])
                    out_node = jnp.asarray(plan.out_node[t])
                    self.stats.instr_bytes += lane_bytes
                if self.stream == "f32":
                    out = _tile_step_f32(
                        out, gathered, coeff, seg_ids, out_node,
                        segments_per_tile=S,
                    )
                else:
                    out = _tile_step_i8(
                        out, gathered, qp.scale, qp.zero_point, coeff, seg_ids,
                        out_node, segments_per_tile=S,
                    )
                ev0 = state.evictions
                for c, slot in state.prefetch_moves(
                    pos, order, self.schedule.tile_chunks, self.prefetch_depth
                ):
                    self._upload(c, slot, prefetch=True)
                self.stats.evictions += state.evictions - ev0
        finally:
            if self._worker is not None:
                self._worker.stop()
                self._worker = None
            if rec.enabled:
                rec.add_span(
                    f"stream:{self.stream}", agg_t0, time.perf_counter(),
                    cat="stream", trace_id=self.trace_id,
                    args={"tiles": int(order.size),
                          "staged": self.async_stage and self.prefetch_depth > 0},
                )
        return out[:n]


# -------------------------------------------------------- streamed executors
def aggregate_streamed(
    sf: StreamedFeatures,
    plans: Mapping[str, sched.EdgeTilePlan],
    schedules: Mapping[str, sched.ChunkSchedule],
    *,
    num_nodes: int,
    mixed: bool,
    qp: Optional[QuantParams] = None,
    tiles: Optional[Mapping[str, DeviceTileStream]] = None,
) -> jnp.ndarray:
    """Chunk-streamed mirror of the engine's aggregation dispatch.

    ``mixed`` replays ``aggregate_mixed_precision``'s combine order exactly
    (zeros + float stream + int8 stream); non-mixed returns the float stream
    alone, matching the engine's direct ``aggregate_edge_tiles`` call.
    ``tiles`` carries the caller's device-cached instruction streams per tag
    (warm requests then re-upload zero plan bytes).
    """
    for tag in plans:
        if tag not in ("float", "int8"):
            raise ValueError(f"unknown precision tag {tag!r}")

    def run(tag: str, stream: str, qp_: Optional[QuantParams]) -> jnp.ndarray:
        pf = ChunkPrefetcher(
            sf.store,
            schedules[tag],
            stream=stream,
            budget_bytes=sf.budget_bytes,
            prefetch_depth=sf.prefetch_depth,
            stats=sf.stats,
            quant_scale=(
                np.float32(np.asarray(qp_.scale)) if qp_ is not None else None
            ),
            tiles=tiles.get(tag) if tiles is not None else None,
            async_stage=sf.async_stage,
            trace_id=sf.trace_id,
        )
        return pf.aggregate(plans[tag], qp=qp_)

    if not mixed:
        return run("float", "f32", None)
    out = jnp.zeros((num_nodes, sf.store.dim), jnp.float32)
    if "float" in plans:
        out = out + run("float", "f32", None)
    if "int8" in plans:
        out = out + run("int8", "i8", qp if qp is not None else sf.agg_qp())
    return out


def _host_fte_qp(amax: np.float32) -> QuantParams:
    """Host mirror of ``compute_scale_zp(rows, symmetric=True)`` given the
    exact row-set amax (max never rounds, the scalar ops are IEEE-exact)."""
    scale = np.maximum(
        np.float32(amax / np.float32(INT8_MAX)), np.float32(1e-8)
    )
    scale_j = jnp.asarray(scale, jnp.float32)
    return QuantParams(scale=scale_j, zero_point=jnp.zeros_like(scale_j))


def transform_streamed(
    sf: StreamedFeatures,
    node_group_ids: Mapping[str, np.ndarray],
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    *,
    w_q: jnp.ndarray,
    w_qp: QuantParams,
    a_qp: Optional[QuantParams] = None,
) -> jnp.ndarray:
    """Mixed-precision FTE over stored features, bitwise-equal to
    ``transform_mixed_precision`` on the dense matrix.

    The float-protected block (a few % of nodes under Degree-Quant) is
    host-gathered and transformed in one matmul — identical shape and values
    to the in-memory group matmul. The int8 block streams chunk-blocked:
    rows are quantized host-side under ``a_qp`` and move as 1-byte elements,
    and the int8×int8→int32 matmul accumulates exactly, so per-chunk blocks
    equal the monolithic matmul row for row.
    """
    store = sf.store
    rec = otrace.get_recorder()
    fte_t0 = time.perf_counter() if rec.enabled else 0.0
    out = jnp.zeros((store.num_rows, w.shape[1]), jnp.float32)
    for tag, ids in node_group_ids.items():
        if ids.size == 0:
            continue
        ids = np.asarray(ids, np.int64)
        if tag == "float":
            rows = jnp.asarray(store.gather_rows_f32(ids))
            sf.stats.bytes_streamed += int(rows.size) * 4
            y = transform_dense(rows, w, b, activation)
            out = out.at[jnp.asarray(ids, jnp.int32)].set(y)
        elif tag == "int8":
            if a_qp is None:
                a_qp = _host_fte_qp(store.amax_rows(ids))
            scale_np = np.float32(np.asarray(a_qp.scale))
            # Same expression as transform_int8's dequant coefficient.
            deq = a_qp.scale * w_qp.scale.reshape(1, -1)
            chunk_of = np.unique(ids // store.chunk_rows)
            for c in chunk_of:
                _, local = store.chunk_row_selection(int(c), ids)
                if local.size == 0:
                    continue
                lo, hi = store.chunk_range(int(c))
                blk = store.chunk_f32(int(c))[: hi - lo]
                # Host quantize under the FTE scale (shared helper, bitwise
                # == quantization.quantize with zp=0); whole-chunk rows keep
                # the device shapes stable, non-group rows are computed and
                # discarded (matmul rows independent).
                hq = jnp.asarray(FeatureStore._quantize_block(blk, scale_np))
                sf.stats.bytes_streamed += int(hq.size)
                acc = jnp.dot(
                    hq.astype(jnp.int32),
                    w_q.astype(jnp.int32),
                    preferred_element_type=jnp.int32,
                )
                y = acc.astype(jnp.float32) * deq
                if b is not None:
                    y = y + b
                if activation is not None:
                    y = activation(y)
                out = out.at[jnp.asarray(lo + local, jnp.int32)].set(
                    y[jnp.asarray(local, jnp.int32)]
                )
        else:
            raise ValueError(f"unknown precision tag {tag!r}")
    if rec.enabled:
        rec.add_span(
            "stream:fte", fte_t0, time.perf_counter(), cat="stream",
            trace_id=sf.trace_id,
        )
    return out


def scale_add_streamed(
    sf: StreamedFeatures, alpha, m: jnp.ndarray
) -> jnp.ndarray:
    """Chunk-streamed ``alpha * x + m`` (GIN's aggregation-side residual).

    Elementwise per row, so chunk blocks concatenate to the exact dense
    result; streams the f32 representation once.
    """
    store = sf.store
    if m.shape[0] != store.num_rows:
        raise ValueError(
            f"residual rows {m.shape[0]} != store rows {store.num_rows}"
        )
    parts = []
    for c in range(store.num_chunks):
        lo, hi = store.chunk_range(c)
        blk = jnp.asarray(store.chunk_f32(c)[: hi - lo])
        sf.stats.bytes_streamed += int(blk.size) * 4
        parts.append(alpha * blk + m[lo:hi])
    return jnp.concatenate(parts, axis=0)
