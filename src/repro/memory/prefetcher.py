"""Plan-driven chunk prefetcher + streamed executors (out-of-core serving).

``ChunkPrefetcher`` executes a ``core.scheduler.ChunkSchedule`` against a
fixed-budget device chunk cache:

* **budget** — the cache is ``num_slots`` shape-stable slots of
  ``chunk_rows`` feature rows; ``num_slots = budget_bytes // chunk_bytes``
  (min 1). A tile whose working set exceeds the cache is served in *waves*:
  each wave pins at most ``num_slots`` chunks, gathers its lanes into the
  tile's gather buffer by masked select, and hands the slots back — so any
  budget down to a single chunk completes, it just streams more bytes
  (thrashing is visible in telemetry, exactly the trade-off the
  ``bench_outofcore`` sweep measures).
* **reuse-distance eviction** — the schedule is known ahead of time, so
  eviction is Belady-optimal: the resident chunk with the farthest next use
  goes first.
* **double buffering** — after a tile's step is issued (async dispatch),
  chunks for the next ``prefetch_depth`` tiles are uploaded into free slots
  so the copy overlaps the running tile's aggregation; the overlap fraction
  (prefetched / total uploads) is reported in :class:`StreamStats`.

Bitwise contract: the streamed executors reproduce the in-memory engine
paths bit for bit. Gathered rows are exact copies of the dense rows (f32
chunks are row slices; int8 chunks match ``quantization.quantize`` under the
store's aggregation scale), tiles execute with the same per-tile op sequence
as the ``aggregate_edge_tiles`` scan body, and the schedule's reordering
permutes whole runs only, preserving every output row's scatter-add order
(see ``scheduler.tile_runs``). The FTE stream exploits exactness instead:
int8 matmuls accumulate in int32 (associativity-free), so chunk-blocked
execution equals the monolithic matmul, while the small float-protected
block is gathered and transformed in one piece.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core.quantization import INT8_MAX, QuantParams
from repro.core.transformation import transform_dense
from repro.memory.feature_store import FeatureStore

__all__ = [
    "StreamStats",
    "StreamedFeatures",
    "DeviceTileStream",
    "make_device_tile_stream",
    "ChunkPrefetcher",
    "aggregate_streamed",
    "transform_streamed",
    "scale_add_streamed",
]

_INF = np.iinfo(np.int64).max


class DeviceTileStream(NamedTuple):
    """Device-resident per-tile plan arrays for the streamed executor.

    The instruction stream of one (plan, chunking) pair: coefficient /
    segment / scatter arrays plus the within-chunk lane offsets, uploaded
    once and indexed per tile on device. An engine caches one of these per
    (mode, tag, chunk_rows, reorder), so warm streamed requests move feature
    chunks only — zero plan bytes (regression-tested via
    ``StreamStats.instr_bytes``).
    """

    coeff: jnp.ndarray  # f32[T, E]
    seg_ids: jnp.ndarray  # int32[T, E]
    out_node: jnp.ndarray  # int32[T, S]
    lane_off: jnp.ndarray  # int32[T, E] row offset within the lane's chunk
    nbytes: int  # host->device bytes the upload cost (charged once, by owner)


def make_device_tile_stream(
    plan: "sched.EdgeTilePlan", schedule: "sched.ChunkSchedule"
) -> DeviceTileStream:
    """Upload one plan's tile arrays (+ the schedule's lane offsets)."""
    nbytes = (
        plan.coeff.nbytes
        + plan.seg_ids.nbytes
        + plan.out_node.nbytes
        + schedule.lane_off.nbytes
    )
    return DeviceTileStream(
        coeff=jnp.asarray(plan.coeff, jnp.float32),
        seg_ids=jnp.asarray(plan.seg_ids, jnp.int32),
        out_node=jnp.asarray(plan.out_node, jnp.int32),
        lane_off=jnp.asarray(schedule.lane_off, jnp.int32),
        nbytes=int(nbytes),
    )


@dataclasses.dataclass
class StreamStats:
    """Telemetry of one (or several merged) streamed executions.

    ``accesses = chunk_hits + chunk_misses`` counts tile→chunk visits;
    ``uploads = chunk_misses + prefetched`` counts host→device chunk copies
    (a prefetched chunk's later visit is a hit, its copy overlapped compute).
    """

    bytes_streamed: int = 0  # feature bytes moved host->device
    instr_bytes: int = 0  # per-tile plan arrays (the instruction stream)
    chunk_hits: int = 0
    chunk_misses: int = 0  # demand uploads (visit found chunk absent)
    prefetched: int = 0  # uploads issued ahead of their first visit
    evictions: int = 0
    waves: int = 0
    tiles: int = 0
    fallbacks: int = 0  # dense materializations (budget violated, loud)
    fallback_bytes: int = 0

    @property
    def accesses(self) -> int:
        return self.chunk_hits + self.chunk_misses

    @property
    def uploads(self) -> int:
        return self.chunk_misses + self.prefetched

    @property
    def hit_rate(self) -> float:
        return self.chunk_hits / self.accesses if self.accesses else 0.0

    @property
    def prefetch_overlap(self) -> float:
        """Fraction of chunk copies that overlapped compute (double buffer)."""
        return self.prefetched / self.uploads if self.uploads else 0.0

    def merge(self, other: "StreamStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self) -> Dict[str, float]:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d["hit_rate"] = self.hit_rate
        d["prefetch_overlap"] = self.prefetch_overlap
        return d


class StreamedFeatures:
    """Handle standing in for a dense feature matrix on the streamed path.

    Carries the host store, the device feature budget and the telemetry the
    serving layer reads back. The engine's ``aggregate``/``transform`` accept
    it wherever they accept a dense array; arithmetic consumers use
    :func:`scale_add_streamed`.
    """

    def __init__(
        self,
        store: FeatureStore,
        budget_bytes: int,
        *,
        prefetch_depth: int = 1,
        reorder: bool = True,
    ):
        self.store = store
        self.budget_bytes = int(budget_bytes)
        self.prefetch_depth = int(prefetch_depth)
        self.reorder = bool(reorder)
        self.stats = StreamStats()

    @property
    def shape(self) -> Tuple[int, int]:
        return self.store.shape

    @property
    def ndim(self) -> int:
        return 2

    @property
    def nbytes(self) -> int:
        return self.store.nbytes

    def agg_qp(self) -> QuantParams:
        """The aggregation-stream QuantParams — bitwise-equal to
        ``compute_scale_zp(dense_x, symmetric=True)``."""
        scale = jnp.asarray(self.store.agg_scale, jnp.float32)
        return QuantParams(scale=scale, zero_point=jnp.zeros_like(scale))


# --------------------------------------------------------------- device ops
@partial(jax.jit, donate_argnums=(0,))
def _upload_slot(buf: jnp.ndarray, chunk: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice(buf, chunk[None], (slot, 0, 0))


@partial(jax.jit, donate_argnums=(0,))
def _gather_wave(
    gathered: jnp.ndarray,
    buf: jnp.ndarray,
    slot_idx: jnp.ndarray,
    off: jnp.ndarray,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    rows = buf[slot_idx, off]
    return jnp.where(mask[:, None], rows, gathered)


@partial(jax.jit, static_argnames=("segments_per_tile",), donate_argnums=(0,))
def _tile_step_f32(
    out: jnp.ndarray,
    gathered: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    out_node: jnp.ndarray,
    *,
    segments_per_tile: int,
) -> jnp.ndarray:
    partial_sums = jax.ops.segment_sum(
        gathered * coeff[:, None], seg_ids, num_segments=segments_per_tile
    )
    return out.at[out_node].add(partial_sums)


@partial(jax.jit, static_argnames=("segments_per_tile",), donate_argnums=(0,))
def _tile_step_i8(
    out: jnp.ndarray,
    gathered_q: jnp.ndarray,
    scale: jnp.ndarray,
    zero_point: jnp.ndarray,
    coeff: jnp.ndarray,
    seg_ids: jnp.ndarray,
    out_node: jnp.ndarray,
    *,
    segments_per_tile: int,
) -> jnp.ndarray:
    # On-chip dequant after the 1-byte gather — same elementwise chain as the
    # in-memory path's whole-matrix dequantize followed by gather.
    gathered = ((gathered_q.astype(jnp.float32) - zero_point) * scale).astype(
        jnp.float32
    )
    partial_sums = jax.ops.segment_sum(
        gathered * coeff[:, None], seg_ids, num_segments=segments_per_tile
    )
    return out.at[out_node].add(partial_sums)


# ------------------------------------------------------------- chunk cache
class ChunkPrefetcher:
    """Fixed-budget device chunk cache executing one plan stream.

    One instance serves one precision stream of one aggregation call; the
    float and int8 streams run sequentially, so each gets the full budget.
    ``stream`` selects the representation: ``"f32"`` gathers raw rows,
    ``"i8"`` gathers 1-byte rows quantized under the store's agg scale.
    """

    def __init__(
        self,
        store: FeatureStore,
        schedule: sched.ChunkSchedule,
        *,
        stream: str,
        budget_bytes: int,
        prefetch_depth: int = 1,
        stats: Optional[StreamStats] = None,
        quant_scale=None,
        tiles: Optional[DeviceTileStream] = None,
    ):
        if schedule.chunk_rows != store.chunk_rows:
            raise ValueError(
                f"schedule chunk_rows {schedule.chunk_rows} != store "
                f"{store.chunk_rows}"
            )
        if stream not in ("f32", "i8"):
            raise ValueError(f"unknown stream {stream!r}")
        self.store = store
        self.schedule = schedule
        self.stream = stream
        # The int8 stream must be quantized under the SAME scale it is later
        # dequantized with. A warm engine's static slot calibration may carry
        # an earlier request's scale; when it differs from this store's own,
        # chunks are re-quantized host-side on upload (bitwise-equal to
        # quantize(x, slot_qp) on the dense matrix) instead of using the
        # store's precomputed int8 representation.
        self.quant_scale = (
            np.float32(store.agg_scale) if quant_scale is None else np.float32(quant_scale)
        )
        self.prefetch_depth = max(int(prefetch_depth), 0)
        self.stats = stats if stats is not None else StreamStats()
        # Device-cached instruction stream (owner charged its upload once);
        # None = upload per-tile plan slices per call (the uncached path,
        # used by direct ChunkPrefetcher construction).
        self.tiles = tiles
        self.chunk_bytes = (
            store.chunk_bytes_f32 if stream == "f32" else store.chunk_bytes_i8
        )
        slots = max(int(budget_bytes) // self.chunk_bytes, 1)
        self.num_slots = int(min(slots, max(schedule.num_chunks, 1)))
        dtype = jnp.float32 if stream == "f32" else jnp.int8
        self._buf = jnp.zeros(
            (self.num_slots, store.chunk_rows, store.dim), dtype
        )
        self._slot_of: Dict[int, int] = {}
        self._chunk_in: List[int] = [-1] * self.num_slots
        self._free: List[int] = list(range(self.num_slots))
        # Belady bookkeeping: per-chunk sorted visit positions + a cursor.
        self._positions: Dict[int, np.ndarray] = {}
        self._cursor: Dict[int, int] = {}
        for pos, t in enumerate(schedule.order):
            for c in schedule.tile_chunks[int(t)]:
                self._positions.setdefault(int(c), []).append(pos)  # type: ignore[arg-type]
        self._positions = {
            c: np.asarray(p, np.int64) for c, p in self._positions.items()
        }
        self._cursor = {c: 0 for c in self._positions}

    # ------------------------------------------------------------ plumbing
    def _host_chunk(self, c: int) -> np.ndarray:
        if self.stream == "f32":
            return self.store.chunk_f32(c)
        if self.quant_scale == self.store.agg_scale:
            return self.store.chunk_i8(c)  # precomputed under the same scale
        return FeatureStore._quantize_block(self.store.chunk_f32(c), self.quant_scale)

    def _next_use(self, c: int) -> int:
        p = self._positions.get(c)
        if p is None:
            return _INF
        k = self._cursor[c]
        return int(p[k]) if k < p.size else _INF

    def _consume(self, c: int) -> None:
        if c in self._cursor:
            self._cursor[c] += 1

    def _evict_slot(self, pinned: set, *, min_use: int = -1) -> Optional[int]:
        """Free the resident chunk with the farthest next use (Belady).

        ``min_use`` makes the eviction conditional: a victim is only taken
        when its next use is strictly beyond it — the prefetch path passes
        the incoming chunk's next use so prefetching never displaces hotter
        data. Returns None when no admissible victim exists.
        """
        victim, victim_use = -1, min_use
        for slot, c in enumerate(self._chunk_in):
            if c < 0 or c in pinned:
                continue
            use = self._next_use(c)
            if use > victim_use:
                victim, victim_use = slot, use
        if victim < 0:
            return None
        del self._slot_of[self._chunk_in[victim]]
        self._chunk_in[victim] = -1
        self.stats.evictions += 1
        return victim

    def _upload(self, c: int, slot: int, *, prefetch: bool) -> None:
        self._buf = _upload_slot(
            self._buf, jnp.asarray(self._host_chunk(c)), jnp.int32(slot)
        )
        self._slot_of[c] = slot
        self._chunk_in[slot] = c
        self.stats.bytes_streamed += self.chunk_bytes
        if prefetch:
            self.stats.prefetched += 1
        else:
            self.stats.chunk_misses += 1

    def _prefetch_ahead(self, pos: int) -> None:
        """Upload chunks the next ``prefetch_depth`` tiles need so the copy
        overlaps the just-issued tile step (async dispatch) — into free slots
        first, else by evicting a resident chunk whose next use is strictly
        farther than the prefetched chunk's (the Belady comparison, so
        prefetching never displaces hotter data)."""
        if self.prefetch_depth <= 0:
            return
        order = self.schedule.order
        for p in range(pos + 1, min(pos + 1 + self.prefetch_depth, order.size)):
            for c in self.schedule.tile_chunks[int(order[p])]:
                c = int(c)
                if c in self._slot_of:
                    continue
                if self._free:
                    slot = self._free.pop()
                else:
                    slot = self._evict_slot(set(), min_use=self._next_use(c))
                    if slot is None:
                        return
                self._upload(c, slot, prefetch=True)

    # ----------------------------------------------------------- execution
    def aggregate(
        self,
        plan: sched.EdgeTilePlan,
        *,
        qp: Optional[QuantParams] = None,
    ) -> jnp.ndarray:
        """Stream one plan's tiles through the cache; returns f32[N, D].

        Bitwise-identical to ``aggregate_edge_tiles`` on the dense matrix
        (f32 stream) / on the dequantized matrix (i8 stream): same gathered
        values, same per-tile op sequence, per-row scatter order preserved
        by the run-respecting schedule.
        """
        if self.stream == "i8" and qp is None:
            raise ValueError("int8 stream needs the aggregation QuantParams")
        S = plan.segments_per_tile
        n = plan.num_nodes
        lanes = plan.gather_idx.shape[1]
        out = jnp.zeros((n + 1, self.store.dim), jnp.float32)
        lane_bytes = plan.gather_idx[0].nbytes + plan.coeff[0].nbytes + (
            plan.seg_ids[0].nbytes + plan.out_node[0].nbytes
        )
        for pos, t in enumerate(self.schedule.order):
            t = int(t)
            # (chunk, offset) lane splits are plan-static — precomputed on
            # the schedule at plan time, not re-derived per request.
            lane_chunk = self.schedule.lane_chunk[t]
            lane_off = (
                self.tiles.lane_off[t]
                if self.tiles is not None
                else jnp.asarray(self.schedule.lane_off[t], jnp.int32)
            )
            todo = [int(c) for c in self.schedule.tile_chunks[t]]
            gathered = jnp.zeros(
                (lanes,) + (self.store.dim,),
                jnp.float32 if self.stream == "f32" else jnp.int8,
            )
            self.stats.tiles += 1
            while todo:
                wave: List[int] = []
                pinned: set = set()
                rest: List[int] = []
                for c in todo:
                    if c in self._slot_of:
                        wave.append(c)
                        pinned.add(c)
                        self.stats.chunk_hits += 1
                    else:
                        rest.append(c)
                for c in list(rest):
                    if len(pinned) >= self.num_slots:
                        break
                    if self._free:
                        slot = self._free.pop()
                    else:
                        slot = self._evict_slot(pinned)
                        if slot is None:
                            break
                    self._upload(c, slot, prefetch=False)
                    wave.append(c)
                    pinned.add(c)
                    rest.remove(c)
                for c in wave:
                    self._consume(c)
                slot_lut = np.zeros(self.schedule.num_chunks, np.int32)
                in_wave = np.zeros(self.schedule.num_chunks, bool)
                for c in wave:
                    slot_lut[c] = self._slot_of[c]
                    in_wave[c] = True
                mask = in_wave[lane_chunk]
                slot_idx = jnp.asarray(slot_lut[lane_chunk], jnp.int32)
                gathered = _gather_wave(
                    gathered, self._buf, slot_idx, lane_off, jnp.asarray(mask)
                )
                self.stats.waves += 1
                todo = rest
            if self.tiles is not None:
                # Device-resident instruction stream: indexing a cached
                # array is a device-side slice, not an upload — warm
                # requests move zero plan bytes.
                coeff = self.tiles.coeff[t]
                seg_ids = self.tiles.seg_ids[t]
                out_node = self.tiles.out_node[t]
            else:
                coeff = jnp.asarray(plan.coeff[t])
                seg_ids = jnp.asarray(plan.seg_ids[t])
                out_node = jnp.asarray(plan.out_node[t])
                self.stats.instr_bytes += lane_bytes
            if self.stream == "f32":
                out = _tile_step_f32(
                    out, gathered, coeff, seg_ids, out_node, segments_per_tile=S
                )
            else:
                out = _tile_step_i8(
                    out, gathered, qp.scale, qp.zero_point, coeff, seg_ids,
                    out_node, segments_per_tile=S,
                )
            self._prefetch_ahead(pos)
        return out[:n]


# -------------------------------------------------------- streamed executors
def aggregate_streamed(
    sf: StreamedFeatures,
    plans: Mapping[str, sched.EdgeTilePlan],
    schedules: Mapping[str, sched.ChunkSchedule],
    *,
    num_nodes: int,
    mixed: bool,
    qp: Optional[QuantParams] = None,
    tiles: Optional[Mapping[str, DeviceTileStream]] = None,
) -> jnp.ndarray:
    """Chunk-streamed mirror of the engine's aggregation dispatch.

    ``mixed`` replays ``aggregate_mixed_precision``'s combine order exactly
    (zeros + float stream + int8 stream); non-mixed returns the float stream
    alone, matching the engine's direct ``aggregate_edge_tiles`` call.
    ``tiles`` carries the caller's device-cached instruction streams per tag
    (warm requests then re-upload zero plan bytes).
    """
    for tag in plans:
        if tag not in ("float", "int8"):
            raise ValueError(f"unknown precision tag {tag!r}")

    def run(tag: str, stream: str, qp_: Optional[QuantParams]) -> jnp.ndarray:
        pf = ChunkPrefetcher(
            sf.store,
            schedules[tag],
            stream=stream,
            budget_bytes=sf.budget_bytes,
            prefetch_depth=sf.prefetch_depth,
            stats=sf.stats,
            quant_scale=(
                np.float32(np.asarray(qp_.scale)) if qp_ is not None else None
            ),
            tiles=tiles.get(tag) if tiles is not None else None,
        )
        return pf.aggregate(plans[tag], qp=qp_)

    if not mixed:
        return run("float", "f32", None)
    out = jnp.zeros((num_nodes, sf.store.dim), jnp.float32)
    if "float" in plans:
        out = out + run("float", "f32", None)
    if "int8" in plans:
        out = out + run("int8", "i8", qp if qp is not None else sf.agg_qp())
    return out


def _host_fte_qp(amax: np.float32) -> QuantParams:
    """Host mirror of ``compute_scale_zp(rows, symmetric=True)`` given the
    exact row-set amax (max never rounds, the scalar ops are IEEE-exact)."""
    scale = np.maximum(
        np.float32(amax / np.float32(INT8_MAX)), np.float32(1e-8)
    )
    scale_j = jnp.asarray(scale, jnp.float32)
    return QuantParams(scale=scale_j, zero_point=jnp.zeros_like(scale_j))


def transform_streamed(
    sf: StreamedFeatures,
    node_group_ids: Mapping[str, np.ndarray],
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    *,
    w_q: jnp.ndarray,
    w_qp: QuantParams,
    a_qp: Optional[QuantParams] = None,
) -> jnp.ndarray:
    """Mixed-precision FTE over stored features, bitwise-equal to
    ``transform_mixed_precision`` on the dense matrix.

    The float-protected block (a few % of nodes under Degree-Quant) is
    host-gathered and transformed in one matmul — identical shape and values
    to the in-memory group matmul. The int8 block streams chunk-blocked:
    rows are quantized host-side under ``a_qp`` and move as 1-byte elements,
    and the int8×int8→int32 matmul accumulates exactly, so per-chunk blocks
    equal the monolithic matmul row for row.
    """
    store = sf.store
    out = jnp.zeros((store.num_rows, w.shape[1]), jnp.float32)
    for tag, ids in node_group_ids.items():
        if ids.size == 0:
            continue
        ids = np.asarray(ids, np.int64)
        if tag == "float":
            rows = jnp.asarray(store.gather_rows_f32(ids))
            sf.stats.bytes_streamed += int(rows.size) * 4
            y = transform_dense(rows, w, b, activation)
            out = out.at[jnp.asarray(ids, jnp.int32)].set(y)
        elif tag == "int8":
            if a_qp is None:
                a_qp = _host_fte_qp(store.amax_rows(ids))
            scale_np = np.float32(np.asarray(a_qp.scale))
            # Same expression as transform_int8's dequant coefficient.
            deq = a_qp.scale * w_qp.scale.reshape(1, -1)
            chunk_of = np.unique(ids // store.chunk_rows)
            for c in chunk_of:
                _, local = store.chunk_row_selection(int(c), ids)
                if local.size == 0:
                    continue
                lo, hi = store.chunk_range(int(c))
                blk = store.chunk_f32(int(c))[: hi - lo]
                # Host quantize under the FTE scale (shared helper, bitwise
                # == quantization.quantize with zp=0); whole-chunk rows keep
                # the device shapes stable, non-group rows are computed and
                # discarded (matmul rows independent).
                hq = jnp.asarray(FeatureStore._quantize_block(blk, scale_np))
                sf.stats.bytes_streamed += int(hq.size)
                acc = jnp.dot(
                    hq.astype(jnp.int32),
                    w_q.astype(jnp.int32),
                    preferred_element_type=jnp.int32,
                )
                y = acc.astype(jnp.float32) * deq
                if b is not None:
                    y = y + b
                if activation is not None:
                    y = activation(y)
                out = out.at[jnp.asarray(lo + local, jnp.int32)].set(
                    y[jnp.asarray(local, jnp.int32)]
                )
        else:
            raise ValueError(f"unknown precision tag {tag!r}")
    return out


def scale_add_streamed(
    sf: StreamedFeatures, alpha, m: jnp.ndarray
) -> jnp.ndarray:
    """Chunk-streamed ``alpha * x + m`` (GIN's aggregation-side residual).

    Elementwise per row, so chunk blocks concatenate to the exact dense
    result; streams the f32 representation once.
    """
    store = sf.store
    if m.shape[0] != store.num_rows:
        raise ValueError(
            f"residual rows {m.shape[0]} != store rows {store.num_rows}"
        )
    parts = []
    for c in range(store.num_chunks):
        lo, hi = store.chunk_range(c)
        blk = jnp.asarray(store.chunk_f32(c)[: hi - lo])
        sf.stats.bytes_streamed += int(blk.size) * 4
        parts.append(alpha * blk + m[lo:hi])
    return jnp.concatenate(parts, axis=0)
