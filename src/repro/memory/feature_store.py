"""Chunked host-resident feature storage — the off-chip half of out-of-core.

The paper's evaluation graphs (Reddit 233K, Yelp 717K nodes) carry feature
matrices of several hundred MB; AMPLE keeps them in off-chip HBM and streams
neighbour rows through the Feature Bank. ``FeatureStore`` is that HBM tier
for the TPU repro: the matrix lives on the host, split into fixed-row chunks
held in **two representations**:

* ``f32`` chunks — raw rows, gathered by the float-precision plan stream;
* ``int8`` chunks — rows quantized under the *aggregation* scale/zero-point
  (the same per-tensor symmetric calibration ``AmpleEngine`` would compute on
  the dense matrix), gathered by the int8 plan stream so unprotected-node
  traffic moves 1-byte elements end-to-end (MEGA's memory-footprint reading
  of Degree-Quant).

Bitwise contract: every value handed to the device is bit-identical to what
the in-memory path would produce. The aggregation scale is computed chunk-wise
on the host with the exact op sequence of ``quantization.compute_scale_zp``
(max is exact, the scalar divide/clamp are IEEE-exact), and chunk quantization
matches ``quantization.quantize`` element for element — both are asserted by
tests, and the streamed executors inherit bitwise identity from them.

``memmap_dir`` backs both representations with ``np.memmap`` files so host
RSS stays bounded for larger-than-RAM matrices.
"""
from __future__ import annotations

import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["FeatureStore", "default_chunk_rows"]

_INT8_MIN, _INT8_MAX = -128, 127
_EPS = np.float32(1e-8)


def default_chunk_rows(num_rows: int, dim: int, budget_bytes: int) -> int:
    """Pick a chunk row count for a feature budget: ~1/16 of the budget per
    f32 chunk (so the cache holds a meaningful working set and the last-chunk
    padding waste stays small), clamped to [256, 65536] and the matrix size."""
    if budget_bytes <= 0:
        target = 4096
    else:
        target = budget_bytes // max(16 * 4 * dim, 1)
    r = 256
    while r * 2 <= target and r < 65536:
        r *= 2
    return int(min(max(r, 256), max(num_rows, 1)))


class FeatureStore:
    """Host-resident chunked feature matrix with f32 + int8 streams.

    Attributes
    ----------
    num_rows, dim: logical matrix shape (rows beyond ``num_rows`` in the last
        chunk are zero padding and are never gathered).
    chunk_rows: rows per chunk; all chunks are padded to this row count so
        device cache slots are shape-stable.
    agg_scale: the per-tensor symmetric int8 scale of the whole matrix —
        bitwise-equal to ``compute_scale_zp(x, symmetric=True).scale``.
    """

    def __init__(
        self,
        chunks_f32: Sequence[np.ndarray],
        chunks_i8: Sequence[np.ndarray],
        num_rows: int,
        chunk_rows: int,
        agg_scale: np.float32,
    ):
        self._f32 = list(chunks_f32)
        self._i8 = list(chunks_i8)
        self.num_rows = int(num_rows)
        self.dim = int(self._f32[0].shape[1]) if self._f32 else 0
        self.chunk_rows = int(chunk_rows)
        self.agg_scale = np.float32(agg_scale)

    # ------------------------------------------------------------- factory
    @classmethod
    def from_array(
        cls,
        x: np.ndarray,
        *,
        chunk_rows: int = 4096,
        memmap_dir: Optional[str] = None,
    ) -> "FeatureStore":
        """Chunk a dense f32 matrix; derive the int8 stream and its scale.

        Without ``memmap_dir`` the f32 chunks are zero-copy views of ``x``
        (except a padded copy of the last chunk) and only the int8 stream
        allocates (¼ of the matrix). With it, both streams are written to
        ``features.f32.bin`` / ``features.i8.bin`` memmaps in that directory.
        """
        x = np.ascontiguousarray(x, np.float32)
        if x.ndim != 2:
            raise ValueError(f"features must be 2-D, got shape {x.shape}")
        n, d = x.shape
        r = int(min(max(chunk_rows, 1), max(n, 1)))
        num_chunks = -(-max(n, 1) // r)
        padded_rows = num_chunks * r

        # Chunk-wise symmetric calibration: max is exact, so this equals the
        # dense compute_scale_zp bitwise (padding rows are 0 and cannot raise
        # the amax since amax >= 0).
        amax = np.float32(0.0)
        for lo in range(0, n, r):
            blk = x[lo : lo + r]
            if blk.size:
                amax = np.maximum(amax, np.float32(np.max(np.abs(blk))))
        scale = np.maximum(np.float32(amax / np.float32(_INT8_MAX)), _EPS)

        if memmap_dir is not None:
            os.makedirs(memmap_dir, exist_ok=True)
            f32_mm = np.memmap(
                os.path.join(memmap_dir, "features.f32.bin"),
                dtype=np.float32, mode="w+", shape=(padded_rows, d),
            )
            i8_mm = np.memmap(
                os.path.join(memmap_dir, "features.i8.bin"),
                dtype=np.int8, mode="w+", shape=(padded_rows, d),
            )
            f32_mm[:n] = x
            if padded_rows > n:
                f32_mm[n:] = 0.0
            for lo in range(0, padded_rows, r):
                i8_mm[lo : lo + r] = cls._quantize_block(
                    f32_mm[lo : lo + r], scale
                )
            chunks_f32 = [f32_mm[lo : lo + r] for lo in range(0, padded_rows, r)]
            chunks_i8 = [i8_mm[lo : lo + r] for lo in range(0, padded_rows, r)]
        else:
            chunks_f32, chunks_i8 = [], []
            for lo in range(0, padded_rows, r):
                blk = x[lo : min(lo + r, n)]
                if blk.shape[0] < r:  # pad the ragged last chunk
                    pad = np.zeros((r, d), np.float32)
                    pad[: blk.shape[0]] = blk
                    blk = pad
                chunks_f32.append(blk)
                chunks_i8.append(cls._quantize_block(blk, scale))
        return cls(chunks_f32, chunks_i8, n, r, scale)

    @staticmethod
    def _quantize_block(blk: np.ndarray, scale: np.float32) -> np.ndarray:
        """Host mirror of ``quantization.quantize`` (symmetric, zp=0):
        round/clip/cast are all exactly-rounded, so this matches the jnp op
        bit for bit."""
        q = np.round(blk / scale)
        return np.clip(q, _INT8_MIN, _INT8_MAX).astype(np.int8)

    # ------------------------------------------------------------ geometry
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.num_rows, self.dim)

    @property
    def ndim(self) -> int:
        return 2

    @property
    def num_chunks(self) -> int:
        return len(self._f32)

    @property
    def nbytes(self) -> int:
        """Logical f32 footprint — what the in-memory path would upload."""
        return self.num_rows * self.dim * 4

    @property
    def chunk_bytes_f32(self) -> int:
        return self.chunk_rows * self.dim * 4

    @property
    def chunk_bytes_i8(self) -> int:
        return self.chunk_rows * self.dim

    def chunk_range(self, c: int) -> Tuple[int, int]:
        """Real (unpadded) row span [lo, hi) of chunk ``c``."""
        lo = c * self.chunk_rows
        return lo, min(lo + self.chunk_rows, self.num_rows)

    # -------------------------------------------------------------- access
    def chunk_f32(self, c: int) -> np.ndarray:
        return self._f32[c]

    def chunk_i8(self, c: int) -> np.ndarray:
        return self._i8[c]

    def gather_rows_f32(self, row_ids: np.ndarray) -> np.ndarray:
        """Host gather of arbitrary rows (used for the small float-protected
        FTE block); returns a fresh [len(row_ids), dim] f32 array."""
        row_ids = np.asarray(row_ids, np.int64)
        out = np.empty((row_ids.size, self.dim), np.float32)
        chunk_of = row_ids // self.chunk_rows
        off = row_ids % self.chunk_rows
        for c in np.unique(chunk_of):
            sel = chunk_of == c
            out[sel] = self._f32[c][off[sel]]
        return out

    def amax_rows(self, row_ids: np.ndarray) -> np.float32:
        """max |x[row_ids]| computed chunk-wise (exact — max never rounds)."""
        row_ids = np.asarray(row_ids, np.int64)
        chunk_of = row_ids // self.chunk_rows
        off = row_ids % self.chunk_rows
        amax = np.float32(0.0)
        for c in np.unique(chunk_of):
            rows = self._f32[c][off[chunk_of == c]]
            if rows.size:
                amax = np.maximum(amax, np.float32(np.max(np.abs(rows))))
        return amax

    def chunk_row_selection(self, c: int, row_ids_sorted: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(global positions into ``row_ids_sorted``, local offsets in chunk)
        of the given sorted row ids that fall inside chunk ``c``."""
        lo, hi = c * self.chunk_rows, (c + 1) * self.chunk_rows
        a = np.searchsorted(row_ids_sorted, lo, side="left")
        b = np.searchsorted(row_ids_sorted, hi, side="left")
        sel = row_ids_sorted[a:b]
        return np.arange(a, b, dtype=np.int64), sel - lo

    def dense(self) -> np.ndarray:
        """Materialize the full f32 matrix (budget-violating fallback path —
        callers count it so it is loud in telemetry)."""
        out = np.empty((self.num_rows, self.dim), np.float32)
        for c in range(self.num_chunks):
            lo, hi = self.chunk_range(c)
            out[lo:hi] = self._f32[c][: hi - lo]
        return out
