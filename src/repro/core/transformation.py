"""Feature Transformation Engine (FTE) — the regular-compute phase.

The paper's FTE is a systolic array fed diagonally from the Aggregation
Buffer; on TPU this is simply the MXU, so the FTE is a (mixed-precision)
matmul stream:

* float stream  — fp32/bf16 ``h @ W`` for Degree-Quant-protected nodes;
* int8 stream   — int8×int8→int32 with per-channel dequant for the rest
  (kernels/quant_matmul is the Pallas version; the jnp path here is its
  oracle and the CPU fallback).

``transform_mixed_precision`` routes disjoint node sets through the two
streams — the isolated per-precision NoC sub-networks of §3.2.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import (
    QuantParams,
    compute_scale_zp,
    dequantize,
    quantize,
    quantize_per_channel,
)

__all__ = [
    "transform_dense",
    "transform_int8",
    "transform_mixed_precision",
]


def transform_dense(
    h: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Float FTE stream: y = act(h @ W + b)."""
    y = h @ w
    if b is not None:
        y = y + b
    if activation is not None:
        y = activation(y)
    return y


def transform_int8(
    h: jnp.ndarray,
    w_q: jnp.ndarray,
    w_qp: QuantParams,
    b: Optional[jnp.ndarray] = None,
    activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    a_qp: Optional[QuantParams] = None,
    use_kernel: bool = False,
    w_packed=None,
) -> jnp.ndarray:
    """int8 FTE stream: symmetric-quantized activations × per-channel int8
    weights, int32 accumulate, float de-quant — the MXU int8 path.

    y ≈ (s_a s_w) · (h_q @ W_q), since both quantizations are symmetric (z=0).

    ``w_packed`` is an optional ``kernels.quant_matmul.RepackedWeight`` (the
    load-time Marlin-style tiling of ``w_q``); when given with ``use_kernel``
    the matmul skips the per-call weight pad/stride — bitwise-identical int32.
    """
    if a_qp is None:
        a_qp = compute_scale_zp(h, symmetric=True)
    h_q = quantize(h, a_qp)
    if use_kernel:
        from repro.kernels.quant_matmul import ops as qm_ops

        if w_packed is not None:
            acc = qm_ops.quant_matmul_repacked(h_q, w_packed)
        else:
            acc = qm_ops.quant_matmul(h_q, w_q)
    else:
        acc = jnp.dot(
            h_q.astype(jnp.int32),
            w_q.astype(jnp.int32),
            preferred_element_type=jnp.int32,
        )
    y = acc.astype(jnp.float32) * (a_qp.scale * w_qp.scale.reshape(1, -1))
    if b is not None:
        y = y + b
    if activation is not None:
        y = activation(y)
    return y


def transform_mixed_precision(
    h: jnp.ndarray,
    node_group_ids: Dict[str, np.ndarray],
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    *,
    w_q: Optional[jnp.ndarray] = None,
    w_qp: Optional[QuantParams] = None,
    a_qp: Optional[QuantParams] = None,
    use_kernel: bool = False,
    w_packed=None,
) -> jnp.ndarray:
    """Route each precision group's rows through its FTE stream.

    ``node_group_ids`` maps precision tag → node indices (disjoint cover of
    rows of ``h``). Weight int8 copies are derived once if not provided;
    ``a_qp`` fixes the int8 activation scale/zero-point (per-call min/max
    calibration over the int8 rows otherwise — the engine passes its static
    per-plan state here).
    """
    out = jnp.zeros((h.shape[0], w.shape[1]), jnp.float32)
    for tag, ids in node_group_ids.items():
        if ids.size == 0:
            continue
        ids_j = jnp.asarray(ids, jnp.int32)
        rows = h[ids_j]
        if tag == "float":
            y = transform_dense(rows, w, b, activation)
        elif tag == "int8":
            if w_q is None or w_qp is None:
                w_q, w_qp = quantize_per_channel(w, axis=-1)
            y = transform_int8(
                rows,
                w_q,
                w_qp,
                b,
                activation,
                a_qp=a_qp,
                use_kernel=use_kernel,
                w_packed=w_packed,
            )
        else:
            raise ValueError(f"unknown precision tag {tag!r}")
        out = out.at[ids_j].set(y)
    return out
