"""Affine int8 quantization utilities (Eq. 5 of the paper) + fake-quant STE.

These are the numerical foundations for both halves of the framework:
* the GNN engine quantizes unprotected node embeddings / weights to int8 and
  runs them through the int8 FTE stream (kernels/quant_matmul);
* the LM half reuses per-channel weight quantization for int8 serving.

Quantization follows Eq. 5:  x_q = clip(round(x/s + z), q_min, q_max)
De-quantization:             x̂  = (x_q - z) * s
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantParams",
    "compute_scale_zp",
    "quantize",
    "dequantize",
    "fake_quant",
    "quantize_per_channel",
    "INT8_MIN",
    "INT8_MAX",
]

INT8_MIN = -128
INT8_MAX = 127


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Scale/zero-point pair; arrays broadcast against the quantized tensor."""

    scale: jnp.ndarray  # f32, scalar or per-channel
    zero_point: jnp.ndarray  # f32 (kept float; rounding applied at quantize)

    def tree_flatten(self):  # noqa: D401 - pytree protocol
        return (self.scale, self.zero_point), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    QuantParams, QuantParams.tree_flatten, QuantParams.tree_unflatten
)


def compute_scale_zp(
    x: jnp.ndarray,
    *,
    axis: Optional[int] = None,
    symmetric: bool = True,
    qmin: int = INT8_MIN,
    qmax: int = INT8_MAX,
    eps: float = 1e-8,
) -> QuantParams:
    """Min/max calibration. ``axis`` keeps that axis (per-channel); None is
    per-tensor. Symmetric mode (z=0) matches MXU-friendly int8 matmuls."""
    if axis is None:
        lo = jnp.min(x)
        hi = jnp.max(x)
    else:
        red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        lo = jnp.min(x, axis=red, keepdims=True)
        hi = jnp.max(x, axis=red, keepdims=True)
    if symmetric:
        amax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        scale = jnp.maximum(amax / qmax, eps)
        zp = jnp.zeros_like(scale)
    else:
        scale = jnp.maximum((hi - lo) / (qmax - qmin), eps)
        zp = qmin - lo / scale
    return QuantParams(scale=scale.astype(jnp.float32), zero_point=zp.astype(jnp.float32))


def quantize(
    x: jnp.ndarray,
    qp: QuantParams,
    *,
    qmin: int = INT8_MIN,
    qmax: int = INT8_MAX,
    dtype=jnp.int8,
) -> jnp.ndarray:
    """Eq. 5: clip(round(x/s + z))."""
    q = jnp.round(x / qp.scale + qp.zero_point)
    return jnp.clip(q, qmin, qmax).astype(dtype)


def dequantize(xq: jnp.ndarray, qp: QuantParams, dtype=jnp.float32) -> jnp.ndarray:
    return ((xq.astype(jnp.float32) - qp.zero_point) * qp.scale).astype(dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def fake_quant(
    x: jnp.ndarray,
    qp: QuantParams,
    axis: Optional[int] = None,
    qmin: int = INT8_MIN,
    qmax: int = INT8_MAX,
) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through estimator (QAT forward).

    Gradients pass through unchanged inside the representable range and are
    zeroed outside it (the standard STE with range clipping used by
    Degree-Quant)."""
    return dequantize(quantize(x, qp, qmin=qmin, qmax=qmax, dtype=jnp.int32), qp)


def _fq_fwd(x, qp, axis, qmin, qmax):
    y = fake_quant(x, qp, axis, qmin, qmax)
    inside = jnp.logical_and(
        x / qp.scale + qp.zero_point >= qmin, x / qp.scale + qp.zero_point <= qmax
    )
    return y, (inside, qp)


def _fq_bwd(axis, qmin, qmax, res, g):
    inside, qp = res
    gx = jnp.where(inside, g, 0.0)
    zero_qp = QuantParams(
        scale=jnp.zeros_like(qp.scale), zero_point=jnp.zeros_like(qp.zero_point)
    )
    return gx, zero_qp


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def quantize_per_channel(
    w: np.ndarray | jnp.ndarray, *, axis: int = -1
) -> Tuple[jnp.ndarray, QuantParams]:
    """Symmetric per-channel weight quantization; returns (int8 weights, qp)."""
    w = jnp.asarray(w)
    qp = compute_scale_zp(w, axis=axis, symmetric=True)
    return quantize(w, qp), qp
