"""Discrete-event simulator of the AMPLE accelerator (Alveo U280 @ 200 MHz).

No FPGA exists in this environment, so the paper's *evaluation* (Table 5 /
Figure 4 latencies) is reproduced with a cycle-level discrete-event model of
the microarchitecture in Section 3:

* **Nodeslots (NID)** — ``num_nodeslots`` independent slots; a slot is
  reprogrammed by the host the moment its node completes (event-driven flow).
  The double-buffered baseline mode instead batches ``num_nodeslots`` nodes
  and waits for the slowest before refilling (HyGCN-style), which reproduces
  the pipeline-gap penalty the paper argues against.
* **Mixed precision** — slots are split between float and int8 pools per the
  Degree-Quant tags (Eq. 6; the paper found 1 float slot usually suffices).
  int8 nodes move 1-byte features and aggregate twice as wide.
* **Prefetcher / Feature Bank** — each slot's Fetch Tag streams neighbour
  embeddings from HBM through one of 32 banks (round-robin groups). The
  **partial response** mechanism starts aggregation after the first
  ``fetch_tag_capacity`` neighbours; the remainder streams concurrently.
* **AGE / FTE** — aggregation consumes ``agg_lanes`` feature elements/cycle
  per slot; transformation is a shared 32×32 systolic array processing nodes
  FIFO after aggregation.

Constants are microarchitectural estimates (the paper publishes none); the
calibration test checks the simulated Table 5 latencies land within a small
factor of the published numbers and — more importantly — that the *speedup
structure* (event-driven ≫ double-buffered on skewed graphs; gap widening
with degree variance) reproduces.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graphs.csr import Graph

__all__ = ["SimConfig", "SimResult", "simulate", "simulate_dataset"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    clock_hz: float = 200e6
    num_nodeslots: int = 64
    float_slots: int = 1  # Eq. 6 outcome: one float slot usually suffices
    hbm_banks: int = 32
    hbm_bank_bytes_per_cycle: float = 32.0  # 64b DDR @2x clock ≈ 32 B/cycle/bank
    fetch_tag_capacity: int = 64  # neighbours buffered before partial response
    agg_lanes: int = 16  # feature elements/cycle/slot (VPU-like)
    fte_macs: int = 32 * 32  # systolic array MACs/cycle (shared)
    instr_overhead_cycles: int = 32  # NID programming + interrupt per node
    event_driven: bool = True  # False = double-buffered baseline
    # Prefetcher lookahead (§3.3): with depth P, a slot's next fetch is
    # issued up to P × (its previous node's aggregation time) before the
    # slot frees, hiding HBM latency behind the running aggregation. 0
    # reproduces the historical no-lookahead timing exactly; the measured
    # counterpart is memory/prefetcher.py's chunk cache (see the
    # bench_prefetch_calibration sweep).
    prefetch_depth: int = 0


@dataclasses.dataclass
class SimResult:
    cycles: float
    latency_ms: float
    nodes_per_ms: float
    slot_busy_frac: float
    fetch_stall_frac: float
    fte_queue_peak: int


def _node_cycles(
    deg: int, feat: int, out_feat: int, is_float: bool, cfg: SimConfig
) -> Tuple[float, float, float]:
    """(fetch_cycles, agg_cycles, fte_cycles) for one node."""
    bytes_per_el = 4 if is_float else 1
    fetch_bytes = deg * feat * bytes_per_el
    fetch = fetch_bytes / cfg.hbm_bank_bytes_per_cycle  # one bank granted
    lanes = cfg.agg_lanes * (1 if is_float else 2)  # int8 packs 2x lanes
    agg = deg * feat / lanes
    fte = feat * out_feat / cfg.fte_macs / (1 if is_float else 2)
    return fetch, agg, fte


def simulate(
    g: Graph,
    *,
    feature_dim: Optional[int] = None,
    out_dim: Optional[int] = None,
    float_mask: Optional[np.ndarray] = None,
    cfg: SimConfig = SimConfig(),
) -> SimResult:
    """Simulate one GNN layer (aggregate + transform) over every node."""
    n = g.num_nodes
    feat = feature_dim or (g.features.shape[1] if g.features is not None else 64)
    out = out_dim or feat
    deg = g.degrees
    if float_mask is None:
        float_mask = np.zeros(n, bool)

    # Precompute per-node phase durations (cycles) — vectorized.
    bytes_per_el = np.where(float_mask, 4.0, 1.0)
    lanes = cfg.agg_lanes * np.where(float_mask, 1.0, 2.0)
    fetch_c = deg * feat * bytes_per_el / cfg.hbm_bank_bytes_per_cycle
    agg_c = deg * feat / lanes
    fte_c = feat * out / cfg.fte_macs / np.where(float_mask, 1.0, 2.0)

    # Event-driven: slots free independently. We model each slot's timeline
    # with a heap of (free_time, slot); HBM banks arbitrate via per-bank
    # next-free times (round-robin assignment); the FTE is a single FIFO
    # server. Partial response: aggregation may start after the first
    # `fetch_tag_capacity` neighbours have landed; the tail of the fetch and
    # the aggregation then proceed in parallel (aggregation rate-limited by
    # whichever is slower).
    if cfg.event_driven:
        order = np.argsort(-deg, kind="stable")  # host issues longest-first (LPT)
    else:
        order = np.arange(n)  # static pipeline streams nodes in id order
    slots = [(0.0, s) for s in range(cfg.num_nodeslots)]
    heapq.heapify(slots)
    bank_free = np.zeros(cfg.hbm_banks)
    fte_free = 0.0
    busy = 0.0
    fetch_stall = 0.0
    fte_queue_peak = 0
    fte_inflight: List[float] = []
    t_end = 0.0

    if cfg.event_driven:
        prev_agg = np.zeros(cfg.num_nodeslots)  # last agg duration per slot
        for idx, v in enumerate(order):
            free_t, slot = heapq.heappop(slots)
            start = free_t + cfg.instr_overhead_cycles
            bank = slot % cfg.hbm_banks
            # Prefetch lookahead: the slot's fetch may be issued while its
            # previous node was still aggregating (depth × that duration).
            lookahead = cfg.prefetch_depth * prev_agg[slot]
            fstart = max(start - lookahead, bank_free[bank])
            # partial response: agg starts when the first chunk has landed
            first_chunk = fetch_c[v] * min(
                1.0, cfg.fetch_tag_capacity / max(int(deg[v]), 1)
            )
            agg_start = max(start, fstart + first_chunk)
            # stall = slot cycles spent waiting on data (bank grant + first
            # chunk arrival); the prefetcher's whole purpose is shrinking it.
            fetch_stall += agg_start - start
            agg_end = max(agg_start + agg_c[v], fstart + fetch_c[v])
            bank_free[bank] = fstart + fetch_c[v]
            prev_agg[slot] = agg_c[v]
            fte_start = max(agg_end, fte_free)
            fte_end = fte_start + fte_c[v]
            fte_free = fte_end
            while fte_inflight and fte_inflight[0] <= agg_end:
                heapq.heappop(fte_inflight)
            heapq.heappush(fte_inflight, fte_end)
            fte_queue_peak = max(fte_queue_peak, len(fte_inflight))
            heapq.heappush(slots, (agg_end, slot))  # slot frees after AGE
            busy += agg_end - start
            t_end = max(t_end, fte_end)
    else:
        # Double-buffered baseline: fill all slots, wait for the SLOWEST
        # aggregation in the batch before refilling (no slot recycling).
        t = 0.0
        for b0 in range(0, n, cfg.num_nodeslots):
            batch = order[b0 : b0 + cfg.num_nodeslots]
            batch_end = t
            for j, v in enumerate(batch):
                bank = j % cfg.hbm_banks
                fstart = max(t + cfg.instr_overhead_cycles, bank_free[bank])
                first_chunk = fetch_c[v] * min(
                    1.0, cfg.fetch_tag_capacity / max(int(deg[v]), 1)
                )
                agg_end = max(fstart + first_chunk + agg_c[v], fstart + fetch_c[v])
                bank_free[bank] = fstart + fetch_c[v]
                fte_start = max(agg_end, fte_free)
                fte_free = fte_start + fte_c[v]
                busy += agg_end - t
                batch_end = max(batch_end, agg_end)
            t = batch_end  # pipeline gap: everyone waits for the straggler
            t_end = max(t_end, fte_free)

    total_slot_time = t_end * cfg.num_nodeslots
    cycles = t_end
    return SimResult(
        cycles=cycles,
        latency_ms=cycles / cfg.clock_hz * 1e3,
        nodes_per_ms=n / (cycles / cfg.clock_hz * 1e3),
        slot_busy_frac=busy / max(total_slot_time, 1.0),
        fetch_stall_frac=fetch_stall / max(total_slot_time, 1.0),
        fte_queue_peak=fte_queue_peak,
    )


def simulate_dataset(
    name: str,
    *,
    model: str = "gcn",
    cfg: SimConfig = SimConfig(),
    seed: int = 0,
    max_nodes: Optional[int] = None,
) -> Dict[str, float]:
    """Table-5 style record for one dataset (layer dims from Table 4)."""
    from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags
    from repro.graphs.datasets import PAPER_DATASETS, make_dataset

    spec = PAPER_DATASETS[name]
    g = make_dataset(name, seed=seed, with_features=False, max_nodes=max_nodes)
    tags = inference_precision_tags(
        g, DegreeQuantConfig(float_ratio=spec.dq_float_ratio)
    )
    fmask = tags == "float"
    hidden = 16 if model == "gcn" else 64
    res = simulate(
        g, feature_dim=spec.feature_dim, out_dim=hidden, float_mask=fmask, cfg=cfg
    )
    scale = spec.num_nodes / g.num_nodes  # if size-reduced, extrapolate
    return {
        "dataset": name,
        "nodes": spec.num_nodes,
        "latency_ms": res.latency_ms * scale,
        "nodes_per_ms": res.nodes_per_ms,
        "slot_busy_frac": res.slot_busy_frac,
        "event_driven": cfg.event_driven,
    }
