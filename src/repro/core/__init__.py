"""AMPLE core: event-driven scheduling, Degree-Quant, mixed-precision AGE/FTE."""
from repro.core.scheduler import (
    EdgeTilePlan, BucketPlan, PaddedPlan,
    build_edge_tile_plan, build_bucket_plan, build_padded_plan,
    build_mixed_precision_plans, pack_segments,
    split_plan_by_halo, tile_runs,
    graph_fingerprint, plan_fingerprint,
    partition_fingerprint, shard_plan_fingerprint,
)
from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags, sample_protection_mask
from repro.core.aggregation import tile_edge_coeff
from repro.core.message_passing import (
    AmpleEngine, EngineConfig, ExecutionPlan, ShardPlan, ShardedExecutionPlan,
    aggregation_coefficients, assemble_union_plan, compile_plans,
    compile_shard_plan, compile_sharded_plans,
)
