"""Degree-Quant (Tailor et al. 2020) — node-granularity precision assignment.

The paper uses Degree-Quant twice:
* offline, to tag each node ``float`` (protected) or ``int8`` — Table 4's "DQ
  ratio" is the resulting float fraction;
* during QAT, to stochastically protect nodes (Bernoulli with degree-
  interpolated probability) so the quantization error that concentrates in
  high-degree aggregations does not corrupt training.

Both modes live here, plus Eq. 6's resource-to-nodeslot allocation, which the
TPU engine reuses to split tile lanes between the float and int8 execution
streams.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Tuple

import numpy as np

from repro.graphs.csr import Graph

__all__ = [
    "DegreeQuantConfig",
    "protection_probabilities",
    "sample_protection_mask",
    "inference_precision_tags",
    "allocate_nodeslots",
]


@dataclasses.dataclass(frozen=True)
class DegreeQuantConfig:
    p_min: float = 0.0  # protection probability of the min-degree node
    p_max: float = 0.1  # protection probability of the max-degree node
    float_ratio: float = 0.03  # inference-time protected fraction (Table 4 <3%)


def protection_probabilities(g: Graph, cfg: DegreeQuantConfig) -> np.ndarray:
    """Per-node Bernoulli protection probability, interpolated in degree.

    The paper interpolates within [p_min, p_max], assigning the limits to the
    graph's min/max neighbour counts. Interpolation is done on *rank-normalised
    log degree* — heavy-tailed degree distributions would otherwise map almost
    every node to p_min.
    """
    deg = g.degrees.astype(np.float64)
    logd = np.log1p(deg)
    lo, hi = logd.min(), logd.max()
    t = np.zeros_like(logd) if hi <= lo else (logd - lo) / (hi - lo)
    return (cfg.p_min + t * (cfg.p_max - cfg.p_min)).astype(np.float32)


def sample_protection_mask(
    g: Graph, cfg: DegreeQuantConfig, rng: np.random.Generator
) -> np.ndarray:
    """QAT-time stochastic mask: True = protected (float) this step."""
    p = protection_probabilities(g, cfg)
    return rng.random(g.num_nodes) < p


def inference_precision_tags(
    g: Graph, cfg: DegreeQuantConfig, *, rng: np.random.Generator | None = None
) -> np.ndarray:
    """Deterministic inference tags: the top ``float_ratio`` fraction of nodes
    by degree are protected (``"float"``); the rest run ``"int8"``.

    This is the deployment-time reading of Degree-Quant the accelerator
    consumes (Table 2's Precision column): protection correlates with degree,
    and the protected ratio matches Table 4.
    """
    n = g.num_nodes
    k = int(round(cfg.float_ratio * n))
    k = min(max(k, 1 if n else 0), n)
    tags = np.full(n, "int8", dtype=object)
    if k:
        deg = g.degrees
        if rng is not None:
            # tie-break hubs stochastically so equal-degree nodes rotate
            jitter = rng.random(n) * 0.5
        else:
            jitter = np.zeros(n)
        top = np.argsort(-(deg + jitter), kind="stable")[:k]
        tags[top] = "float"
    return tags.astype(str)


def allocate_nodeslots(
    resource_budget: Mapping[str, Mapping[str, float]],
    cost_per_slot: Mapping[str, Mapping[str, float]],
) -> Dict[str, int]:
    """Eq. 6: N_p = ceil( min_r  R_p^{max,r} / C_p^r ).

    ``resource_budget[p][r]`` is the budget of resource type r (LUT/FF/BRAM/
    DSP) granted to precision group p; ``cost_per_slot[p][r]`` the per-nodeslot
    cost of that resource in a single-precision synthesis. Returns nodeslot
    count per precision. Reused by the simulator's resource model and by the
    engine to pick the tile-lane split between precision streams.
    """
    slots: Dict[str, int] = {}
    for p, budget in resource_budget.items():
        costs = cost_per_slot[p]
        ratios = [
            budget[r] / costs[r] for r in budget if r in costs and costs[r] > 0
        ]
        if not ratios:
            raise ValueError(f"no overlapping resource types for precision {p!r}")
        slots[p] = max(1, int(np.ceil(min(ratios))))
    return slots
